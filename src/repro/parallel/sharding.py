"""Logical-axis sharding: map named parameter axes onto the physical mesh.

Every model exposes a pytree of logical-axis tuples mirroring its params
(e.g. ``("embed", "heads")`` for wq).  Rules tables translate logical names
to mesh axes; `resolve_spec` drops axes that don't divide evenly and never
reuses a mesh axis twice within one spec.

Three rule sets:

- ``DP_RULES``   — paper-faithful pure data parallelism (mirrored strategy):
                   params fully replicated, batch sharded over (pod, data).
- ``TP_RULES``   — tensor/expert parallelism over ``model`` only.
- ``FSDP_TP_RULES`` (beyond-paper default for big archs) — tensor/expert
                   parallel over ``model`` + parameter FSDP over ``data``.

Usage — resolve one spec, or shard a whole param tree::

    mesh = make_production_mesh()                  # (data=16, model=16)
    spec = resolve_spec(("embed", "heads"), (4096, 32), mesh, TP_RULES)
    # -> PartitionSpec(None, 'model')

    shardings = tree_shardings(model.logical_axes(cfg),
                               jax.eval_shape(model.init, key, cfg),
                               mesh, FSDP_TP_RULES)
    params = jax.device_put(params, shardings)

Activation-side helpers (`constrain_batch` / `constrain_act` /
`constrain_tree`) are with_sharding_constraint wrappers used INSIDE jitted
model code; the data-parallel engine (`train/engine.py`) instead relies on
`batch_axes`/`batch_spec` to place whole input batches.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# activation/cache logical axes shared by all rule sets
_ACT_RULES = {
    "batch": ("pod", "node", "data", "device"),
    "cache_seq": "model",
}

DP_RULES = {**_ACT_RULES}

TP_RULES = {
    **_ACT_RULES,
    "heads": "model", "kv_heads": "model", "mlp": "model",
    "vocab": "model", "inner": "model", "expert": "model",
}

FSDP_TP_RULES = {
    **TP_RULES,
    "embed": "data",
}

RULE_SETS = {"dp": DP_RULES, "tp": TP_RULES, "fsdp_tp": FSDP_TP_RULES}


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    """Size of mesh axis ``name``, or 1 when the mesh doesn't have it."""
    return mesh.shape[name] if name in mesh.axis_names else 1


def resolve_spec(logical, shape, mesh: Mesh, rules: dict) -> P:
    """logical: tuple of axis names (or None) matching `shape`.

    Rule values may be a mesh-axis name or a tuple of names (e.g. batch ->
    ("pod", "data")).  Axes that don't exist, don't divide the dim, or are
    already used by an earlier dim are dropped.
    """
    assert len(logical) == len(shape), (logical, shape)
    used = set()
    out = []
    for name, dim in zip(logical, shape):
        phys = rules.get(name) if name is not None else None
        if phys is None:
            out.append(None)
            continue
        cand = (phys,) if isinstance(phys, str) else tuple(phys)
        cand = tuple(a for a in cand if a in mesh.axis_names and a not in used)
        size = int(np.prod([mesh_axis_size(mesh, a) for a in cand])) if cand else 1
        if not cand or dim % size != 0:
            out.append(None)
        else:
            out.append(cand[0] if len(cand) == 1 else cand)
            used.update(cand)
    return P(*out)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def tree_specs(axes_tree, shape_tree, mesh: Mesh, rules: dict):
    """Build a PartitionSpec pytree from (axes, shapes) pytrees."""
    flat_axes = jax.tree.leaves(axes_tree, is_leaf=_is_axes_leaf)
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    assert len(flat_axes) == len(flat_shapes), (
        f"{len(flat_axes)} axis leaves vs {len(flat_shapes)} shape leaves")
    specs = [resolve_spec(a, tuple(s.shape), mesh, rules)
             for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree.unflatten(treedef, specs)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules: dict):
    """Like :func:`tree_specs` but wraps each spec in a ``NamedSharding`` —
    ready for ``jax.device_put`` / ``jit(in_shardings=...)``."""
    specs = tree_specs(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh):
    """Mesh axes carrying the batch dimension (paper: pure DP over these).

    Slow-to-fast order: ``pod``/``node`` (cross-pod / cross-node) before
    ``data``/``device`` — the hierarchical grad-reduce strategy relies on
    axis 0 being the inter-node level (see collectives.make_grad_reduce).
    """
    names = tuple(a for a in ("pod", "node", "data", "device")
                  if a in mesh.axis_names)
    return names if names else None


def batch_spec(mesh: Mesh, rank: int = 2) -> P:
    """PartitionSpec sharding dim 0 over the data axes, rest replicated:
    ``batch_spec(mesh, 3) -> P(('pod', 'data'), None, None)``."""
    ax = batch_axes(mesh)
    return P(ax, *([None] * (rank - 1)))


# Sequence-parallel residual-stream constraint.  ON: the seq dim of the
# residual stream is sharded over 'model' between blocks — smaller
# remat-saved activations, but GSPMD must re-gather the sequence for
# attention in every layer (an all-gather of the full activation per
# block, fwd AND bwd).  The §Perf hillclimb measured that cost dominating
# every train/prefill pair, so the default is OFF; flip per-run with
# `seq_sharding(True)` when activation MEMORY (not collectives) binds.
_SEQ_SHARD = [False]


class seq_sharding:
    """Context manager: enable/disable seq-dim model sharding."""

    def __init__(self, on: bool):
        self.on = on

    def __enter__(self):
        self.prev = _SEQ_SHARD[0]
        _SEQ_SHARD[0] = self.on
        return self

    def __exit__(self, *a):
        _SEQ_SHARD[0] = self.prev


def constrain_batch(x, mesh: Optional[Mesh], seq_dim: Optional[int] = None):
    """with_sharding_constraint: leading dim over (pod, data); optionally the
    ``seq_dim`` over 'model' (see seq_sharding above).  Skipped automatically
    when the dim does not divide."""
    if mesh is None or batch_axes(mesh) is None:
        return x
    entries = list(batch_spec(mesh, x.ndim))
    if (_SEQ_SHARD[0] and seq_dim is not None and "model" in mesh.axis_names
            and x.shape[seq_dim] % mesh_axis_size(mesh, "model") == 0
            and x.shape[seq_dim] > 1):
        entries[seq_dim] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def constrain_act(x, mesh: Optional[Mesh], logical: tuple,
                  rules: Optional[dict] = None):
    """Constrain one activation tensor by logical dim names.

    H5 (§Perf): pinning q/k/v/o to head-sharded, full-sequence layout
    inside each block locks GSPMD into the Megatron schedule (one AG of
    the residual into the block, one AR out) instead of per-chunk
    dynamic-slice gathers inside blockwise attention."""
    if mesh is None:
        return x
    rules = rules if rules is not None else FSDP_TP_RULES
    spec = resolve_spec(logical, tuple(x.shape), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_tree(tree, axes_tree, mesh: Optional[Mesh], rules: dict):
    """with_sharding_constraint over a whole param subtree.

    Used INSIDE the scan-over-layers body with TP_RULES: the per-layer
    weight slice is constrained to tensor-parallel-only sharding, so GSPMD
    ALL-GATHERS the (small) FSDP weight shards over 'data' instead of
    computing contractions against data-sharded weights and ALL-REDUCING
    the (huge) activation-sized partial sums — the §Perf H2 fix that cut
    the collective term ~20x on the big dense archs."""
    if mesh is None:
        return tree
    flat_axes = jax.tree.leaves(axes_tree, is_leaf=_is_axes_leaf)
    flat, treedef = jax.tree.flatten(tree)
    assert len(flat_axes) == len(flat), (len(flat_axes), len(flat))
    out = []
    for leaf, ax in zip(flat, flat_axes):
        spec = resolve_spec(ax, tuple(leaf.shape), mesh, rules)
        out.append(jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec)))
    return jax.tree.unflatten(treedef, out)


def stacked(axes_tree):
    """Prepend a (replicated) 'layers' axis to every leaf — for
    scan-over-layers stacked params."""
    return jax.tree.map(lambda t: (None,) + t, axes_tree, is_leaf=_is_axes_leaf)


def count_params(tree) -> int:
    """Total element count over every leaf of a param pytree."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))
