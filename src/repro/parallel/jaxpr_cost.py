"""Exact structural cost analysis by walking the jaxpr.

XLA's ``cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, so for
scan-over-layers models it under-reports FLOPs/bytes by ~n_layers.  This
module walks the closed jaxpr instead, multiplying scan bodies by their trip
count and remat (custom_jvp/checkpoint) bodies by their call count — giving
the TRUE global per-step numbers the roofline needs:

- flops: 2*M*N*K for every dot_general (batch dims included), the standard
  2 * out_elems * kernel_elems * C_in for convolutions;
- bytes: sum of operand + result aval bytes for every *memory-moving*
  primitive (dots, convs, gathers/scatters, dynamic slices, transposes,
  concatenations, reductions >= 1 MiB) — a structural HBM-traffic estimate
  consistent across architectures (it ignores fusion, like XLA's own
  "bytes accessed"; we report it per device by dividing by shard counts at
  the call site).

Usage:  stats = jaxpr_cost(jax.make_jaxpr(fn)(*args))
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.extend import core

_BIG = 1 << 20        # only count byte traffic of ops touching >= 1 MiB


def _aval_bytes(aval) -> int:
    if not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (v.aval for v in eqn.invars)
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    contract = math.prod(lhs.shape[d] for d in lc) or 1
    batch = math.prod(lhs.shape[d] for d in lb) or 1
    m = math.prod(lhs.shape[d] for d in range(len(lhs.shape))
                  if d not in lc and d not in lb) or 1
    n = math.prod(rhs.shape[d] for d in range(len(rhs.shape))
                  if d not in rc and d not in rb) or 1
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    k_spatial = math.prod(rhs.shape[d] for d in dn.rhs_spec[2:]) or 1
    c_in = rhs.shape[dn.rhs_spec[1]]
    groups = eqn.params.get("feature_group_count", 1)
    out_elems = math.prod(out.shape)
    return 2.0 * out_elems * k_spatial * (c_in // max(groups, 1)) * groups


# data-MOVEMENT primitives only: elementwise ops are excluded because XLA
# fuses their intermediate traffic away; what's left is a lower-ish bound
# on unavoidable HBM movement (matmul operands, gathers, cache updates,
# layout changes, reductions).  The roofline's memory term additionally
# uses the compiled post-fusion "bytes accessed" scaled by the scan-trip
# ratio — see benchmarks/roofline.py.
_MEM_PRIMS = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "transpose",
    "reduce_sum", "reduce_max", "cumsum", "rev", "pad", "slice",
}


def _eqn_bytes(eqn) -> int:
    total = sum(_aval_bytes(v.aval) for v in eqn.invars
                if isinstance(v, core.Var))
    total += sum(_aval_bytes(v.aval) for v in eqn.outvars)
    return total if total >= _BIG else 0


# jaxpr-level collective primitives (only visible inside shard_map bodies —
# the custom loop's explicit psums).  Their result bytes feed the cross-node
# interconnect model (cloud/interconnect.py): for the custom GAN loop the
# psum'd bytes ARE the per-phase gradient-reduction payload.
_COLLECTIVE_PRIMS = {
    "psum", "psum2", "psum_invariant", "pmax", "pmin", "all_gather",
    "all_to_all", "reduce_scatter", "psum_scatter", "ppermute", "pbroadcast",
}

# per-kind accumulator keys: the ZeRO-1 schedule (reduce-scatter grads ->
# local update -> all-gather params) is only visible when gather/scatter
# traffic is counted separately from the all-reduce psums
_COLLECTIVE_KIND = {
    "psum": "psum_bytes", "psum2": "psum_bytes",
    "psum_invariant": "psum_bytes",
    "all_gather": "all_gather_bytes",
    "reduce_scatter": "reduce_scatter_bytes",
    "psum_scatter": "reduce_scatter_bytes",
}


_CALL_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def _walk(jaxpr, mult: float, acc: dict):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
            acc["bytes"] += mult * _eqn_bytes(eqn)
            acc["dot_count"] += mult
        elif prim == "conv_general_dilated":
            acc["flops"] += mult * _conv_flops(eqn)
            acc["bytes"] += mult * _eqn_bytes(eqn)
        elif prim == "scan":
            length = eqn.params["length"]
            inner = eqn.params["jaxpr"]
            _walk(inner.jaxpr, mult * length, acc)
            continue
        elif prim == "shard_map":
            # the inner jaxpr is the PER-DEVICE program (local shapes);
            # every mesh device executes it, so global cost is x mesh.size
            mesh = eqn.params["mesh"]
            n = getattr(mesh, "size", None) or math.prod(mesh.shape.values())
            sub = eqn.params["jaxpr"]
            _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, mult * n, acc)
            continue
        elif prim == "while":
            # rarely used directly; body counted once (trip unknown)
            _walk(eqn.params["body_jaxpr"].jaxpr, mult, acc)
            continue
        elif prim == "cond":
            branches = eqn.params["branches"]
            # count the most expensive branch (worst case)
            subs = []
            for br in branches:
                sub = {k: 0.0 for k in acc}
                _walk(br.jaxpr, mult, sub)
                subs.append(sub)
            best = max(subs, key=lambda s: s["flops"])
            for k in best:
                acc[k] += best[k]
            continue
        elif prim in _COLLECTIVE_PRIMS:
            # per-replica payload (the shard_map multiplier already scaled
            # ``mult`` by the mesh size, so this totals GLOBAL bytes)
            b = mult * sum(_aval_bytes(v.aval) for v in eqn.outvars)
            acc["collective_bytes"] += b
            kind = _COLLECTIVE_KIND.get(prim)
            if kind:
                acc[kind] += b
        else:
            handled = False
            for key in _CALL_SUBJAXPR_KEYS:
                if key in eqn.params:
                    sub = eqn.params[key]
                    inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                    _walk(inner, mult, acc)
                    handled = True
                    break
            if not handled and prim in _MEM_PRIMS:
                acc["bytes"] += mult * _eqn_bytes(eqn)


def jaxpr_cost(closed_jaxpr) -> dict:
    """Returns {"flops", "bytes", "dot_count", "collective_bytes",
    "psum_bytes", "all_gather_bytes", "reduce_scatter_bytes"} — GLOBAL
    (unsharded) totals.

    ``flops`` counts matmul/conv MACs*2 (the MXU term); ``bytes`` is the
    structural memory-traffic estimate described in the module docstring;
    ``collective_bytes`` sums explicit jaxpr collectives (psum & friends,
    nonzero only for shard_map programs — the custom loop's gradient
    reductions) and feeds the interconnect model; the per-kind keys split
    it so the ZeRO-1 reduce-scatter/all-gather traffic is visible next to
    the gradient psums.
    """
    acc = {"flops": 0.0, "bytes": 0.0, "dot_count": 0.0,
           "collective_bytes": 0.0, "psum_bytes": 0.0,
           "all_gather_bytes": 0.0, "reduce_scatter_bytes": 0.0}
    _walk(closed_jaxpr.jaxpr, 1.0, acc)
    return acc


def cost_of(fn, *args) -> dict:
    """Trace fn(*args) (ShapeDtypeStructs fine) and analyse."""
    return jaxpr_cost(jax.make_jaxpr(fn)(*args))


def per_device_state_bytes(state, num_shards: int = 1) -> int:
    """Bytes of train state ONE device holds.

    Replicated leaves count in full; ZeRO-1 shard-major leaves — arrays
    under an optimizer's ``"zero1"`` subtree whose leading dim equals
    ``num_shards`` (`optim.optimizers.zero1`'s ``(N, L)`` layout, which
    `Engine.state_pspecs` shards over the data axes) — count 1/N.  Works
    on real arrays and ``jax.eval_shape`` outputs alike; the benches
    report it as ``state_bytes_per_device``.
    """
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        nbytes = int(np.prod(shape, dtype=np.int64)) * leaf.dtype.itemsize
        if num_shards > 1 and len(shape) >= 1 \
                and shape[0] == num_shards \
                and any(getattr(e, "key", None) == "zero1" for e in path):
            nbytes = -(-nbytes // num_shards)
        total += nbytes
    return int(total)


# ---------------------------------------------------------------------------
# Collective scheduling: MEASURED comm/compute overlap
# ---------------------------------------------------------------------------


def collective_schedule(closed_jaxpr) -> dict:
    """Dependence analysis of WHERE each collective sits in the program.

    A collective can overlap compute iff some compute scheduled after it
    does not consume its result — then an async runtime (and XLA's
    collective scheduler) can run them concurrently.  This walks the
    jaxpr in program order propagating a per-variable taint set of
    collective ids; a collective is HIDDEN the moment a later
    dot/conv does not carry its taint, and EXPOSED if every subsequent
    compute op depends on it (e.g. the monolithic post-backward psum,
    whose result feeds the optimizer update and nothing else runs).

    Returns ``{"n_collectives", "total_bytes", "hidden_bytes",
    "exposed_bytes", "exposed_frac"}`` where ``exposed_frac`` is the
    byte-weighted fraction with no independent later compute — the
    MEASURED counterpart of the interconnect model's overlap assumption
    (``cloud/interconnect.exposed_comm_s``).  Approximations: sub-jaxpr
    loop bodies are analysed once (cross-iteration hiding in a scan is
    not credited) and ``cond`` branches are all walked; both err toward
    reporting MORE exposure, never less.
    """
    taint: dict = {}                 # core.Var -> frozenset of cids
    info: list = []                  # cid -> {"bytes": float, "hidden": bool}

    def get(v):
        if isinstance(v, core.Literal):
            return frozenset()
        return taint.get(v, frozenset())

    def walk(jaxpr, mult):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            in_t = frozenset().union(*(get(v) for v in eqn.invars)) \
                if eqn.invars else frozenset()
            out_t = in_t
            if prim in ("dot_general", "conv_general_dilated"):
                # compute op: every live collective it does NOT depend on
                # has found something to hide under
                for cid, rec in enumerate(info):
                    if not rec["hidden"] and cid not in in_t:
                        rec["hidden"] = True
            elif prim in _COLLECTIVE_PRIMS:
                cid = len(info)
                info.append({"bytes": mult * sum(
                    _aval_bytes(v.aval) for v in eqn.outvars),
                    "hidden": False})
                out_t = in_t | {cid}
            else:
                sub, submult = None, mult
                if prim == "scan":
                    sub = eqn.params["jaxpr"]
                    submult = mult * eqn.params["length"]
                elif prim == "shard_map":
                    mesh = eqn.params["mesh"]
                    n = getattr(mesh, "size", None) or \
                        math.prod(mesh.shape.values())
                    sub = eqn.params["jaxpr"]
                    submult = mult * n
                elif prim == "while":
                    sub = eqn.params["body_jaxpr"]
                elif prim == "cond":
                    for br in eqn.params["branches"]:
                        out_t |= _enter(br, eqn.invars[1:], mult)
                else:
                    for key in _CALL_SUBJAXPR_KEYS:
                        if key in eqn.params:
                            sub = eqn.params[key]
                            break
                if sub is not None:
                    out_t |= _enter(sub, eqn.invars, submult)
            for v in eqn.outvars:
                taint[v] = out_t

    def _enter(sub, call_invars, mult):
        """Walk a sub-jaxpr with taints seeded from the call site;
        returns the union of its outvar taints."""
        inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
        iv = list(inner.invars)
        if len(iv) == len(call_invars):
            for a, b in zip(iv, call_invars):
                taint[a] = get(b)
        else:       # arity mismatch (carry packing): conservative union
            u = frozenset().union(*(get(b) for b in call_invars)) \
                if call_invars else frozenset()
            for a in iv:
                taint[a] = u
        walk(inner, mult)
        return frozenset().union(*(get(v) for v in inner.outvars)) \
            if inner.outvars else frozenset()

    walk(closed_jaxpr.jaxpr, 1.0)
    total = sum(r["bytes"] for r in info)
    hidden = sum(r["bytes"] for r in info if r["hidden"])
    return {"n_collectives": len(info), "total_bytes": total,
            "hidden_bytes": hidden, "exposed_bytes": total - hidden,
            "exposed_frac": (total - hidden) / total if total else 0.0}


def schedule_of(fn, *args) -> dict:
    """Trace fn(*args) (ShapeDtypeStructs fine) and analyse its
    collective schedule."""
    return collective_schedule(jax.make_jaxpr(fn)(*args))
