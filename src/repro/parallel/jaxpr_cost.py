"""Exact structural cost analysis by walking the jaxpr.

XLA's ``cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, so for
scan-over-layers models it under-reports FLOPs/bytes by ~n_layers.  This
module walks the closed jaxpr instead, multiplying scan bodies by their trip
count and remat (custom_jvp/checkpoint) bodies by their call count — giving
the TRUE global per-step numbers the roofline needs:

- flops: 2*M*N*K for every dot_general (batch dims included), the standard
  2 * out_elems * kernel_elems * C_in for convolutions;
- bytes: sum of operand + result aval bytes for every *memory-moving*
  primitive (dots, convs, gathers/scatters, dynamic slices, transposes,
  concatenations, reductions >= 1 MiB) — a structural HBM-traffic estimate
  consistent across architectures (it ignores fusion, like XLA's own
  "bytes accessed"; we report it per device by dividing by shard counts at
  the call site).

Usage:  stats = jaxpr_cost(jax.make_jaxpr(fn)(*args))
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.extend import core

_BIG = 1 << 20        # only count byte traffic of ops touching >= 1 MiB


def _aval_bytes(aval) -> int:
    if not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (v.aval for v in eqn.invars)
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    contract = math.prod(lhs.shape[d] for d in lc) or 1
    batch = math.prod(lhs.shape[d] for d in lb) or 1
    m = math.prod(lhs.shape[d] for d in range(len(lhs.shape))
                  if d not in lc and d not in lb) or 1
    n = math.prod(rhs.shape[d] for d in range(len(rhs.shape))
                  if d not in rc and d not in rb) or 1
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    k_spatial = math.prod(rhs.shape[d] for d in dn.rhs_spec[2:]) or 1
    c_in = rhs.shape[dn.rhs_spec[1]]
    groups = eqn.params.get("feature_group_count", 1)
    out_elems = math.prod(out.shape)
    return 2.0 * out_elems * k_spatial * (c_in // max(groups, 1)) * groups


# data-MOVEMENT primitives only: elementwise ops are excluded because XLA
# fuses their intermediate traffic away; what's left is a lower-ish bound
# on unavoidable HBM movement (matmul operands, gathers, cache updates,
# layout changes, reductions).  The roofline's memory term additionally
# uses the compiled post-fusion "bytes accessed" scaled by the scan-trip
# ratio — see benchmarks/roofline.py.
_MEM_PRIMS = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "transpose",
    "reduce_sum", "reduce_max", "cumsum", "rev", "pad", "slice",
}


def _eqn_bytes(eqn) -> int:
    total = sum(_aval_bytes(v.aval) for v in eqn.invars
                if isinstance(v, core.Var))
    total += sum(_aval_bytes(v.aval) for v in eqn.outvars)
    return total if total >= _BIG else 0


# jaxpr-level collective primitives (only visible inside shard_map bodies —
# the custom loop's explicit psums).  Their result bytes feed the cross-node
# interconnect model (cloud/interconnect.py): for the custom GAN loop the
# psum'd bytes ARE the per-phase gradient-reduction payload.
_COLLECTIVE_PRIMS = {
    "psum", "psum2", "psum_invariant", "pmax", "pmin", "all_gather",
    "all_to_all", "reduce_scatter", "ppermute", "pbroadcast",
}


_CALL_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def _walk(jaxpr, mult: float, acc: dict):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
            acc["bytes"] += mult * _eqn_bytes(eqn)
            acc["dot_count"] += mult
        elif prim == "conv_general_dilated":
            acc["flops"] += mult * _conv_flops(eqn)
            acc["bytes"] += mult * _eqn_bytes(eqn)
        elif prim == "scan":
            length = eqn.params["length"]
            inner = eqn.params["jaxpr"]
            _walk(inner.jaxpr, mult * length, acc)
            continue
        elif prim == "shard_map":
            # the inner jaxpr is the PER-DEVICE program (local shapes);
            # every mesh device executes it, so global cost is x mesh.size
            mesh = eqn.params["mesh"]
            n = getattr(mesh, "size", None) or math.prod(mesh.shape.values())
            sub = eqn.params["jaxpr"]
            _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, mult * n, acc)
            continue
        elif prim == "while":
            # rarely used directly; body counted once (trip unknown)
            _walk(eqn.params["body_jaxpr"].jaxpr, mult, acc)
            continue
        elif prim == "cond":
            branches = eqn.params["branches"]
            # count the most expensive branch (worst case)
            subs = []
            for br in branches:
                sub = {k: 0.0 for k in acc}
                _walk(br.jaxpr, mult, sub)
                subs.append(sub)
            best = max(subs, key=lambda s: s["flops"])
            for k in best:
                acc[k] += best[k]
            continue
        elif prim in _COLLECTIVE_PRIMS:
            # per-replica payload (the shard_map multiplier already scaled
            # ``mult`` by the mesh size, so this totals GLOBAL bytes)
            acc["collective_bytes"] += mult * sum(
                _aval_bytes(v.aval) for v in eqn.outvars)
        else:
            handled = False
            for key in _CALL_SUBJAXPR_KEYS:
                if key in eqn.params:
                    sub = eqn.params[key]
                    inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                    _walk(inner, mult, acc)
                    handled = True
                    break
            if not handled and prim in _MEM_PRIMS:
                acc["bytes"] += mult * _eqn_bytes(eqn)


def jaxpr_cost(closed_jaxpr) -> dict:
    """Returns {"flops", "bytes", "dot_count", "collective_bytes"} — GLOBAL
    (unsharded) totals.

    ``flops`` counts matmul/conv MACs*2 (the MXU term); ``bytes`` is the
    structural memory-traffic estimate described in the module docstring;
    ``collective_bytes`` sums explicit jaxpr collectives (psum & friends,
    nonzero only for shard_map programs — the custom loop's gradient
    reductions) and feeds the interconnect model.
    """
    acc = {"flops": 0.0, "bytes": 0.0, "dot_count": 0.0,
           "collective_bytes": 0.0}
    _walk(closed_jaxpr.jaxpr, 1.0, acc)
    return acc


def cost_of(fn, *args) -> dict:
    """Trace fn(*args) (ShapeDtypeStructs fine) and analyse."""
    return jaxpr_cost(jax.make_jaxpr(fn)(*args))
