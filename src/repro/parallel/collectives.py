"""Collective traffic analysis + gradient-reduction strategies.

Two halves:

1. HLO parsing — ``cost_analysis()`` does not expose collective bytes, so
   the roofline's third term comes from summing operand/result sizes of
   every collective op in the optimized HLO module.
2. Gradient reduction — the strategies the custom training loop selects
   via config (``flat`` | ``hierarchical`` | ``overlap``).  ``flat`` is
   one psum-mean over all data axes (what the engine always did);
   ``hierarchical`` is the 2-level cluster schedule: intra-node psum over
   the fast ``device`` axis first, then a BUCKETED reduction over the
   slow ``node`` axis — gradient leaves are packed into ~bucket_bytes 1-D
   buckets, each bucket its own collective, so XLA can start reducing
   early buckets while the tail of the backward pass still computes, and
   small leaves stop paying a per-tensor inter-node latency.  ``overlap``
   goes one step further: the SAME buckets, issued in reverse parameter
   order (last-computed grads first) from INSIDE the backward pass — each
   bucket's reduction is a ``jax.custom_vjp`` identity tag on the
   parameters whose backward rule performs the collective, so it fires as
   soon as that bucket's cotangents exist, while earlier layers are still
   differentiating (see :class:`OverlapReduce`).  All strategies divide
   by the total replica count, so they are numerically interchangeable
   (asserted by tests/test_scaleout.py at f32 tolerance).
"""
from __future__ import annotations

import re
from collections import defaultdict

import jax
import jax.numpy as jnp

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# matches e.g.  f32[512,1024]  or  bf16[8,128]{1,0}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# LHS of an HLO instruction:  %name = <result-type> opcode(
_LINE_RE = re.compile(
    r"=\s*(.+?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start|-done)?\(")


def _shape_bytes(span: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(span):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# while instruction with named condition/body computations
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict:
    """Map computation name -> its text block.

    A computation header is a top-level line ending in ``{`` that contains
    ``->`` (params may hold arbitrarily nested parens, so no param regex);
    the name is the first ``%``-token (with optional leading ENTRY).
    """
    blocks = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s and not line.startswith("  "):
            tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
            name = tok.lstrip("%")
            buf = []
            blocks[name] = buf
        elif s == "}":
            name = None
        elif name is not None:
            buf.append(line)
    return {k: "\n".join(v) for k, v in blocks.items()}


def _loop_multipliers(blocks: dict) -> dict:
    """Per-computation execution-count multiplier from while-loop nesting.

    XLA prints a while body ONCE regardless of trip count, so anything
    inside it (collectives included) must be scaled by the loop length —
    read from the loop-condition's s32 constant (the jax.lax.scan bound).
    """
    parent = {}          # body -> (enclosing computation, trip count)
    for comp, text in blocks.items():
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            consts = [int(c) for c in _S32_CONST_RE.findall(
                blocks.get(cond, ""))]
            trip = max(consts) if consts else 1
            parent[body] = (comp, trip)

    mult = {}

    def resolve(comp, _depth=0):
        if comp in mult:
            return mult[comp]
        if comp not in parent or _depth > 32:
            mult[comp] = 1.0
            return 1.0
        up, trip = parent[comp]
        mult[comp] = trip * resolve(up, _depth + 1)
        return mult[comp]

    for comp in blocks:
        resolve(comp)
    return mult


def collective_stats(hlo_text: str, scale_loops: bool = True) -> dict:
    """Returns {op: {"bytes": result-bytes-sum, "count": n}} per collective
    kind (async -start/-done pairs counted once, on the -start).

    With ``scale_loops`` (default), collectives inside while-loop bodies are
    multiplied by the loop trip count — XLA prints scan bodies once, but the
    traffic happens every iteration.
    """
    blocks = _split_computations(hlo_text)
    mults = _loop_multipliers(blocks) if scale_loops else {}
    stats = defaultdict(lambda: {"bytes": 0, "count": 0})
    for comp, text in blocks.items():
        k = mults.get(comp, 1.0)
        for line in text.splitlines():
            m = _LINE_RE.search(line)
            if not m:
                continue
            result_span, op, suffix = m.group(1), m.group(2), m.group(3)
            if suffix == "-done":
                continue
            stats[op]["bytes"] += int(k * _shape_bytes(result_span))
            stats[op]["count"] += int(k)
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


GRAD_REDUCE_STRATEGIES = ("flat", "hierarchical", "overlap")
DEFAULT_BUCKET_BYTES = 4 << 20        # 4 MiB per inter-node bucket


def plan_buckets(leaves, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Greedy bucket plan over gradient leaves: lists of leaf indices.

    Leaves are packed in flatten order, same-dtype only (buckets are
    concatenated into one 1-D array), cut when the running size would
    exceed ``bucket_bytes``.  A single leaf larger than the cap gets its
    own bucket — nothing is ever split across buckets.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be > 0, got {bucket_bytes}")
    buckets, cur, cur_bytes, cur_dtype = [], [], 0, None
    for i, leaf in enumerate(leaves):
        nbytes = leaf.size * leaf.dtype.itemsize
        if cur and (leaf.dtype != cur_dtype or cur_bytes + nbytes > bucket_bytes):
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nbytes
        cur_dtype = leaf.dtype
    if cur:
        buckets.append(cur)
    return buckets


def _bucketed(tree, reduce_vec, bucket_bytes: int):
    """Apply ``reduce_vec`` (1-D array -> 1-D array) bucket-by-bucket.

    Flattens the tree, packs leaves into :func:`plan_buckets` groups,
    concatenates each group into one vector, reduces it, and splits the
    result back into the original shapes/treedef.  Each bucket is an
    independent collective in the lowered program — the overlap (and
    latency-amortization) granularity of the hierarchical strategy.
    """
    flat, treedef = jax.tree.flatten(tree)
    out = list(flat)
    for bucket in plan_buckets(flat, bucket_bytes):
        vec = jnp.concatenate([flat[i].reshape(-1) for i in bucket]) \
            if len(bucket) > 1 else flat[bucket[0]].reshape(-1)
        vec = reduce_vec(vec)
        off = 0
        for i in bucket:
            n = flat[i].size
            out[i] = jax.lax.slice(vec, (off,), (off + n,)) \
                .reshape(flat[i].shape)
            off += n
    return jax.tree.unflatten(treedef, out)


def bucket_transform(bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Identity-valued bucket regrouping (concat -> split).

    The builtin (jit + GSPMD) loop's gradients arrive already all-reduced
    by the partitioner, so there is no explicit psum to restructure; the
    ``hierarchical`` strategy there only re-expresses the gradient stream
    at bucket granularity and leaves reduction placement to GSPMD — the
    exact control gap between the paper's built-in and custom strategies.
    """
    def apply(tree):
        return _bucketed(tree, lambda v: v, bucket_bytes)

    return apply


def reverse_bucket_schedule(leaves, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Bucket plan in backward-completion order.

    The backward pass produces gradients in reverse forward order: the
    LAST parameters a forward pass touches get their cotangents FIRST.
    Reversing :func:`plan_buckets` therefore lists buckets in the order
    their gradients become available — the issue order of the ``overlap``
    strategy.  The schedule is an exact permutation of the plan_buckets
    output: same buckets, same intra-bucket leaf order, no leaf dropped
    or duplicated (pinned by tests/test_property.py).
    """
    return list(reversed(plan_buckets(leaves, bucket_bytes)))


def _bucket_tag(reduce_vec):
    """custom_vjp identity over one bucket's parameter leaves.

    Forward: pass the leaves through untouched (zero cost — XLA folds the
    identity away).  Backward: the bucket's cotangents are concatenated
    into one 1-D vector, ``reduce_vec`` runs the collective, and the
    result is sliced back to leaf shapes.  Because the tag sits on the
    PARAMETERS, its backward rule executes the moment every cotangent of
    the bucket exists — i.e. mid-backward, overlapping the reduction with
    the differentiation of earlier layers.
    """
    @jax.custom_vjp
    def tag(*leaves):
        return leaves

    def fwd(*leaves):
        return leaves, tuple((l.shape, l.size) for l in leaves)

    def bwd(meta, cts):
        vec = cts[0].reshape(-1) if len(meta) == 1 else \
            jnp.concatenate([c.reshape(-1) for c in cts])
        vec = reduce_vec(vec)
        out, off = [], 0
        for shape, n in meta:
            out.append(jax.lax.slice(vec, (off,), (off + n,)).reshape(shape))
            off += n
        return tuple(out)

    tag.defvjp(fwd, bwd)
    return tag


class OverlapReduce:
    """Dataflow-scheduled gradient reduction (``grad_reduce="overlap"``).

    Two-sided protocol with the train steps:

    - ``wrap_params(params)`` is called on the parameter pytree BEFORE the
      loss evaluation.  It installs a :func:`_bucket_tag` per
      reverse-order bucket; differentiating the wrapped loss then reduces
      each bucket inside the backward pass itself, as soon as its
      cotangents complete.
    - ``__call__(grads)`` — the post-hoc hook every step already applies —
      is the identity: by the time the gradient tree exists, reduction
      already happened.

    Steps detect the protocol via ``getattr(reduce, "wrap_params", None)``
    so plain callables and the other strategies keep the old post-hoc
    contract.
    """

    def __init__(self, reduce_vec, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
        self.reduce_vec = reduce_vec
        self.bucket_bytes = bucket_bytes

    def wrap_params(self, params):
        flat, treedef = jax.tree.flatten(params)
        out = list(flat)
        for bucket in reverse_bucket_schedule(flat, self.bucket_bytes):
            tagged = _bucket_tag(self.reduce_vec)(*[flat[i] for i in bucket])
            for j, i in enumerate(bucket):
                out[i] = tagged[j]
        return jax.tree.unflatten(treedef, out)

    def __call__(self, tree):
        return tree


def overlap_transform(bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Builtin-loop ``overlap``: identity-valued in-backward regrouping.

    The jit+GSPMD loop's gradients are all-reduced by the partitioner, so
    — exactly like :func:`bucket_transform` for ``hierarchical`` — the
    overlap strategy there only re-expresses the gradient stream at
    bucket granularity, but does it INSIDE the backward pass in reverse
    bucket order, leaving reduction placement to GSPMD.  Numerics are
    bit-identical (concat -> slice is the identity)."""
    return OverlapReduce(lambda v: v, bucket_bytes)


def make_grad_reduce(strategy, mesh, axes, *,
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Build the ``grad_reduce`` callable the custom (shard_map) loop
    applies to every phase's gradients before its optimizer update.

    ``strategy``: a callable is passed through; ``"flat"`` is one
    psum-mean over all ``axes``; ``"hierarchical"`` treats ``axes[0]`` as
    the slow inter-node axis and ``axes[1:]`` as the fast intra-node axes
    (mesh convention: ``(node, device)``, and ``(pod, data)`` maps the
    same way) — intra psum first, then bucketed psums over the node axis,
    then one division by the global replica count.  ``"overlap"`` runs
    the same per-bucket hierarchical collective but returns an
    :class:`OverlapReduce`, whose ``wrap_params`` hook moves each
    bucket's reduction INTO the backward pass (reverse bucket order, so
    the first-completed gradients reduce first); unlike hierarchical it
    also works on flat (single-axis) meshes.  Means are identical to
    ``flat`` up to f32 summation-order rounding.
    """
    if strategy is None or callable(strategy):
        return strategy
    if strategy not in GRAD_REDUCE_STRATEGIES:
        raise ValueError(f"grad_reduce must be one of "
                         f"{GRAD_REDUCE_STRATEGIES}, got {strategy!r}")
    axes = tuple(axes or ())
    if not axes:
        return lambda tree: tree
    if strategy == "flat":
        return lambda tree: jax.lax.pmean(tree, axes)
    world = 1
    for a in axes:
        world *= mesh.shape[a]
    inv = 1.0 / world

    if strategy == "overlap":
        if len(axes) >= 2:
            o_inter, o_intra = axes[0], axes[1:]

            def reduce_vec(v):
                v = jax.lax.psum(v, o_intra)             # NVLink/ICI hop
                v = jax.lax.psum(v, o_inter)             # NIC hop
                return v * jnp.asarray(inv, v.dtype)
        else:
            def reduce_vec(v):
                return jax.lax.psum(v, axes) * jnp.asarray(inv, v.dtype)

        return OverlapReduce(reduce_vec, bucket_bytes)

    if len(axes) < 2:
        raise ValueError(
            "hierarchical grad_reduce needs a 2-level mesh (node, device); "
            f"got data axes {axes} — use strategy='flat' on flat meshes")
    inter, intra = axes[0], axes[1:]

    def reduce(tree):
        tree = jax.lax.psum(tree, intra)                 # NVLink/ICI hop
        tree = _bucketed(tree, lambda v: jax.lax.psum(v, inter),
                         bucket_bytes)                    # NIC hops, bucketed
        return jax.tree.map(lambda g: g * jnp.asarray(inv, g.dtype), tree)

    return reduce


def ici_traffic_bytes(stats: dict, n_devices: int) -> float:
    """Approximate per-device ICI traffic from result sizes.

    ring algorithms: all-gather/reduce-scatter move (N-1)/N of the global
    result per device; all-reduce = reduce-scatter + all-gather = 2x that;
    all-to-all moves (N-1)/N of the shard; collective-permute moves the
    full result once.
    """
    f = (n_devices - 1) / max(n_devices, 1)
    total = 0.0
    for op, v in stats.items():
        b = v["bytes"]
        if op == "all-reduce":
            total += 2 * f * b
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            total += f * b
        else:                       # collective-permute
            total += b
    return total
