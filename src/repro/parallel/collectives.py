"""Parse collective traffic out of lowered/compiled HLO text.

``cost_analysis()`` does not expose collective bytes, so the roofline's
third term comes from summing operand/result sizes of every collective op
in the optimized HLO module.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# matches e.g.  f32[512,1024]  or  bf16[8,128]{1,0}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# LHS of an HLO instruction:  %name = <result-type> opcode(
_LINE_RE = re.compile(
    r"=\s*(.+?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start|-done)?\(")


def _shape_bytes(span: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(span):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# while instruction with named condition/body computations
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict:
    """Map computation name -> its text block.

    A computation header is a top-level line ending in ``{`` that contains
    ``->`` (params may hold arbitrarily nested parens, so no param regex);
    the name is the first ``%``-token (with optional leading ENTRY).
    """
    blocks = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s and not line.startswith("  "):
            tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
            name = tok.lstrip("%")
            buf = []
            blocks[name] = buf
        elif s == "}":
            name = None
        elif name is not None:
            buf.append(line)
    return {k: "\n".join(v) for k, v in blocks.items()}


def _loop_multipliers(blocks: dict) -> dict:
    """Per-computation execution-count multiplier from while-loop nesting.

    XLA prints a while body ONCE regardless of trip count, so anything
    inside it (collectives included) must be scaled by the loop length —
    read from the loop-condition's s32 constant (the jax.lax.scan bound).
    """
    parent = {}          # body -> (enclosing computation, trip count)
    for comp, text in blocks.items():
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            consts = [int(c) for c in _S32_CONST_RE.findall(
                blocks.get(cond, ""))]
            trip = max(consts) if consts else 1
            parent[body] = (comp, trip)

    mult = {}

    def resolve(comp, _depth=0):
        if comp in mult:
            return mult[comp]
        if comp not in parent or _depth > 32:
            mult[comp] = 1.0
            return 1.0
        up, trip = parent[comp]
        mult[comp] = trip * resolve(up, _depth + 1)
        return mult[comp]

    for comp in blocks:
        resolve(comp)
    return mult


def collective_stats(hlo_text: str, scale_loops: bool = True) -> dict:
    """Returns {op: {"bytes": result-bytes-sum, "count": n}} per collective
    kind (async -start/-done pairs counted once, on the -start).

    With ``scale_loops`` (default), collectives inside while-loop bodies are
    multiplied by the loop trip count — XLA prints scan bodies once, but the
    traffic happens every iteration.
    """
    blocks = _split_computations(hlo_text)
    mults = _loop_multipliers(blocks) if scale_loops else {}
    stats = defaultdict(lambda: {"bytes": 0, "count": 0})
    for comp, text in blocks.items():
        k = mults.get(comp, 1.0)
        for line in text.splitlines():
            m = _LINE_RE.search(line)
            if not m:
                continue
            result_span, op, suffix = m.group(1), m.group(2), m.group(3)
            if suffix == "-done":
                continue
            stats[op]["bytes"] += int(k * _shape_bytes(result_span))
            stats[op]["count"] += int(k)
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def ici_traffic_bytes(stats: dict, n_devices: int) -> float:
    """Approximate per-device ICI traffic from result sizes.

    ring algorithms: all-gather/reduce-scatter move (N-1)/N of the global
    result per device; all-reduce = reduce-scatter + all-gather = 2x that;
    all-to-all moves (N-1)/N of the shard; collective-permute moves the
    full result once.
    """
    f = (n_devices - 1) / max(n_devices, 1)
    total = 0.0
    for op, v in stats.items():
        b = v["bytes"]
        if op == "all-reduce":
            total += 2 * f * b
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            total += f * b
        else:                       # collective-permute
            total += b
    return total
