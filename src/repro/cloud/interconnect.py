"""Comms-aware analytic model: per-step all-reduce time per topology.

The paper's weak-scaling story (Fig. 2) lives or dies on how gradient
all-reduce time grows with node count.  This module prices a reduction
payload on a :class:`repro.launch.mesh.Topology` under the three
strategies the runtime implements
(``parallel/collectives.make_grad_reduce``):

``flat``
    One ring over ALL ``nodes * devices_per_node`` replicas.  With more
    than one node the ring crosses node boundaries, so the bandwidth term
    is bounded by the inter-node NIC, and every replica adds two latency
    hops — the classic many-small-workers penalty the paper measures in
    its worker-configuration sweep (Fig. 4).

``hierarchical``
    Ring reduce-scatter + all-gather INSIDE each node over NVLink/ICI,
    then per-shard rings ACROSS nodes: the node NIC carries
    ``2*(n-1)/n * nbytes`` once, and only ``2*(n-1)`` latency hops per
    bucket remain on the slow link.  The runtime still issues these
    bucketed psums AFTER the full backward, so the model treats the
    whole reduction as exposed.

``overlap``
    Same hierarchical bucket collectives, but issued from INSIDE the
    backward pass in reverse parameter order
    (``collectives.OverlapReduce``) — every bucket except each round's
    tail can hide under the remaining backward compute, so only the tail
    (clamped by the backward window) enters the predicted step time.
    Historically this overlap credit was (incorrectly) granted to the
    ``hierarchical`` strategy; since the runtime grew a real overlapping
    reducer the credit lives where the runtime earns it, and
    ``parallel/jaxpr_cost.collective_schedule`` measures the actual
    exposed fraction to compare against this model (the
    ``bench_fig2_weakscaling`` gap columns).

Payloads come from measurement or structure, not guesses: per-phase
gradient bytes via ``core/adversarial.grad_reduce_traffic`` /
``train/steps.grad_reduce_traffic``, or the jaxpr walk's
``collective_bytes`` term (``parallel/jaxpr_cost``) for an arbitrary
shard_map program.  `cloud/planner.py` combines these predictions with
measured single-node step times into the Fig. 2 / Fig. 5 curves.

All formulas are standard ring-collective algebra; constants live on the
``Topology``'s :class:`repro.launch.mesh.Link` objects.
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence, Tuple

from repro.launch.mesh import Link, Topology
# the model must price the SAME bucket granularity the runtime lowers
from repro.parallel.collectives import DEFAULT_BUCKET_BYTES
# fraction of a step's compute that runs AFTER the first gradient bucket
# is ready (i.e. the backward-pass window bucketed reduction can hide
# under).  Algorithm 1 is ~2/3 backward by FLOPs.
OVERLAP_WINDOW = 0.5


def ring_allreduce_s(nbytes: float, world: int, link: Link,
                     n_buckets: int = 1) -> float:
    """Ring all-reduce of ``nbytes`` over ``world`` peers on one link
    class: reduce-scatter + all-gather move ``2*(w-1)/w`` of the payload
    past every peer, plus ``2*(w-1)`` latency hops per bucket."""
    if world <= 1 or nbytes <= 0:
        return 0.0
    bw = 2.0 * (world - 1) / world * nbytes / link.bandwidth
    lat = 2.0 * (world - 1) * link.latency * max(n_buckets, 1)
    return bw + lat


def n_buckets(nbytes: float, bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> int:
    return max(1, math.ceil(nbytes / max(bucket_bytes, 1)))


def allreduce_s(nbytes: float, topo: Topology, strategy: str = "hierarchical",
                bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> float:
    """Wall time of one gradient all-reduce of ``nbytes`` on ``topo``."""
    d, n = topo.devices_per_node, topo.nodes
    if nbytes <= 0 or topo.total_devices <= 1:
        return 0.0
    nb = n_buckets(nbytes, bucket_bytes)
    if strategy == "flat":
        if n == 1:
            return ring_allreduce_s(nbytes, d, topo.intra_link, 1)
        # one ring over all N replicas; the stream crosses a NIC at every
        # node boundary, so the slow link bounds the bandwidth term and
        # every replica contributes latency hops (un-bucketed: the flat
        # strategy reduces each tensor in one shot)
        slow = Link(min(topo.intra_link.bandwidth, topo.inter_link.bandwidth),
                    max(topo.intra_link.latency, topo.inter_link.latency))
        return ring_allreduce_s(nbytes, n * d, slow, 1)
    if strategy not in ("hierarchical", "overlap"):
        raise ValueError(f"unknown strategy {strategy!r}")
    # "overlap" issues the SAME hierarchical bucket collectives, just
    # earlier (from inside the backward) — identical wire time; only the
    # exposed fraction differs (see exposed_comm_s).
    t_intra = ring_allreduce_s(nbytes, d, topo.intra_link, nb)
    # inter-node: after the intra reduce-scatter each of the d devices
    # owns nbytes/d; their cross-node rings run in parallel but share the
    # node NIC, which therefore carries the full 2*(n-1)/n * nbytes
    t_inter = ring_allreduce_s(nbytes, n, topo.inter_link, nb)
    return t_intra + t_inter


def exposed_comm_s(rounds: Iterable[Tuple[str, float]], topo: Topology,
                   strategy: str = "hierarchical",
                   bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                   compute_s: float = 0.0,
                   tail_bytes: dict | None = None) -> float:
    """Non-overlapped communication time of one training step.

    ``rounds``: the step's reduction payloads in program order (e.g.
    ``adversarial.grad_reduce_traffic(cfg)["rounds"]``).  Each round is
    priced by :func:`allreduce_s`.

    ``flat`` and ``hierarchical`` reduce AFTER the backward pass, so the
    whole reduction is exposed.  ``overlap`` issues buckets from inside
    the backward in reverse parameter order: everything except each
    round's TAIL bucket (the one carrying the earliest-forward params,
    whose cotangents arrive last — no compute left to hide under) can
    overlap with the backward window ``OVERLAP_WINDOW * compute_s``, so
    the exposed time is ``max(total - window, tails)``.

    ``tail_bytes`` maps round name -> actual bytes of that round's tail
    bucket (from the runtime's real ``plan_buckets`` plan — tail buckets
    are whole leaves, so an oversize first layer makes the tail far
    bigger than the uniform ``bytes/n_buckets`` guess used when the map
    is absent).  Supplying it is what makes the modeled overlap term
    track the measured schedule (``jaxpr_cost.collective_schedule``).
    """
    rounds = list(rounds)
    total = sum(allreduce_s(b, topo, strategy, bucket_bytes)
                for _, b in rounds)
    if strategy != "overlap" or total <= 0:
        return total
    tail = sum(
        allreduce_s((tail_bytes or {}).get(name,
                                           b / n_buckets(b, bucket_bytes)),
                    topo, strategy, bucket_bytes)
        for name, b in rounds)
    return max(total - OVERLAP_WINDOW * compute_s, tail)


def predict_step_s(compute_s: float, rounds: Sequence[Tuple[str, float]],
                   topo: Topology, strategy: str = "hierarchical",
                   bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                   tail_bytes: dict | None = None) -> dict:
    """Predicted per-step wall time on ``topo``: measured/derived compute
    plus the exposed communication term.  Returns the decomposition the
    weak-scaling bench reports side by side with the roofline numbers."""
    comm = exposed_comm_s(rounds, topo, strategy, bucket_bytes, compute_s,
                          tail_bytes)
    return {
        "compute_s": compute_s,
        "comm_s": comm,
        "comm_total_s": sum(allreduce_s(b, topo, strategy, bucket_bytes)
                            for _, b in rounds),
        "step_s": compute_s + comm,
        "strategy": strategy,
    }
