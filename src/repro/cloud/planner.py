"""Measurement-driven cloud scaling planner (paper Fig. 2 + Fig. 5).

Replays MEASURED single-node step times — the committed
``results/BENCH_fig1_loop.json`` baselines, or any anchor you hand it —
through the cross-node interconnect model (`cloud/interconnect.py`) and
the GCP price table (`cloud/costs.py`) to answer the paper's two
questions without touching a cluster:

- Fig. 2: how does epoch time scale as nodes are added (weak scaling,
  per-device batch fixed)?  ``weak_scaling_curve`` predicts the step-time
  decomposition per topology; efficiency falls out of the measured
  compute anchor vs. the predicted exposed communication — no efficiency
  table is ever hard-coded on this path.
- Fig. 5: what does an epoch COST across offerings (reserved vs.
  preemptible V100 nodes, TPU v2/v3 slices), and which one should I buy?
  ``efficiency_table`` + ``cost_frontier`` rebuild the paper's cost
  table from an anchor epoch + the derived efficiencies;
  ``recommend(budget, deadline)`` picks the cheapest feasible offering.

CLI: ``tools/plan_scaleout.py``; benchmarks
``bench_fig2_weakscaling``/``bench_fig5_cost`` report these predictions
next to roofline-derived ("measured") numbers.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, Optional, Sequence

from repro.cloud import costs as cost_lib
from repro.cloud import interconnect
from repro.launch.mesh import Topology, gpu_topology, tpu_topology

EPOCH_SAMPLES = 180_000        # paper-era 3DGAN training-set scale
# per-device batch sizes of the paper's MEASURED runs — the epoch anchors
# fed to cost_frontier imply a step time only at these batch sizes, so
# they must track the configuration the anchor was measured in
GPU_ANCHOR_BS = 96             # paper Fig. 5: BS=96 per V100
TPU_ANCHOR_BS = 128            # paper Fig. 2: BS=128 per TPU core


@dataclasses.dataclass(frozen=True)
class Anchor:
    """One measured single-node training-step baseline."""
    step_s: float               # measured wall time of one step
    global_batch: int           # samples per step in that measurement
    loop: str = "custom"
    config: str = "bench"       # calo3dgan config variant measured
    source: str = "manual"

    @property
    def per_device_batch(self) -> int:
        return self.global_batch      # anchors are single-device runs


def load_anchor(results_dir: str, prefer_loop: str = "custom") -> Anchor:
    """Measured GAN step time from ``results/BENCH_fig1_loop.json``: the
    largest-batch row of the preferred loop (fused loops only — the naive
    baseline is the bottleneck the paper removes, not a scaling anchor).
    """
    path = os.path.join(results_dir, "BENCH_fig1_loop.json")
    with open(path) as f:
        payload = json.load(f)
    rows = payload["rows"] if isinstance(payload, dict) else payload
    row = max(rows, key=lambda r: r["global_batch"])
    for loop in (prefer_loop, "builtin", "custom"):
        ms = row.get(f"{loop}_ms")
        if ms:                       # missing or null column: next loop
            break
    else:
        raise KeyError(f"no fused-loop step time in {path}")
    return Anchor(step_s=ms / 1e3, global_batch=int(row["global_batch"]),
                  loop=loop, config="bench", source=path)


def gan_rounds(config: str = "bench") -> list:
    """Per-phase gradient-reduction payloads of the fused Algorithm-1
    step for a calo3dgan config variant (lazy jax import)."""
    from repro.configs import calo3dgan
    from repro.core import adversarial

    cfg = {"full": calo3dgan.config, "reduced": calo3dgan.reduced,
           "bench": calo3dgan.bench}[config]()
    return adversarial.grad_reduce_traffic(cfg)["rounds"]


def gpu_count_topology(n_gpus: int, gpus_per_node: int = 8) -> Topology:
    """Fig. 5 granularity: <= ``gpus_per_node`` GPUs live in ONE node
    (NVLink only); beyond that, full nodes on the NIC."""
    if n_gpus <= gpus_per_node:
        return gpu_topology(1, n_gpus)
    assert n_gpus % gpus_per_node == 0, n_gpus
    return gpu_topology(n_gpus // gpus_per_node, gpus_per_node)


def weak_scaling_curve(anchor: Anchor, *,
                       node_counts: Sequence[int] = (1, 2, 4, 8, 16),
                       devices_per_node: int = 8,
                       strategy: str = "overlap",
                       bucket_bytes: int = interconnect.DEFAULT_BUCKET_BYTES,
                       rounds: Optional[list] = None,
                       samples_per_epoch: int = EPOCH_SAMPLES,
                       family: str = "v100",
                       tail_bytes: Optional[dict] = None) -> list:
    """Fig. 2 prediction: per-device batch fixed at the anchor's, global
    batch grows with devices.  Efficiency = anchor step / predicted step
    — measured compute + modelled exposed comms, nothing tabulated.

    ``tail_bytes`` (round name -> tail-bucket bytes from the runtime's
    real bucket plan) sharpens the ``overlap`` strategy's exposed term;
    see :func:`interconnect.exposed_comm_s`.
    """
    rounds = rounds if rounds is not None else gan_rounds(anchor.config)
    rows = []
    for n in node_counts:
        if family == "v100":
            topo = gpu_topology(n, devices_per_node)
        else:
            topo = tpu_topology(family.split("_")[1],
                                n * devices_per_node)
        pred = interconnect.predict_step_s(anchor.step_s, rounds, topo,
                                           strategy, bucket_bytes,
                                           tail_bytes)
        devices = topo.total_devices
        global_batch = anchor.per_device_batch * devices
        steps_per_epoch = samples_per_epoch / global_batch
        rows.append({
            "topology": topo.name, "nodes": topo.nodes, "devices": devices,
            "global_batch": global_batch,
            "step_s_pred": pred["step_s"],
            "comm_s_pred": pred["comm_s"],
            "epoch_s_pred": pred["step_s"] * steps_per_epoch,
            "efficiency_pred": anchor.step_s / pred["step_s"],
            "strategy": strategy,
        })
    return rows


def efficiency_table(anchor_step_s: float, *,
                     counts: Sequence[int] = (2, 4, 8, 16, 32, 64, 128),
                     base: int = 2,
                     strategy: str = "overlap",
                     bucket_bytes: int = interconnect.DEFAULT_BUCKET_BYTES,
                     rounds: Optional[list] = None,
                     config: str = "full") -> Dict[int, float]:
    """Parallel efficiency per GPU count, derived (NOT tabulated): the
    measured base-step compute is held fixed per device (weak scaling per
    step), each count pays its topology's exposed comms.

    ``anchor_step_s`` is the measured per-step time at the ``base`` GPU
    count; compute is backed out by subtracting the base topology's own
    (small) comm term, so efficiencies stay relative to a comm-free
    ideal exactly like the paper's Fig. 5 normalization.
    """
    rounds = rounds if rounds is not None else gan_rounds(config)
    base_topo = gpu_count_topology(base)
    base_comm = interconnect.exposed_comm_s(rounds, base_topo, strategy,
                                            bucket_bytes, anchor_step_s)
    compute_s = max(anchor_step_s - base_comm, anchor_step_s * 0.1)
    out = {}
    for n in counts:
        topo = gpu_count_topology(n)
        comm = interconnect.exposed_comm_s(rounds, topo, strategy,
                                           bucket_bytes, compute_s)
        out[n] = compute_s / (compute_s + comm)
    return out


def cost_frontier(base_epoch_s: float, *, base_gpus: int = 2,
                  efficiencies: Optional[Dict[int, float]] = None,
                  anchor_step_s: Optional[float] = None,
                  strategy: str = "overlap",
                  bucket_bytes: int = interconnect.DEFAULT_BUCKET_BYTES,
                  tpu_epochs: Optional[Dict[str, float]] = None) -> list:
    """Fig. 5: cost/epoch across offerings.

    ``efficiencies`` defaults to :func:`efficiency_table` derived from
    ``anchor_step_s`` (the measured base step; defaults to the implied
    per-step time of the epoch anchor itself) — the planner path never
    falls back to a hard-coded table.  ``tpu_epochs`` maps e.g.
    ``"v3-8" -> 480.0`` measured anchors; a ``"v3-32"`` entry of None is
    PREDICTED from the v3-8 anchor through the ICI model.
    """
    if efficiencies is None:
        if anchor_step_s is None:
            # implied measured step at the paper's per-GPU batch
            steps_per_epoch = EPOCH_SAMPLES / (GPU_ANCHOR_BS * base_gpus)
            anchor_step_s = base_epoch_s / steps_per_epoch
        efficiencies = efficiency_table(anchor_step_s, base=base_gpus,
                                        strategy=strategy,
                                        bucket_bytes=bucket_bytes)
    rows = []
    for pre in (False, True):
        for ec in cost_lib.scaling_cost_table(base_epoch_s,
                                              base_gpus=base_gpus,
                                              efficiencies=efficiencies,
                                              preemptible=pre):
            rows.append({"device": ec.device, "n": ec.n_devices,
                         "epoch_s": ec.epoch_time_s, "cost_usd": ec.cost,
                         "efficiency": efficiencies[ec.n_devices],
                         "eff_source": "planner"})
    for name, epoch_s in (tpu_epochs or {}).items():
        version, cores = name.split("-")
        cores = int(cores)
        if epoch_s is None:        # predict from the 8-core anchor
            anchor8 = (tpu_epochs or {}).get(f"{version}-8")
            if anchor8 is None:
                continue
            topo = tpu_topology(version, cores)
            step8 = anchor8 / (EPOCH_SAMPLES / (TPU_ANCHOR_BS * 8))
            rounds = gan_rounds("full")
            comm = interconnect.exposed_comm_s(rounds, topo, strategy,
                                               bucket_bytes, step8)
            eff = step8 / (step8 + comm)
            epoch_s = anchor8 * 8 / (cores * eff)
        for pre in (False, True):
            try:
                ec = cost_lib.tpu_epoch_cost(version, cores, epoch_s,
                                             preemptible=pre)
            except KeyError:
                continue
            rows.append({"device": ec.device, "n": ec.n_devices,
                         "epoch_s": ec.epoch_time_s, "cost_usd": ec.cost,
                         "efficiency": None, "eff_source": "tpu_anchor"})
    return rows


def load_elastic(results_dir: str) -> Optional[dict]:
    """Measured elastic overhead from ``results/BENCH_elastic.json``.

    Returns ``{"overhead_frac", "recovery_s", "lost_steps", "source"}``
    (or None when the benchmark has not been recorded).  The overhead
    fraction is the measured faulted-vs-clean wall-time ratio minus one —
    what riding through the trace's preemptions actually cost, recovery
    time and redone steps included (`tools/run_elastic.py` records it).
    """
    path = os.path.join(results_dir, "BENCH_elastic.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    rows = payload.get("rows", {})
    return {"overhead_frac": float(rows.get("overhead_frac", 0.0)),
            "recovery_s": float(rows.get("recovery_s", 0.0)),
            "lost_steps": int(rows.get("lost_steps", 0)),
            "source": path}


def apply_elastic_overhead(rows: Iterable[dict],
                           overhead_frac: float) -> list:
    """Derate the PREEMPTIBLE rows of a cost frontier by the measured
    elastic overhead: epoch time and cost both scale by ``1 + overhead``
    (recoveries burn wall clock AND billed instance-hours).  Reserved
    rows pass through untouched — preemptions don't happen there.  Feed
    the result to :func:`recommend` for a preemption-honest answer:
    spot capacity stays the paper's >3x win while the measured overhead
    is small, and the planner flips to reserved when recovery costs eat
    the discount.
    """
    if overhead_frac < 0:
        raise ValueError(f"overhead_frac must be >= 0, got {overhead_frac}")
    out = []
    for r in rows:
        if str(r.get("device", "")).endswith("-pre"):
            r = dict(r, epoch_s=r["epoch_s"] * (1 + overhead_frac),
                     cost_usd=r["cost_usd"] * (1 + overhead_frac),
                     elastic_overhead=overhead_frac)
        out.append(r)
    return out


def recommend(rows: Iterable[dict], budget_usd: float, deadline_s: float,
              epochs: int = 1) -> Optional[dict]:
    """Cheapest offering that trains ``epochs`` epochs within both the
    budget and the deadline; ties break toward the faster one.  Returns
    the chosen row (with totals filled in) or None when infeasible."""
    feasible = []
    for r in rows:
        total_cost = r["cost_usd"] * epochs
        total_time = r["epoch_s"] * epochs
        if total_cost <= budget_usd and total_time <= deadline_s:
            feasible.append(dict(r, total_cost_usd=total_cost,
                                 total_time_s=total_time))
    if not feasible:
        return None
    return min(feasible, key=lambda r: (r["total_cost_usd"],
                                        r["total_time_s"]))
