"""Cloud scale-out planning (paper Fig. 2 + Fig. 5).

Three layers: ``costs`` (GCP price table + cost-per-epoch arithmetic),
``interconnect`` (analytic all-reduce time per `launch.mesh.Topology`,
flat vs. hierarchical), and ``planner`` (replays measured step-time
baselines from ``results/`` through both to emit weak-scaling curves,
the cost frontier, and ``recommend(budget, deadline)`` answers).
CLI: ``tools/plan_scaleout.py``.
"""
from repro.cloud.costs import (EpochCost, PAPER_EFFICIENCIES, PRICES,
                               gpu_epoch_cost, scaling_cost_table,
                               tpu_epoch_cost)

__all__ = ["EpochCost", "PAPER_EFFICIENCIES", "PRICES", "gpu_epoch_cost",
           "scaling_cost_table", "tpu_epoch_cost"]
