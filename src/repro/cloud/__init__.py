"""Cloud cost modelling (paper Fig. 5): TPU vs GPU price per epoch."""
from repro.cloud.costs import EpochCost, PRICES, gpu_epoch_cost, scaling_cost_table, tpu_epoch_cost

__all__ = ["EpochCost", "PRICES", "gpu_epoch_cost", "scaling_cost_table", "tpu_epoch_cost"]
