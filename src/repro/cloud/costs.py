"""Cloud cost model (paper §5.1.2, Fig. 5-right).

Reproduces the paper's cost-per-epoch analysis: GCP europe-west4 hourly
prices (2020/2021 era, as in the paper) for V100 GPUs (reserved vs.
preemptible) and TPU v2/v3 slices, plus the v5e pricing used for the
roofline target.  The paper's headline numbers this model reproduces:

- cost/epoch stays ~flat as GPUs scale 2 -> 128 while epoch time drops
  ~linearly (Fig. 5);
- preemptible V100s are >3x cheaper than reserved;
- preemptible TPU v3-8 is ~2.4x cheaper than the GPU-equivalent epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# $/hour, GCP europe-west4 (paper-era list prices)
PRICES = {
    "v100_reserved": 2.55,          # per GPU
    "v100_preemptible": 0.77,       # per GPU (>3x cheaper, paper §5.1)
    "n1_vm_per_8gpu": 1.52,         # VM share per 8-GPU node (<5% of total)
    "tpu_v2_8_preemptible": 1.35,   # per 8-core slice
    "tpu_v3_8_preemptible": 2.40,
    "tpu_v2_8_reserved": 4.50,
    "tpu_v3_8_reserved": 8.00,
    "tpu_v3_32_reserved": 32.00,
    "tpu_v5e_reserved": 1.20,       # per chip (roofline target hardware)
}


@dataclasses.dataclass(frozen=True)
class EpochCost:
    device: str
    n_devices: int
    epoch_time_s: float
    price_per_hour: float

    @property
    def cost(self) -> float:
        return self.price_per_hour * self.epoch_time_s / 3600.0


def gpu_epoch_cost(n_gpus: int, epoch_time_s: float,
                   preemptible: bool = True) -> EpochCost:
    gpu = PRICES["v100_preemptible" if preemptible else "v100_reserved"]
    vms = -(-n_gpus // 8) * PRICES["n1_vm_per_8gpu"]
    return EpochCost("V100" + ("-pre" if preemptible else ""), n_gpus,
                     epoch_time_s, n_gpus * gpu + vms)


def tpu_epoch_cost(version: str, cores: int, epoch_time_s: float,
                   preemptible: bool = True) -> EpochCost:
    kind = "preemptible" if preemptible else "reserved"
    key = f"tpu_{version}_8_{kind}"
    if f"tpu_{version}_{cores}_{kind}" in PRICES:
        hourly = PRICES[f"tpu_{version}_{cores}_{kind}"]
    else:
        hourly = PRICES[key] * cores / 8          # linear slice pricing
    return EpochCost(f"TPU-{version}-{cores}" + ("-pre" if preemptible else ""),
                     cores, epoch_time_s, hourly)


# the paper's REPORTED Fig. 5 efficiencies — a literature fallback only.
# The planner path (cloud/planner.cost_frontier) always injects
# efficiencies DERIVED from measured step times + the interconnect model.
PAPER_EFFICIENCIES: Dict[int, float] = {
    2: 1.0, 4: 0.99, 8: 0.97, 16: 0.95, 32: 0.93, 64: 0.90, 128: 0.81}


def scaling_cost_table(base_epoch_s: float, base_gpus: int = 2,
                       efficiencies: Optional[Dict[int, float]] = None,
                       preemptible: bool = True):
    """Fig. 5: epoch time + cost across GPU counts.

    ``efficiencies``: parallel efficiency per GPU count (1.0 = perfectly
    linear).  Inject measured/derived values here (the planner does);
    ``None`` falls back to the paper's published ``PAPER_EFFICIENCIES``."""
    eff = efficiencies if efficiencies is not None else PAPER_EFFICIENCIES
    rows = []
    for n, e in sorted(eff.items()):
        t = base_epoch_s * base_gpus / (n * e)
        rows.append(gpu_epoch_cost(n, t, preemptible))
    return rows
