"""Synthetic token data source for the LM architectures.

A first-order Markov chain over the vocabulary with a learnable structure
(low-entropy transitions) so short training runs show decreasing loss —
giving the integration tests a real signal, not noise.
"""
from __future__ import annotations

import numpy as np


class MarkovTokens:
    def __init__(self, vocab: int, seed: int = 0, branching: int = 8):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.branching = branching
        # each token deterministically prefers `branching` successors
        self._succ = self.rng.integers(0, vocab, size=(min(vocab, 4096),
                                                       branching))

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len), np.int32)
        cur = self.rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len):
            out[:, t] = cur
            idx = cur % self._succ.shape[0]
            pick = self.rng.integers(0, self.branching, size=batch)
            nxt = self._succ[idx, pick]
            noise = self.rng.random(batch) < 0.1
            cur = np.where(noise, self.rng.integers(0, self.vocab, batch), nxt)
        return out

    def batches(self, batch: int, seq_len: int):
        while True:
            yield {"tokens": self.sample(batch, seq_len)}
