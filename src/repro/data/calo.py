"""Synthetic electromagnetic-calorimeter Monte Carlo (the training data).

Stands in for the Geant4-produced CLIC calorimeter dataset used by 3DGAN:
3-D energy-deposit images of shape (X, Y, Z) conditioned on the primary
particle energy E_p and incidence angle theta.

The generator follows standard EM-shower parameterisations:

- longitudinal profile: gamma distribution  dE/dz ~ z^(a-1) exp(-b z)
  with a,b mildly energy-dependent (shower max grows with log E);
- transverse profile: two-gaussian core+halo around the shower axis, which
  is tilted in the x-z plane by theta (the paper's angle conditioning);
- per-cell multiplicative fluctuation + sampling noise.

This is a physics-shaped simulator, not Geant4 — but it reproduces the
qualitative features the paper validates against (fig. 3/7): longitudinal
shape, transverse core/edges across orders of magnitude, ECAL/E_p response.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CaloSpec:
    image_shape: tuple = (51, 51, 25)
    e_min: float = 10.0        # GeV
    e_max: float = 500.0
    theta_min: float = np.deg2rad(60.0)
    theta_max: float = np.deg2rad(120.0)
    moliere_core: float = 1.1  # cells
    moliere_halo: float = 3.5
    halo_frac: float = 0.18
    sampling_frac: float = 0.025   # ECAL measures ~2.5% of E_p


class CaloSimulator:
    def __init__(self, spec: CaloSpec = CaloSpec(), seed: int = 0):
        self.spec = spec
        self.rng = np.random.default_rng(seed)

    def sample_labels(self, n: int):
        s = self.spec
        e_p = self.rng.uniform(s.e_min, s.e_max, n).astype(np.float32)
        theta = self.rng.uniform(s.theta_min, s.theta_max, n).astype(np.float32)
        return e_p, theta

    def generate(self, n: int):
        """Returns images (n, X, Y, Z), e_p (n,), theta (n,), ecal (n,)."""
        s = self.spec
        X, Y, Z = s.image_shape
        e_p, theta = self.sample_labels(n)

        z = np.arange(Z, dtype=np.float32) + 0.5
        x = np.arange(X, dtype=np.float32) + 0.5
        y = np.arange(Y, dtype=np.float32) + 0.5

        # longitudinal gamma profile, shower max ~ log(E)
        a = 2.0 + 0.6 * np.log(e_p / 10.0)[:, None]          # (n, 1)
        b = (a - 1.0) / (0.45 * Z * (1.0 + 0.08 * np.log(e_p / 100.0)[:, None]))
        long_prof = np.power(z[None], a - 1.0) * np.exp(-b * z[None])
        long_prof /= long_prof.sum(axis=1, keepdims=True)    # (n, Z)

        # shower axis tilted in x-z by theta (90 deg = perpendicular)
        x0, y0 = X / 2.0, Y / 2.0
        slope = np.tan(theta - np.pi / 2.0)[:, None]         # (n, 1)
        cx = x0 + slope * (z[None] - Z / 2.0)                # (n, Z)

        dx2 = (x[None, :, None] - cx[:, None, :]) ** 2       # (n, X, Z)
        dy2 = ((y - y0) ** 2)[None, :, None]                 # (1, Y, 1)

        def gauss(d2, sig):
            return np.exp(-d2 / (2 * sig * sig)) / (np.sqrt(2 * np.pi) * sig)

        tx = (1 - s.halo_frac) * gauss(dx2, s.moliere_core) \
            + s.halo_frac * gauss(dx2, s.moliere_halo)       # (n, X, Z)
        ty = (1 - s.halo_frac) * gauss(dy2, s.moliere_core) \
            + s.halo_frac * gauss(dy2, s.moliere_halo)       # (1, Y, 1)

        img = (e_p * s.sampling_frac)[:, None, None, None] \
            * long_prof[:, None, None, :] * tx[:, :, None, :] * ty[None]
        # per-cell fluctuations + sampling noise
        img *= self.rng.gamma(20.0, 1 / 20.0, size=img.shape)
        img += self.rng.normal(0.0, 2e-5, size=img.shape)
        img = np.clip(img, 0.0, None).astype(np.float32)
        ecal = img.sum(axis=(1, 2, 3)).astype(np.float32)
        return img, e_p, theta, ecal

    def batches(self, batch: int, skip: int = 0):
        """Endless batch stream; ``skip`` discards the first N batches.

        The elastic trainer's replay contract: a simulator seeded once
        and asked for ``batches(b, skip=s)`` yields EXACTLY the batches
        a fresh ``batches(b)`` would yield from step ``s`` on (the
        generate-and-discard keeps this instance's RNG stream aligned),
        so a resumed run sees the same data the uninterrupted run saw.
        """
        for _ in range(skip):
            self.generate(batch)
        while True:
            img, e_p, theta, ecal = self.generate(batch)
            yield {"image": img[..., None],      # (B, X, Y, Z, 1) NDHWC
                   "e_p": e_p, "theta": theta, "ecal": ecal}

    def write_shards(self, store, n_shards: int, shard_size: int):
        """Convert to the native record format (paper: HDF5 -> TF Records)."""
        for i in range(n_shards):
            img, e_p, theta, ecal = self.generate(shard_size)
            store.write(f"calo_{i:05d}", {
                "image": img[..., None], "e_p": e_p,
                "theta": theta, "ecal": ecal})
