"""Host-side data pipeline with device prefetch (paper §3).

The paper's final optimisation converts HDF5 to a native record format and
overlaps host batching/shuffling with accelerator compute.  The JAX-native
equivalent implemented here:

- `ShardStore`: fixed-size memmapped .npy shards on disk (the "TF Records"
  analogue — sequential reads, no per-item deserialisation),
- `prefetch` / `Prefetcher`: a double-buffered device prefetcher.  The
  PRODUCER thread issues `jax.device_put` (against the target sharding
  when given) for batch N+1 while the consumer's dispatched step N runs,
  so the host->device transfer rides under compute — and because
  `device_put` is asynchronous, the producer immediately returns to
  pulling batch N+2 from the host iterator.  The consumer only ever pops
  finished device arrays off a bounded queue; the time it spends BLOCKED
  on that queue is exactly the transfer/host time the overlap failed to
  hide, surfaced as ``Prefetcher.stats["h2d_wait_ms"]`` (the engine
  re-exposes it per logging window in ``Engine.last_fit_stats``).
"""
from __future__ import annotations

import os
import queue
import threading
import time
from typing import Iterator, Optional

import jax
import numpy as np


class ShardStore:
    """Directory of memmapped fixed-shape .npy shards."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def write(self, name: str, arrays: dict):
        np.savez(os.path.join(self.root, f"{name}.npz"), **arrays)

    def shard_names(self):
        return sorted(f[:-4] for f in os.listdir(self.root)
                      if f.endswith(".npz"))

    def read(self, name: str) -> dict:
        with np.load(os.path.join(self.root, f"{name}.npz")) as z:
            return {k: z[k] for k in z.files}

    def iter_epoch(self, batch: int, shuffle_seed: Optional[int] = None):
        """Yield batches covering every record exactly once per epoch."""
        names = self.shard_names()
        rng = np.random.default_rng(shuffle_seed)
        if shuffle_seed is not None:
            names = list(rng.permutation(names))
        for name in names:
            data = self.read(name)
            n = len(next(iter(data.values())))
            order = rng.permutation(n) if shuffle_seed is not None else np.arange(n)
            for i in range(0, n - batch + 1, batch):
                idx = order[i:i + batch]
                yield {k: v[idx] for k, v in data.items()}


class Prefetcher:
    """Double-buffered device prefetch: producer-side ``device_put``.

    The producer thread pulls host batches, places them on device
    (sharded when ``sharding`` is given) and parks the resulting device
    arrays in a queue bounded at ``size`` — with ``size=2`` that is
    classic double buffering: transfer of batch N+1 overlaps the step
    consuming batch N.  Iterating yields batches in input order.

    ``stats`` (host-side, cheap):

    - ``h2d_wait_ms``  — total time the CONSUMER blocked waiting for a
      batch, i.e. transfer/host time compute did not hide (0 when the
      pipeline keeps up);
    - ``put_ms``       — producer time spent issuing ``device_put``
      dispatches (not the transfer itself, which is async);
    - ``batches``      — batches yielded so far.

    Exceptions in the source iterator are re-raised to the consumer.
    """

    _DONE = object()

    def __init__(self, it: Iterator[dict], size: int = 2, sharding=None):
        self._q: queue.Queue = queue.Queue(maxsize=max(int(size), 1))
        self._sharding = sharding
        self.stats = {"h2d_wait_ms": 0.0, "put_ms": 0.0, "batches": 0}
        self._thread = threading.Thread(
            target=self._produce, args=(it,), daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is not None:
            return jax.tree.map(
                lambda x, s: jax.device_put(x, s), batch, self._sharding)
        return jax.tree.map(jax.device_put, batch)

    def _produce(self, it):
        try:
            for batch in it:
                t0 = time.perf_counter()
                placed = self._place(batch)
                self.stats["put_ms"] += 1e3 * (time.perf_counter() - t0)
                self._q.put(placed)
        except BaseException as e:        # surface in the consumer
            self._q.put((self._DONE, e))
            return
        self._q.put((self._DONE, None))

    def __iter__(self):
        return self

    def __next__(self):
        t0 = time.perf_counter()
        item = self._q.get()
        self.stats["h2d_wait_ms"] += 1e3 * (time.perf_counter() - t0)
        if isinstance(item, tuple) and len(item) == 2 \
                and item[0] is self._DONE:
            self._q.put(item)             # keep raising on repeat next()
            if item[1] is not None:
                raise item[1]
            raise StopIteration
        self.stats["batches"] += 1
        return item


def prefetch(it: Iterator[dict], size: int = 2,
             sharding=None) -> Prefetcher:
    """Double-buffered host->device prefetch on a background thread."""
    return Prefetcher(it, size=size, sharding=sharding)
