"""Host-side data pipeline with device prefetch (paper §3).

The paper's final optimisation converts HDF5 to a native record format and
overlaps host batching/shuffling with accelerator compute.  The JAX-native
equivalent implemented here:

- `ShardStore`: fixed-size memmapped .npy shards on disk (the "TF Records"
  analogue — sequential reads, no per-item deserialisation),
- `prefetch`: a double-buffered iterator that moves the NEXT batch to device
  (`jax.device_put`, optionally with a NamedSharding) while the CURRENT step
  is running — host prep and accelerator compute overlap exactly as in the
  paper's custom loop.
"""
from __future__ import annotations

import collections
import os
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class ShardStore:
    """Directory of memmapped fixed-shape .npy shards."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def write(self, name: str, arrays: dict):
        np.savez(os.path.join(self.root, f"{name}.npz"), **arrays)

    def shard_names(self):
        return sorted(f[:-4] for f in os.listdir(self.root)
                      if f.endswith(".npz"))

    def read(self, name: str) -> dict:
        with np.load(os.path.join(self.root, f"{name}.npz")) as z:
            return {k: z[k] for k in z.files}

    def iter_epoch(self, batch: int, shuffle_seed: Optional[int] = None):
        """Yield batches covering every record exactly once per epoch."""
        names = self.shard_names()
        rng = np.random.default_rng(shuffle_seed)
        if shuffle_seed is not None:
            names = list(rng.permutation(names))
        for name in names:
            data = self.read(name)
            n = len(next(iter(data.values())))
            order = rng.permutation(n) if shuffle_seed is not None else np.arange(n)
            for i in range(0, n - batch + 1, batch):
                idx = order[i:i + batch]
                yield {k: v[idx] for k, v in data.items()}


def prefetch(it: Iterator[dict], size: int = 2, sharding=None) -> Iterator[dict]:
    """Double-buffered host->device prefetch on a background thread."""
    q: collections.deque = collections.deque()
    sem = threading.Semaphore(size)
    done = object()

    def put(batch):
        if sharding is not None:
            return jax.tree.map(
                lambda x, s: jax.device_put(x, s), batch, sharding)
        return jax.tree.map(jax.device_put, batch)

    def producer():
        for batch in it:
            sem.acquire()
            q.append(put(batch))
        q.append(done)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        while not q:
            t.join(0.001)
            if not t.is_alive() and not q:
                return
        item = q.popleft()
        if item is done:
            return
        sem.release()
        yield item
