"""Pure-JAX optimizers (no optax): SGD, Adam, AdamW, RMSprop.

Each optimizer is a pair of pure functions packaged in an `Optimizer`
namedtuple:  ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``.
Updates are ADDED to params (they already contain the negative sign).

The paper's 3DGAN trains with RMSprop (the classic GAN choice); the LM
architectures default to AdamW.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


ScheduleOrFloat = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: ScheduleOrFloat, step):
    return lr(step) if callable(lr) else lr


def _zeros_like_float(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


# ---------------------------------------------------------------------------


def sgd(lr: ScheduleOrFloat, momentum: float = 0.0):
    def init(params):
        mu = _zeros_like_float(params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lrt = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            upd = jax.tree.map(lambda m: -lrt * m, mu)
            return upd, {"step": step, "mu": mu}
        return jax.tree.map(lambda g: -lrt * g, grads), {"step": step, "mu": None}

    return Optimizer(init, update)


def adam(lr: ScheduleOrFloat, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _zeros_like_float(params), "v": _zeros_like_float(params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lrt = _lr_at(lr, step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m_, v_, p):
            upd = -lrt * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd - lrt * weight_decay * p.astype(jnp.float32)
            return upd.astype(p.dtype)

        upds = (jax.tree.map(u, m, v, params) if params is not None else
                jax.tree.map(lambda m_, v_: u(m_, v_, m_), m, v))
        return upds, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr: ScheduleOrFloat, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    return adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def rmsprop(lr: ScheduleOrFloat, decay=0.9, eps=1e-8, momentum=0.0):
    """RMSprop — the 3DGAN training optimizer (keras-compatible math)."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "nu": _zeros_like_float(params),
                "mu": _zeros_like_float(params) if momentum else None}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lrt = _lr_at(lr, step)
        nu = jax.tree.map(
            lambda n, g: decay * n + (1 - decay) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        scaled = jax.tree.map(
            lambda g, n: g.astype(jnp.float32) / (jnp.sqrt(n) + eps), grads, nu)
        if momentum:
            mu = jax.tree.map(lambda m, s: momentum * m + s, state["mu"], scaled)
            upd = jax.tree.map(lambda m: -lrt * m, mu)
            return upd, {"step": step, "nu": nu, "mu": mu}
        upd = jax.tree.map(lambda s: -lrt * s, scaled)
        return upd, {"step": step, "nu": nu, "mu": None}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer state + f32 master params sharded across data replicas
# ---------------------------------------------------------------------------


def _zero1_to2d(tree, num_shards: int):
    """Flatten a pytree to one f32 vector, zero-pad to a multiple of
    ``num_shards``, reshape to the shard-major ``(N, L)`` layout (row i =
    shard i).  Padding entries are ZERO and stay zero forever — zero
    grads make every element-wise moment update a no-op — which is the
    invariant that lets checkpoints reshard across device counts by
    truncating/extending the flat vector (checkpoint.zero1_reshard)."""
    flat = [jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(tree)]
    vec = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
    cap = -(-vec.size // num_shards)          # ceil(total / N)
    pad = num_shards * cap - vec.size
    if pad:
        vec = jnp.concatenate([vec, jnp.zeros((pad,), jnp.float32)])
    return vec.reshape(num_shards, cap)


def _zero1_from_flat(vec, template):
    """Slice the leading ``sum(sizes)`` entries of ``vec`` back into the
    shapes/treedef of ``template`` (padding tail never read)."""
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for l in leaves:
        out.append(jax.lax.slice(vec, (off,), (off + l.size,))
                   .reshape(l.shape))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def zero1(inner: Optimizer, num_shards: int, axis=None) -> Optimizer:
    """ZeRO stage-1 wrapper: partition ``inner``'s state + an f32 master
    copy of the params across ``num_shards`` data replicas.

    State layout: ``{"zero1": {"inner": <inner state over (N, L)>,
    "master": (N, L) f32}}`` — the whole param tree flattened, zero-padded
    and reshaped shard-major, so shard i's slice is row i.  The engine
    recognizes the ``zero1`` subtree and shards every ``(N, L)`` leaf
    over its data axes (`Engine.state_pspecs`), which is where the
    ~1/N per-device state-memory saving comes from
    (`parallel.jaxpr_cost.per_device_state_bytes` reports it).

    ``axis=None`` (builtin/jit loop, or tests without a mesh): the update
    runs on the full ``(N, L)`` arrays — GSPMD partitions the
    element-wise math along the sharded leading dim and inserts the
    params all-gather itself.  ``axis`` set to the mesh data axis name(s)
    (custom/shard_map loop): each replica holds its ``(1, L)`` state row
    locally, slices its row of the (already reduced) gradients — the
    reduce + slice pair is the reduce-scatter of the classic ZeRO
    schedule — updates it with ``inner``, and ``all_gather``s the updated
    master rows back to full params.

    Because every wrapped optimizer here is element-wise, the sharded
    update is numerically identical to the replicated one; only the f32
    flatten/concat round-trip separates ``zero1(opt)`` from ``opt``
    (pinned in tests/test_scaleout.py).  Updates are returned as
    ``new_master - params`` so ``apply_updates`` lands params exactly on
    the master values.
    """
    N = int(num_shards)
    if N < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    ax_names = ((axis,) if isinstance(axis, str) else tuple(axis)) \
        if axis is not None else ()

    def init(params):
        m2d = _zero1_to2d(params, N)
        return {"zero1": {"inner": inner.init(m2d), "master": m2d}}

    def update(grads, state, params=None):
        z = state["zero1"]
        g2d = _zero1_to2d(grads, N)
        if not ax_names:
            upd2d, new_inner = inner.update(g2d, z["inner"], z["master"])
            new_master = z["master"] + upd2d
            gathered = new_master
        else:
            # sharded mode: state rows are LOCAL (1, L) under shard_map;
            # grads are replicated post-reduce, so slice our own row
            idx = jnp.int32(0)
            for a in ax_names:
                idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
            g_loc = jax.lax.dynamic_slice_in_dim(g2d, idx, 1, 0)
            upd_loc, new_inner = inner.update(g_loc, z["inner"], z["master"])
            new_master = z["master"] + upd_loc
            gathered = jax.lax.all_gather(new_master, ax_names, axis=0,
                                          tiled=True)
        new_params = _zero1_from_flat(gathered.reshape(-1), params)
        upd = jax.tree.map(lambda q, p: q - p.astype(jnp.float32),
                           new_params, params)
        return upd, {"zero1": {"inner": new_inner, "master": new_master}}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Gradient transforms
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), grads), g


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return schedule


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def get_optimizer(name: str, lr: ScheduleOrFloat, **kw) -> Optimizer:
    return {"sgd": sgd, "adam": adam, "adamw": adamw, "rmsprop": rmsprop}[name](lr, **kw)
