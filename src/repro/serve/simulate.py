"""Fast-simulation serving engine: batched, sharded 3DGAN event generation.

The paper trains the 3DGAN so it can REPLACE Monte Carlo in production —
this module is that deployment surface.  Requests ask for showers
(``primary_energy``, ``n_events``, ``seed``); the engine turns them into
accelerator work the same way the training side does:

- **fixed batch buckets** — event work from the head of the host-side
  queue is packed into the smallest bucket that fits (padded + masked),
  so the whole service runs on a handful of AOT-compiled programs, one
  per bucket, instead of recompiling per request shape;
- **data-parallel sharding** — with a mesh, every bucket batch is sharded
  over the data axes exactly like a training batch
  (`parallel/sharding.batch_axes`), params stay replicated, and the
  generator runs through the same `core/gan.py` path (including the
  Pallas fused conv3d kernels when `gan.pallas_conv_enabled(cfg)`);
- **on-device results** — generated shower tensors stay on the
  accelerator until a request's LAST event is generated; the drain is
  one device->host transfer per request (`SimulateEngine._finalize`);
- **deterministic per-event RNG** — event ``i`` of a request is generated
  from ``fold_in(fold_in(key(0), request.seed), i)``, so a request's
  showers are bit-identical no matter which bucket they were packed into
  or which other requests shared the batch;
- **rolling physics gate** — every step's masked profile sums
  (`core/validation.profile_sums`) accumulate on device; once per
  ``window`` events the gate drains ONE small pytree and reports the
  paper's Fig. 3/7 divergences against a fixed MC reference
  (:class:`PhysicsGate`), so generator drift in production is detected
  with the same numbers that validate training fidelity;
- **resilient scheduling** — request ordering, per-request deadlines
  and priorities, admission control and load shedding all live in
  `serve/scheduler.Scheduler` (the default config reproduces the old
  FIFO drain bit-for-bit).  A request that cannot be served — deadline
  expired, queue bound exceeded, degraded mode, no healthy replica —
  is REJECTED with a structured error (``req.status == "rejected"``,
  ``req.error``), never silently dropped and never left to hang;
- **replica failover** — with a `serve/replicas.ReplicaGroup`, bucket
  steps round-robin over health-checked generator replicas and a
  killed or stalled replica's step re-dispatches onto a survivor
  (retry with exponential backoff, hedging).  Because per-event
  ``fold_in`` RNG makes each step a pure function of its inputs, a
  request that survives a replica failure returns showers
  bit-identical to a fault-free run;
- **graceful degradation** — under a PhysicsGate ``drifted()`` alarm
  (``max_kl``) or a total replica outage the engine sheds
  lowest-priority work first and surfaces a structured
  :meth:`SimulateEngine.degraded_report` instead of silently queueing.

Typical use::

    from repro.configs import calo3dgan
    from repro.core import validation
    from repro.data.calo import CaloSimulator, CaloSpec
    from repro.serve.simulate import PhysicsGate, SimRequest, SimulateEngine

    cfg = calo3dgan.reduced()
    mc = next(CaloSimulator(CaloSpec(cfg.image_shape)).batches(512))
    gate = PhysicsGate(validation.reference_profiles(mc["image"], mc["e_p"]))
    eng = SimulateEngine(cfg, g_params, buckets=(8, 32, 128), gate=gate)
    eng.submit(SimRequest(rid=0, primary_energy=250.0, n_events=100, seed=7))
    (req,) = eng.run()
    req.images            # (100, X, Y, Z, 1) — exactly n_events
    gate.latest()         # {'longitudinal_kl': ..., 'response_rel_err': ...}
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import gan, validation
from repro.parallel import sharding
from repro.serve.replicas import NoHealthyReplicas, ReplicaGroup
from repro.serve.scheduler import Rejection, Scheduler, SchedulerConfig
from repro.substrate.precision import get_policy


@dataclasses.dataclass
class SimRequest:
    """One event-generation request: n_events showers at one beam setting.

    ``priority`` (higher wins; lowest sheds first under overload or
    degradation) and ``deadline_s`` (a relative latency SLA, measured
    from submit) feed the scheduler; both default to the legacy
    "no-SLA, single-class" behavior.  A request that cannot be served
    ends ``status == "rejected"`` with the structured ``error`` dict
    (`serve/scheduler.Rejection`) instead of hanging.
    """
    rid: int
    primary_energy: float          # E_p in GeV (conditioning label)
    n_events: int
    seed: int = 0
    theta: float = float(np.pi / 2)   # incidence angle (rad); 90 deg = normal
    priority: int = 0
    deadline_s: Optional[float] = None
    # filled by the engine:
    images: Optional[np.ndarray] = None   # (n_events, X, Y, Z, 1)
    latency_s: float = 0.0
    done: bool = False
    status: str = "queued"         # "queued" | "done" | "rejected"
    error: Optional[dict] = None


@dataclasses.dataclass
class _Cursor:
    """Engine-internal progress through one request's event range."""
    req: SimRequest
    t0: float
    next_ev: int = 0
    chunks: List[jax.Array] = dataclasses.field(default_factory=list)
    deadline_t: Optional[float] = None   # absolute, engine-clock time


class PhysicsGate:
    """Rolling on-device physics validation for a serving deployment.

    ``update`` folds one step's masked profile sums into device-side
    running sums (an async dispatch — no host sync); window accounting
    uses the HOST-side real-event count, so deciding when to drain never
    blocks on the device.  Every ``window`` generated events the gate
    drains once and appends a report with the training-time divergences
    (`core/validation.gate_report`) against the fixed MC ``reference``
    (`core/validation.reference_profiles`).
    """

    def __init__(self, reference: dict, window: int = 512):
        self.reference = reference
        self.window = int(window)
        self.reports: List[dict] = []
        self._sums: Optional[dict] = None
        self._pending = 0

    def update(self, sums: dict, n_real: int) -> None:
        self._pending += int(n_real)
        if self._sums is None:
            self._sums = dict(sums)
        else:
            self._sums = {k: jnp.add(self._sums[k], sums[k])
                          for k in self._sums}
        if self._pending >= self.window:
            self.flush()

    def flush(self) -> Optional[dict]:
        """Drain the current (possibly partial) window: ONE device->host
        transfer, one appended report.  No-op when nothing accumulated."""
        if not self._pending:
            return None
        host = jax.device_get(self._sums)
        rep = validation.gate_report(host, self.reference)
        self.reports.append(rep)
        self._sums, self._pending = None, 0
        return rep

    def latest(self) -> Optional[dict]:
        return self.reports[-1] if self.reports else None

    def drifted(self, max_kl: float) -> bool:
        """True when the latest window's worst profile KL exceeds the
        budget — the deploy-time analogue of the paper's >64-GPU check."""
        rep = self.latest()
        if rep is None:
            return False
        worst = max(rep["longitudinal_kl"], rep["transverse_x_kl"],
                    rep["transverse_y_kl"])
        return worst > max_kl


class SimulateEngine:
    """Micro-batching 3DGAN event-generation service over bucketed steps.

    Parameters
    ----------
    cfg
        A `configs/calo3dgan.GANConfig` (the generator architecture; its
        ``use_pallas_conv`` field picks the kernel route as in training).
    g_params
        Trained generator params (e.g. restored via
        `train/checkpoint.restore_gan_generator`).
    buckets
        Ascending fixed batch sizes.  Each gets exactly ONE compiled
        program (``compile_count`` tracks this); work is padded to the
        smallest bucket that fits the queue's remaining events.
    mesh
        Optional device mesh — bucket batches are sharded over its data
        axes (`sharding.batch_axes`), params replicated, exactly the
        training engine's pure-DP placement.  Every bucket must divide
        by the number of data shards.
    policy_name
        Precision policy (`substrate/precision.get_policy`): noise and the
        conv stacks run in ``compute_dtype``, returned images are cast to
        ``output_dtype``.
    gate
        Optional :class:`PhysicsGate`; fed once per step, drains itself
        once per window.
    sched
        Optional `serve/scheduler.SchedulerConfig` — deadlines,
        priorities, admission bound, age promotion.  ``None`` keeps the
        legacy FIFO semantics exactly (an unconfigured scheduler).
    replicas
        Optional `serve/replicas.ReplicaGroup`; bucket steps dispatch
        through it (health-checked failover, backoff, hedging) instead
        of the engine's single program cache.
    max_kl
        PhysicsGate drift budget.  When the gate's worst profile KL
        exceeds it the engine enters QUALITY-DEGRADED mode: queued and
        arriving requests below ``sched.degrade_shed_below`` priority
        are shed with reason ``degraded`` and
        :meth:`degraded_report` turns structured.  ``None`` disables.
    clock
        Injected time source for deadlines/latency (default
        ``time.perf_counter``); chaos tests pass a fake clock so
        deadline expiry and shed counts replay deterministically.
    """

    def __init__(self, cfg, g_params, *, buckets: Sequence[int] = (8, 32, 128),
                 mesh=None, policy_name: str = "f32",
                 gate: Optional[PhysicsGate] = None,
                 sched: Optional[SchedulerConfig] = None,
                 replicas: Optional[ReplicaGroup] = None,
                 max_kl: Optional[float] = None,
                 clock=time.perf_counter):
        self.cfg = cfg
        self.policy = get_policy(policy_name)
        self.mesh = mesh
        axes = sharding.batch_axes(mesh) if mesh is not None else None
        self.axes: tuple = tuple(axes) if axes else ()
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        self.buckets = tuple(sorted(int(b) for b in buckets))
        if not self.buckets:
            raise ValueError("need at least one batch bucket")
        for b in self.buckets:
            if b <= 0 or b % self.n_shards:
                raise ValueError(
                    f"bucket {b} must be positive and divisible by the "
                    f"{self.n_shards} data shards")
        if mesh is not None:
            self.params = jax.device_put(g_params, NamedSharding(mesh, P()))
        else:
            self.params = g_params
        self.gate = gate
        self.max_kl = max_kl
        self.clock = clock
        self.replicas = replicas
        self.scheduler = Scheduler(sched or SchedulerConfig(), clock=clock)
        self._compiled: Dict[int, object] = {}
        self.compile_count = 0
        self._finished: List[SimRequest] = []
        self.rejected: List[SimRequest] = []
        self._submitted = 0
        self._degraded: List[dict] = []     # degradation ladder transitions
        self.stats = {"steps": 0, "events_generated": 0, "padded_events": 0,
                      "device_transfers": 0, "events_wasted": 0,
                      "bucket_steps": {b: 0 for b in self.buckets}}

    @classmethod
    def from_checkpoint(cls, path: str, cfg, *, policy_name: Optional[str]
                        = None, **kw) -> "SimulateEngine":
        """Restore a generator checkpoint AND the precision policy it was
        trained under (manifest ``extra["precision"]``; manifests written
        before that field default to f32) — the production handoff that
        keeps serving numerics matched to training numerics.  An explicit
        ``policy_name`` overrides the recorded one.
        """
        from repro.train import checkpoint as ckpt_lib
        params = ckpt_lib.restore_gan_generator(path, cfg)
        resolved = policy_name or ckpt_lib.manifest_precision(path)
        return cls(cfg, params, policy_name=resolved, **kw)

    # -- host API ----------------------------------------------------------

    def warmup(self) -> None:
        """Pre-compile every bucket's program so the first requests don't
        pay compile time (deployments call this before opening traffic)."""
        for b in self.buckets:
            if b not in self._compiled:
                self._compiled[b] = self._compile_bucket(b)

    def submit(self, req: SimRequest) -> None:
        """Admission-controlled enqueue.  A shed arrival (queue bound,
        infeasible/expired deadline, degraded mode) is marked
        ``rejected`` with a structured ``error`` — check ``req.status``
        after submit when the engine runs with an admission policy."""
        if req.n_events <= 0:
            raise ValueError(f"request {req.rid}: n_events must be positive")
        now = self.clock()
        self._submitted += 1
        cur = _Cursor(req, now)
        if req.deadline_s is not None:
            cur.deadline_t = now + float(req.deadline_s)
        if self._degraded and \
                req.priority < self.scheduler.config.degrade_shed_below:
            self._reject(cur, Rejection(
                req.rid, "degraded",
                f"degraded mode ({self._degraded[-1]['reason']}): only "
                f"priority >= {self.scheduler.config.degrade_shed_below} "
                "admitted", t=now, priority=req.priority))
            return
        res = self.scheduler.admit(cur, rid=req.rid, n_events=req.n_events,
                                   priority=req.priority,
                                   deadline=cur.deadline_t)
        for item, rej in res.rejections:
            self._reject(item, rej)

    def run(self, max_steps: int = 100_000) -> List[SimRequest]:
        """Serve until the queue drains (or ``max_steps`` bucket steps);
        returns every request finished so far.

        Each iteration: expire dead deadlines (structured rejections,
        never hangs), check the PhysicsGate drift alarm (degrade +
        shed low priority), plan one bucket step (scheduler order:
        promoted, then priority, then earliest deadline), dispatch it —
        through the replica group when configured — and finalize any
        requests whose last event landed.  A total replica outage
        rejects the remaining queue with reason ``capacity`` instead of
        looping forever.
        """
        for _ in range(max_steps):
            for item, rej in self.scheduler.expire():
                self._reject(item, rej)
            self._check_gate_drift()
            plan = self.scheduler.plan_step(self.buckets)
            if plan is None:
                break
            bucket, assignments = plan
            inputs, spans, n_real = self._pack_plan(bucket, assignments)
            try:
                img, sums = self._dispatch(bucket, inputs)
            except NoHealthyReplicas:
                self._enter_degraded("no_healthy_replicas")
                for item, rej in self.scheduler.drain(
                        "capacity", "no healthy replica left"):
                    self._reject(item, rej)
                break
            self.scheduler.commit(plan)
            if self.gate is not None:
                self.gate.update(sums, n_real)
            self.stats["padded_events"] += bucket - n_real
            for cur, row, take in spans:
                cur.chunks.append(img[row:row + take])
                cur.next_ev += take
                if cur.next_ev == cur.req.n_events:
                    self._finalize(cur)
        return list(self._finished)

    def generate_events(self, primary_energy: float, n_events: int,
                        seed: int = 0) -> np.ndarray:
        """One-shot convenience: serve a single request, return its images."""
        req = SimRequest(rid=self._submitted, primary_energy=primary_energy,
                         n_events=n_events, seed=seed)
        self.submit(req)
        self.run()
        return req.images

    # -- degradation ladder ------------------------------------------------

    def _enter_degraded(self, reason: str) -> None:
        if self._degraded and self._degraded[-1]["reason"] == reason:
            return
        self._degraded.append({"reason": reason, "t": self.clock(),
                               "step": self.stats["steps"]})

    def _check_gate_drift(self) -> None:
        """PhysicsGate alarm -> quality-degraded mode: shed everything
        below the configured priority floor, keep serving the rest."""
        if self.gate is None or self.max_kl is None:
            return
        if not self.gate.drifted(self.max_kl):
            return
        self._enter_degraded("gate_drift")
        floor = self.scheduler.config.degrade_shed_below
        worst = self.gate.latest()
        for item, rej in self.scheduler.shed_below(
                floor, "degraded",
                f"physics gate drifted past max_kl={self.max_kl} "
                f"(longitudinal_kl={worst['longitudinal_kl']:.4f})"):
            self._reject(item, rej)

    def degraded_report(self) -> dict:
        """Structured service-state report — what an operator (or the
        autoscaler) polls instead of grepping logs.  ``mode`` is
        ``healthy`` until a degradation transition is recorded."""
        sched = self.scheduler
        return {
            "mode": self._degraded[-1]["reason"] if self._degraded
            else "healthy",
            "transitions": list(self._degraded),
            "queue": {"requests": sched.queue_depth(),
                      "events": sched.backlog_events()},
            "shed": dict(sched.stats["rejected"]),
            "replicas": (self.replicas.health_report()
                         if self.replicas is not None else None),
            "gate": self.gate.latest() if self.gate is not None else None,
            "drifted": (self.gate.drifted(self.max_kl)
                        if self.gate is not None and self.max_kl is not None
                        else False),
            "served": len(self._finished),
            "rejected": len(self.rejected),
        }

    # -- rejection bookkeeping ---------------------------------------------

    def _reject(self, cur: _Cursor, rej: Rejection) -> None:
        req = cur.req
        req.status = "rejected"
        req.error = rej.to_dict()
        req.done = False
        req.images = None
        self.stats["events_wasted"] += cur.next_ev
        cur.chunks = []
        self.rejected.append(req)

    # -- packing -----------------------------------------------------------

    def _pack_plan(self, bucket: int, assignments):
        """Materialise a scheduler plan into one bucket batch.  Padded
        rows carry a benign mid-range E_p and mask=0 so they never reach
        the gate or a user.  Bucket choice and span order are the
        scheduler's — with the default config that reproduces the old
        FIFO ``_pack`` exactly."""
        seeds = np.zeros((bucket,), np.int32)
        ev_idx = np.zeros((bucket,), np.int32)
        e_p = np.full((bucket,), 100.0, np.float32)
        theta = np.full((bucket,), np.pi / 2, np.float32)
        mask = np.zeros((bucket,), np.float32)
        spans = []
        row = 0
        for entry, take in assignments:
            cur = entry.item
            seeds[row:row + take] = cur.req.seed
            ev_idx[row:row + take] = np.arange(cur.next_ev,
                                               cur.next_ev + take)
            e_p[row:row + take] = cur.req.primary_energy
            theta[row:row + take] = cur.req.theta
            mask[row:row + take] = 1.0
            spans.append((cur, row, take))
            row += take
        return (seeds, ev_idx, e_p, theta, mask), spans, row

    # -- compiled steps ----------------------------------------------------

    def _make_step(self):
        cfg, latent = self.cfg, self.cfg.latent_dim
        compute = self.policy.compute_dtype
        output = self.policy.output_dtype

        def step(params, req_seed, ev_idx, e_p, theta, mask):
            def ev_key(s, i):
                return jax.random.fold_in(
                    jax.random.fold_in(jax.random.key(0), s), i)

            keys = jax.vmap(ev_key)(req_seed, ev_idx)
            noise = jax.vmap(
                lambda k: jax.random.normal(k, (latent,), compute))(keys)
            img = gan.generate(params, noise, e_p, theta, cfg)
            sums = validation.profile_sums(img, e_p, mask)
            return img.astype(output), sums

        return step

    def _bucket_shardings(self):
        """(replicated, batch-sharded-1d, batch-sharded-image) shardings."""
        rep = NamedSharding(self.mesh, P())
        ax = self.axes if len(self.axes) > 1 else self.axes[0]
        vec = NamedSharding(self.mesh, P(ax))
        img = NamedSharding(self.mesh, P(ax, None, None, None, None))
        return rep, vec, img

    def _compile_bucket(self, bucket: int):
        """ONE AOT-compiled program per bucket: lower + compile now, so
        serving never hides a recompile inside a request."""
        step = self._make_step()
        if self.mesh is not None and self.axes:
            rep, vec, img = self._bucket_shardings()
            fn = jax.jit(step,
                         in_shardings=(rep, vec, vec, vec, vec, vec),
                         out_shardings=(img, rep))
        else:
            fn = jax.jit(step)
        sds = jax.ShapeDtypeStruct
        compiled = fn.lower(
            self.params,
            sds((bucket,), jnp.int32), sds((bucket,), jnp.int32),
            sds((bucket,), jnp.float32), sds((bucket,), jnp.float32),
            sds((bucket,), jnp.float32)).compile()
        self.compile_count += 1
        return compiled

    def _place(self, arrs):
        if self.mesh is not None and self.axes:
            _, vec, _ = self._bucket_shardings()
            return tuple(jax.device_put(a, vec) for a in arrs)
        return tuple(jnp.asarray(a) for a in arrs)

    def _dispatch(self, bucket: int, inputs):
        placed = self._place(inputs)
        if self.replicas is not None:
            # per-replica program caches: a respawned replica starts cold
            # and recompiles (compile_count counts that, like a fresh
            # process would); failover re-dispatches the SAME placed
            # inputs, so the surviving replica's result is bit-identical.
            def run_on(rep):
                if bucket not in rep.compiled:
                    rep.compiled[bucket] = self._compile_bucket(bucket)
                return rep.compiled[bucket](self.params, *placed)
            img, sums = self.replicas.dispatch(run_on)
        else:
            if bucket not in self._compiled:
                self._compiled[bucket] = self._compile_bucket(bucket)
            img, sums = self._compiled[bucket](self.params, *placed)
        self.stats["steps"] += 1
        self.stats["bucket_steps"][bucket] += 1
        return img, sums

    def _finalize(self, cur: _Cursor) -> None:
        now = self.clock()
        if cur.deadline_t is not None and now > cur.deadline_t:
            # generated, but too late to honor the SLA: a structured
            # rejection, never a silently-late result
            self._reject(cur, Rejection(
                cur.req.rid, "deadline",
                f"completed {now - cur.deadline_t:.3f}s past its deadline",
                t=now, priority=cur.req.priority))
            return
        dev = (cur.chunks[0] if len(cur.chunks) == 1
               else jnp.concatenate(cur.chunks, axis=0))
        cur.req.images = np.asarray(dev)   # the ONE transfer per request
        cur.chunks = []
        self.stats["device_transfers"] += 1
        self.stats["events_generated"] += cur.req.n_events
        cur.req.latency_s = now - cur.t0
        cur.req.done = True
        cur.req.status = "done"
        self._finished.append(cur.req)
