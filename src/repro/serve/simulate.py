"""Fast-simulation serving engine: batched, sharded 3DGAN event generation.

The paper trains the 3DGAN so it can REPLACE Monte Carlo in production —
this module is that deployment surface.  Requests ask for showers
(``primary_energy``, ``n_events``, ``seed``); the engine turns them into
accelerator work the same way the training side does:

- **fixed batch buckets** — event work from the head of the host-side
  queue is packed into the smallest bucket that fits (padded + masked),
  so the whole service runs on a handful of AOT-compiled programs, one
  per bucket, instead of recompiling per request shape;
- **data-parallel sharding** — with a mesh, every bucket batch is sharded
  over the data axes exactly like a training batch
  (`parallel/sharding.batch_axes`), params stay replicated, and the
  generator runs through the same `core/gan.py` path (including the
  Pallas fused conv3d kernels when `gan.pallas_conv_enabled(cfg)`);
- **on-device results** — generated shower tensors stay on the
  accelerator until a request's LAST event is generated; the drain is
  one device->host transfer per request (`SimulateEngine._finalize`);
- **deterministic per-event RNG** — event ``i`` of a request is generated
  from ``fold_in(fold_in(key(0), request.seed), i)``, so a request's
  showers are bit-identical no matter which bucket they were packed into
  or which other requests shared the batch;
- **rolling physics gate** — every step's masked profile sums
  (`core/validation.profile_sums`) accumulate on device; once per
  ``window`` events the gate drains ONE small pytree and reports the
  paper's Fig. 3/7 divergences against a fixed MC reference
  (:class:`PhysicsGate`), so generator drift in production is detected
  with the same numbers that validate training fidelity.

Typical use::

    from repro.configs import calo3dgan
    from repro.core import validation
    from repro.data.calo import CaloSimulator, CaloSpec
    from repro.serve.simulate import PhysicsGate, SimRequest, SimulateEngine

    cfg = calo3dgan.reduced()
    mc = next(CaloSimulator(CaloSpec(cfg.image_shape)).batches(512))
    gate = PhysicsGate(validation.reference_profiles(mc["image"], mc["e_p"]))
    eng = SimulateEngine(cfg, g_params, buckets=(8, 32, 128), gate=gate)
    eng.submit(SimRequest(rid=0, primary_energy=250.0, n_events=100, seed=7))
    (req,) = eng.run()
    req.images            # (100, X, Y, Z, 1) — exactly n_events
    gate.latest()         # {'longitudinal_kl': ..., 'response_rel_err': ...}
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import gan, validation
from repro.parallel import sharding
from repro.substrate.precision import get_policy


@dataclasses.dataclass
class SimRequest:
    """One event-generation request: n_events showers at one beam setting."""
    rid: int
    primary_energy: float          # E_p in GeV (conditioning label)
    n_events: int
    seed: int = 0
    theta: float = float(np.pi / 2)   # incidence angle (rad); 90 deg = normal
    # filled by the engine:
    images: Optional[np.ndarray] = None   # (n_events, X, Y, Z, 1)
    latency_s: float = 0.0
    done: bool = False


@dataclasses.dataclass
class _Cursor:
    """Engine-internal progress through one request's event range."""
    req: SimRequest
    t0: float
    next_ev: int = 0
    chunks: List[jax.Array] = dataclasses.field(default_factory=list)


class PhysicsGate:
    """Rolling on-device physics validation for a serving deployment.

    ``update`` folds one step's masked profile sums into device-side
    running sums (an async dispatch — no host sync); window accounting
    uses the HOST-side real-event count, so deciding when to drain never
    blocks on the device.  Every ``window`` generated events the gate
    drains once and appends a report with the training-time divergences
    (`core/validation.gate_report`) against the fixed MC ``reference``
    (`core/validation.reference_profiles`).
    """

    def __init__(self, reference: dict, window: int = 512):
        self.reference = reference
        self.window = int(window)
        self.reports: List[dict] = []
        self._sums: Optional[dict] = None
        self._pending = 0

    def update(self, sums: dict, n_real: int) -> None:
        self._pending += int(n_real)
        if self._sums is None:
            self._sums = dict(sums)
        else:
            self._sums = {k: jnp.add(self._sums[k], sums[k])
                          for k in self._sums}
        if self._pending >= self.window:
            self.flush()

    def flush(self) -> Optional[dict]:
        """Drain the current (possibly partial) window: ONE device->host
        transfer, one appended report.  No-op when nothing accumulated."""
        if not self._pending:
            return None
        host = jax.device_get(self._sums)
        rep = validation.gate_report(host, self.reference)
        self.reports.append(rep)
        self._sums, self._pending = None, 0
        return rep

    def latest(self) -> Optional[dict]:
        return self.reports[-1] if self.reports else None

    def drifted(self, max_kl: float) -> bool:
        """True when the latest window's worst profile KL exceeds the
        budget — the deploy-time analogue of the paper's >64-GPU check."""
        rep = self.latest()
        if rep is None:
            return False
        worst = max(rep["longitudinal_kl"], rep["transverse_x_kl"],
                    rep["transverse_y_kl"])
        return worst > max_kl


class SimulateEngine:
    """Micro-batching 3DGAN event-generation service over bucketed steps.

    Parameters
    ----------
    cfg
        A `configs/calo3dgan.GANConfig` (the generator architecture; its
        ``use_pallas_conv`` field picks the kernel route as in training).
    g_params
        Trained generator params (e.g. restored via
        `train/checkpoint.restore_gan_generator`).
    buckets
        Ascending fixed batch sizes.  Each gets exactly ONE compiled
        program (``compile_count`` tracks this); work is padded to the
        smallest bucket that fits the queue's remaining events.
    mesh
        Optional device mesh — bucket batches are sharded over its data
        axes (`sharding.batch_axes`), params replicated, exactly the
        training engine's pure-DP placement.  Every bucket must divide
        by the number of data shards.
    policy_name
        Precision policy (`substrate/precision.get_policy`): noise and the
        conv stacks run in ``compute_dtype``, returned images are cast to
        ``output_dtype``.
    gate
        Optional :class:`PhysicsGate`; fed once per step, drains itself
        once per window.
    """

    def __init__(self, cfg, g_params, *, buckets: Sequence[int] = (8, 32, 128),
                 mesh=None, policy_name: str = "f32",
                 gate: Optional[PhysicsGate] = None):
        self.cfg = cfg
        self.policy = get_policy(policy_name)
        self.mesh = mesh
        axes = sharding.batch_axes(mesh) if mesh is not None else None
        self.axes: tuple = tuple(axes) if axes else ()
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        self.buckets = tuple(sorted(int(b) for b in buckets))
        if not self.buckets:
            raise ValueError("need at least one batch bucket")
        for b in self.buckets:
            if b <= 0 or b % self.n_shards:
                raise ValueError(
                    f"bucket {b} must be positive and divisible by the "
                    f"{self.n_shards} data shards")
        if mesh is not None:
            self.params = jax.device_put(g_params, NamedSharding(mesh, P()))
        else:
            self.params = g_params
        self.gate = gate
        self._compiled: Dict[int, object] = {}
        self.compile_count = 0
        self._queue: List[_Cursor] = []
        self._finished: List[SimRequest] = []
        self.stats = {"steps": 0, "events_generated": 0, "padded_events": 0,
                      "device_transfers": 0,
                      "bucket_steps": {b: 0 for b in self.buckets}}

    @classmethod
    def from_checkpoint(cls, path: str, cfg, *, policy_name: Optional[str]
                        = None, **kw) -> "SimulateEngine":
        """Restore a generator checkpoint AND the precision policy it was
        trained under (manifest ``extra["precision"]``; manifests written
        before that field default to f32) — the production handoff that
        keeps serving numerics matched to training numerics.  An explicit
        ``policy_name`` overrides the recorded one.
        """
        from repro.train import checkpoint as ckpt_lib
        params = ckpt_lib.restore_gan_generator(path, cfg)
        resolved = policy_name or ckpt_lib.manifest_precision(path)
        return cls(cfg, params, policy_name=resolved, **kw)

    # -- host API ----------------------------------------------------------

    def warmup(self) -> None:
        """Pre-compile every bucket's program so the first requests don't
        pay compile time (deployments call this before opening traffic)."""
        for b in self.buckets:
            if b not in self._compiled:
                self._compiled[b] = self._compile_bucket(b)

    def submit(self, req: SimRequest) -> None:
        if req.n_events <= 0:
            raise ValueError(f"request {req.rid}: n_events must be positive")
        self._queue.append(_Cursor(req, time.perf_counter()))

    def run(self, max_steps: int = 100_000) -> List[SimRequest]:
        """Drain the queue (or stop after ``max_steps`` bucket steps);
        returns every request finished so far, FIFO order."""
        for _ in range(max_steps):
            if not self._queue:
                break
            bucket, inputs, spans, n_real = self._pack()
            img, sums = self._dispatch(bucket, inputs)
            if self.gate is not None:
                self.gate.update(sums, n_real)
            self.stats["padded_events"] += bucket - n_real
            for cur, row, take in spans:
                cur.chunks.append(img[row:row + take])
                if cur.next_ev == cur.req.n_events:
                    self._finalize(cur)
            self._queue = [c for c in self._queue if not c.req.done]
        return list(self._finished)

    def generate_events(self, primary_energy: float, n_events: int,
                        seed: int = 0) -> np.ndarray:
        """One-shot convenience: serve a single request, return its images."""
        rid = len(self._finished) + len(self._queue)
        req = SimRequest(rid=rid, primary_energy=primary_energy,
                         n_events=n_events, seed=seed)
        self.submit(req)
        self.run()
        return req.images

    # -- packing -----------------------------------------------------------

    def _pick_bucket(self, remaining: int) -> int:
        for b in self.buckets:
            if b >= remaining:
                return b
        return self.buckets[-1]

    def _pack(self):
        """Fill one bucket batch from the queue head (FIFO, requests may
        split across steps or share one).  Padded rows carry a benign
        mid-range E_p and mask=0 so they never reach the gate or a user."""
        remaining = sum(c.req.n_events - c.next_ev for c in self._queue)
        bucket = self._pick_bucket(remaining)
        seeds = np.zeros((bucket,), np.int32)
        ev_idx = np.zeros((bucket,), np.int32)
        e_p = np.full((bucket,), 100.0, np.float32)
        theta = np.full((bucket,), np.pi / 2, np.float32)
        mask = np.zeros((bucket,), np.float32)
        spans = []
        row = 0
        for cur in self._queue:
            if row == bucket:
                break
            take = min(bucket - row, cur.req.n_events - cur.next_ev)
            if take == 0:
                continue
            seeds[row:row + take] = cur.req.seed
            ev_idx[row:row + take] = np.arange(cur.next_ev,
                                               cur.next_ev + take)
            e_p[row:row + take] = cur.req.primary_energy
            theta[row:row + take] = cur.req.theta
            mask[row:row + take] = 1.0
            spans.append((cur, row, take))
            cur.next_ev += take
            row += take
        return bucket, (seeds, ev_idx, e_p, theta, mask), spans, row

    # -- compiled steps ----------------------------------------------------

    def _make_step(self):
        cfg, latent = self.cfg, self.cfg.latent_dim
        compute = self.policy.compute_dtype
        output = self.policy.output_dtype

        def step(params, req_seed, ev_idx, e_p, theta, mask):
            def ev_key(s, i):
                return jax.random.fold_in(
                    jax.random.fold_in(jax.random.key(0), s), i)

            keys = jax.vmap(ev_key)(req_seed, ev_idx)
            noise = jax.vmap(
                lambda k: jax.random.normal(k, (latent,), compute))(keys)
            img = gan.generate(params, noise, e_p, theta, cfg)
            sums = validation.profile_sums(img, e_p, mask)
            return img.astype(output), sums

        return step

    def _bucket_shardings(self):
        """(replicated, batch-sharded-1d, batch-sharded-image) shardings."""
        rep = NamedSharding(self.mesh, P())
        ax = self.axes if len(self.axes) > 1 else self.axes[0]
        vec = NamedSharding(self.mesh, P(ax))
        img = NamedSharding(self.mesh, P(ax, None, None, None, None))
        return rep, vec, img

    def _compile_bucket(self, bucket: int):
        """ONE AOT-compiled program per bucket: lower + compile now, so
        serving never hides a recompile inside a request."""
        step = self._make_step()
        if self.mesh is not None and self.axes:
            rep, vec, img = self._bucket_shardings()
            fn = jax.jit(step,
                         in_shardings=(rep, vec, vec, vec, vec, vec),
                         out_shardings=(img, rep))
        else:
            fn = jax.jit(step)
        sds = jax.ShapeDtypeStruct
        compiled = fn.lower(
            self.params,
            sds((bucket,), jnp.int32), sds((bucket,), jnp.int32),
            sds((bucket,), jnp.float32), sds((bucket,), jnp.float32),
            sds((bucket,), jnp.float32)).compile()
        self.compile_count += 1
        return compiled

    def _place(self, arrs):
        if self.mesh is not None and self.axes:
            _, vec, _ = self._bucket_shardings()
            return tuple(jax.device_put(a, vec) for a in arrs)
        return tuple(jnp.asarray(a) for a in arrs)

    def _dispatch(self, bucket: int, inputs):
        if bucket not in self._compiled:
            self._compiled[bucket] = self._compile_bucket(bucket)
        img, sums = self._compiled[bucket](self.params, *self._place(inputs))
        self.stats["steps"] += 1
        self.stats["bucket_steps"][bucket] += 1
        return img, sums

    def _finalize(self, cur: _Cursor) -> None:
        dev = (cur.chunks[0] if len(cur.chunks) == 1
               else jnp.concatenate(cur.chunks, axis=0))
        cur.req.images = np.asarray(dev)   # the ONE transfer per request
        cur.chunks = []
        self.stats["device_transfers"] += 1
        self.stats["events_generated"] += cur.req.n_events
        cur.req.latency_s = time.perf_counter() - cur.t0
        cur.req.done = True
        self._finished.append(cur.req)
