"""Batched serving engine: continuous-batching decode over a fixed slot pool.

The serving analogue of the paper's fused training loop: ONE compiled
``serve_step`` advances every active slot a token per call — prompt
insertion (prefill) happens on free slots, finished requests release their
slot.  All per-slot state (KV cache / SSM state, positions, emitted tokens)
lives on device; the host only enqueues prompts and drains outputs.

Works with every architecture family through models.api (KV-cache archs and
recurrent-state archs expose the same prefill/decode_step signatures).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.serve.scheduler import Rejection, Scheduler, SchedulerConfig
from repro.substrate.precision import get_policy
from repro.train import steps as steps_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never stops early
    priority: int = 0               # higher wins slot admission
    deadline_s: Optional[float] = None   # latency SLA from submit
    # filled by the engine:
    tokens: Optional[list] = None
    done: bool = False
    status: str = "queued"          # "queued" | "done" | "rejected"
    error: Optional[dict] = None
    # absolute SLA deadline (engine clock), kept so in-flight slot
    # requests can be expired mid-decode (the scheduler stops tracking a
    # request once pop_next hands it to a slot)
    _abs_deadline: Optional[float] = None


class ServeEngine:
    """Slot-based continuous batching on a single compiled decode step.

    Slot admission goes through the same `serve/scheduler.Scheduler` as
    the fast-sim engine (the service front-end unification hook):
    deadlines, priorities, admission bound and age promotion apply to
    LM requests too, with ``max_new_tokens`` as the backlog weight.
    The default ``sched`` reproduces the legacy FIFO slot fill exactly.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 512,
                 policy_name: str = "f32", mesh=None,
                 sched: Optional[SchedulerConfig] = None,
                 clock=time.monotonic, prefill: str = "auto",
                 prefill_chunk: int = 128):
        self.cfg = cfg
        self.model = api.get_model(cfg)
        self.policy = get_policy(policy_name)
        self.mesh = mesh
        self.slots = slots
        self.max_len = max_len
        self.params = params

        self._decode = jax.jit(steps_lib.make_serve_step(
            self.model, cfg, self.policy, mesh=mesh))
        # prompt ingestion: "chunked" runs C prompt tokens per slot in ONE
        # batched prefill_chunk launch (token-identical to sequential —
        # pinned by tests); "sequential" is the legacy token-by-token path
        # every arch supports; "auto" picks chunked whenever the arch
        # exports a prefill_chunk (recurrent-only archs like xlstm don't).
        if prefill not in ("auto", "chunked", "sequential"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill == "auto":
            prefill = "chunked" if self.model.prefill_chunk is not None \
                else "sequential"
        elif prefill == "chunked" and self.model.prefill_chunk is None:
            raise ValueError(
                f"arch family {cfg.family!r} has no chunked prefill path")
        self.prefill_mode = prefill
        self._chunk = max(1, min(prefill_chunk, max_len))
        if prefill == "chunked":
            self._prefill_fn = jax.jit(steps_lib.make_prefill_chunk_step(
                self.model, cfg, self.policy, mesh=mesh))
        # per-slot state: one cache of batch=slots; per-slot positions.
        # The cache holds activations, so it lives in the policy's COMPUTE
        # dtype (bf16 under the bf16 policy, f32 under f32) — not a
        # hardcoded bf16 that would silently down-cast an f32 deployment.
        self.cache_dtype = self.policy.compute_dtype
        self.cache = self.model.init_cache(cfg, slots, max_len,
                                           self.cache_dtype)
        self._cache_axes = self.model.cache_logical_axes(cfg)
        self.pos = np.zeros((slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.cur_tok = np.zeros((slots, 1), np.int32)
        self.clock = clock
        self.scheduler = Scheduler(sched or SchedulerConfig(), clock=clock)
        self.rejected: List[Request] = []
        self._finished: List[Request] = []

    # -- host API ----------------------------------------------------------

    def submit(self, req: Request):
        req.tokens = []
        deadline = (self.clock() + float(req.deadline_s)
                    if req.deadline_s is not None else None)
        req._abs_deadline = deadline
        res = self.scheduler.admit(req, rid=req.rid,
                                   n_events=req.max_new_tokens,
                                   priority=req.priority, deadline=deadline)
        for item, rej in res.rejections:
            self._reject(item, rej)

    def run(self, max_steps: int = 10_000):
        """Drive until queue + slots drain (or max_steps)."""
        for _ in range(max_steps):
            self._sweep_slot_deadlines()
            self._fill_slots()
            if all(r is None for r in self.slot_req):
                break
            self._step()
        return self._finished

    # -- internals -----------------------------------------------------------

    def _reject(self, req: Request, rej):
        req.status = "rejected"
        req.error = rej.to_dict()
        self.rejected.append(req)

    def _sweep_slot_deadlines(self):
        """Expire IN-FLIGHT requests whose SLA deadline has passed.

        ``scheduler.expire()`` only covers queued requests — once
        ``pop_next`` hands a request to a slot the scheduler stops
        tracking it, so without this sweep a request that blows its
        deadline mid-decode would keep burning slot time to completion
        and be delivered late anyway.  Finalized as a structured
        deadline rejection, like a queue-side expiry."""
        now = self.clock()
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None or req._abs_deadline is None:
                continue
            if now > req._abs_deadline:
                self._reject(req, Rejection(
                    rid=req.rid, reason="deadline",
                    detail=f"deadline exceeded mid-decode after "
                           f"{len(req.tokens)} tokens", t=now,
                    priority=req.priority))
                req.done = True
                self.slot_req[s] = None

    def _fill_slots(self):
        for item, rej in self.scheduler.expire():
            self._reject(item, rej)
        newly = []
        for s in range(self.slots):
            if self.slot_req[s] is None:
                req = self.scheduler.pop_next()
                if req is None:
                    break
                self.slot_req[s] = req
                newly.append((s, req))
        if not newly:
            return
        if self.prefill_mode == "chunked":
            self._prefill_chunked(newly)
        else:
            for s, req in newly:
                self._prefill_slot(s, req)

    def _merge_slot(self, new_cache, old_cache, slot: int):
        """Take slot `slot`'s rows from new_cache, everything else from
        old_cache.  The batch dim of each cache leaf comes from the
        model's cache_logical_axes ('batch' entry) — this is what makes
        the engine correct for RECURRENT state (Mamba/xLSTM), where decode
        updates are not idempotent like KV-cache writes."""
        from repro.parallel.sharding import _is_axes_leaf

        flat_axes = jax.tree.leaves(self._cache_axes, is_leaf=_is_axes_leaf)
        flat_new, treedef = jax.tree.flatten(new_cache)
        flat_old = jax.tree.leaves(old_cache)

        def merge(new, old, axes):
            if "batch" not in axes:
                return new
            bdim = axes.index("batch")
            idx = jnp.arange(new.shape[bdim])
            shape = [1] * new.ndim
            shape[bdim] = new.shape[bdim]
            mask = (idx == slot).reshape(shape)
            return jnp.where(mask, new, old)

        merged = [merge(n, o, a)
                  for n, o, a in zip(flat_new, flat_old, flat_axes)]
        return jax.tree.unflatten(treedef, merged)

    def _zero_slot(self, slot: int):
        zeros = self.model.init_cache(self.cfg, self.slots, self.max_len,
                                      self.cache_dtype)
        self.cache = self._merge_slot(zeros, self.cache, slot)

    def _prefill_slot(self, s: int, req: Request):
        """Sequential per-slot prefill: feed prompt tokens through decode
        steps for this slot (single-slot prefill keeps ONE compiled program
        for the whole engine; a bulk-prefill variant is a future fast path).

        Other slots' cache rows are snapshotted and restored afterwards:
        during prefill the global decode step advances EVERY slot, which is
        harmless for KV caches (same-index overwrite) but double-advances
        recurrent state."""
        self._zero_slot(s)
        snapshot = self.cache
        self.pos[s] = 0
        # decode the prompt token by token into the slot's cache region
        for t in req.prompt:
            self.cur_tok[s, 0] = t
            self._step(active_slot=s)
        self.cache = self._merge_slot(self.cache, snapshot, s)
        # after the prompt, cur_tok[s] holds the model's first sampled token
        req.tokens.append(int(self.cur_tok[s, 0]))

    def _prefill_chunked(self, pairs):
        """Batched chunked prefill: ingest every newly-admitted prompt in
        ceil(prompt_len / chunk) ``prefill_chunk`` launches TOTAL (all new
        slots ride the same launch), instead of prompt_len global decode
        steps PER slot.  The chunk step masks inactive rows (lens = 0)
        inside the model — other slots' cache rows, recurrent state and
        ``pos`` are untouched, so no snapshot/merge is needed (pinned by
        the pos-freeze test).  Token-identical to ``_prefill_slot``."""
        prompts = {}
        for s, req in pairs:
            self._zero_slot(s)
            self.pos[s] = 0
            prompts[s] = np.asarray(req.prompt, np.int32).reshape(-1)
        C = self._chunk
        offset = {s: 0 for s in prompts}
        first_tok = {}
        while any(offset[s] < len(prompts[s]) for s in prompts):
            tokens = np.zeros((self.slots, C), np.int32)
            lens = np.zeros((self.slots,), np.int32)
            for s, p in prompts.items():
                n = min(C, len(p) - offset[s])
                if n > 0:
                    tokens[s, :n] = p[offset[s]:offset[s] + n]
                    lens[s] = n
            extra = {}
            if self.cfg.mrope:
                qp = (self.pos[:, None] + np.arange(C)).astype(np.int32)
                extra["positions"] = jnp.asarray(
                    np.broadcast_to(qp[None], (3, self.slots, C)))
            nxt, self.cache = self._prefill_fn(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(self.pos, jnp.int32), jnp.asarray(lens), extra)
            nxt = np.asarray(nxt)
            for s in prompts:
                n = int(lens[s])
                if n == 0:
                    continue
                self.pos[s] += n
                offset[s] += n
                if offset[s] >= len(prompts[s]):
                    first_tok[s] = int(nxt[s])
        for s, req in pairs:
            # empty prompt: no launch sampled anything — keep the slot's
            # stale cur_tok, matching the sequential path's behavior
            tok = first_tok.get(s, int(self.cur_tok[s, 0]))
            self.cur_tok[s, 0] = tok
            req.tokens.append(tok)

    def _step(self, active_slot: Optional[int] = None):
        """One global decode step (all slots advance; inactive slots are
        harmless — their outputs are ignored)."""
        extra = {}
        if self.cfg.mrope:
            p = jnp.asarray(self.pos[None, :, None].repeat(3, 0))
            extra["positions"] = p.astype(jnp.int32)
        # per-slot position vector: every slot writes its own cache row at
        # its own depth (ragged continuous batching); inactive slots'
        # writes are idempotent (same index until the slot advances)
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        nxt, self.cache = self._decode(self.params, jnp.asarray(self.cur_tok),
                                       self.cache, pos_vec, extra)
        nxt = np.asarray(nxt)
        for s in range(self.slots):
            req = self.slot_req[s]
            if active_slot is not None and s != active_slot:
                continue
            self.pos[s] += 1
            if req is None:
                continue
            if active_slot is None:
                req.tokens.append(int(nxt[s]))
            self.cur_tok[s, 0] = nxt[s]
            if (len(req.tokens) >= req.max_new_tokens
                    or (req.eos_id >= 0 and req.tokens
                        and req.tokens[-1] == req.eos_id)
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                req.status = "done"
                self._finished.append(req)
                self.slot_req[s] = None
