"""Replica groups for the serving runtime: health, failover, hedging.

A production fast-sim service does not run on one accelerator: the
cloud planner (`cloud/planner.recommend`) provisions N generator
replicas across a `launch/mesh.Topology`, and — on the preemptible
capacity the paper's cost story favors — some of them WILL die or stall
mid-traffic.  This module is the dispatch layer that rides through
that:

- :class:`Replica` — one generator worker: a health flag, its own
  compiled-program cache (a respawned replacement starts cold), and
  per-replica dispatch stats.  On a real cluster each replica owns one
  node row of the topology (`launch.mesh.replica_meshes`); on this
  container replicas share the host devices and are distinguished by
  the fault channel — the policy logic is identical.
- :class:`ReplicaGroup` — round-robin dispatch over the healthy set
  with **retry + exponential backoff**: when the chosen replica is dead
  (or dies mid-bucket), the SAME bucket step re-dispatches onto a
  surviving replica after ``backoff_s * 2^(attempt-1)``.  Because the
  engine's per-event ``fold_in`` RNG makes a bucket step a pure
  function of its inputs, the re-dispatched step returns showers
  **bit-identical** to the fault-free run — the chaos suite's
  acceptance bar.
- **hedged re-dispatch** — a replica scripted to stall longer than
  ``hedge_stall_ms`` is skipped for that step (charged a bounded hedge
  wait) and the bucket runs on a peer instead; short stalls are simply
  absorbed.  The stalled replica stays healthy.
- :class:`ReplicaFaultInjector` — the serve-side consumer of
  `train/faults.FaultPlan`: ``preempt`` events kill replica ``node``
  (``lose_node=False`` respawns it, cache cleared, after the step
  completes elsewhere), ``stall`` events slow it.  Faults fire at exact
  GROUP DISPATCH indices and each fires once, so a committed trace
  (``results/serve_chaos_trace.json``) replays byte-for-byte in CI —
  the same determinism discipline as the elastic training suite.

When the last replica dies, :meth:`ReplicaGroup.dispatch` raises
:class:`NoHealthyReplicas`; the engine converts that into structured
``capacity`` rejections and a degraded-state report rather than
hanging its queue.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.train.faults import FaultEvent, FaultInjector, FaultPlan


class NoHealthyReplicas(RuntimeError):
    """Every replica in the group is dead — a total capacity outage."""

    def __init__(self, step: int):
        super().__init__(f"no healthy replica left for dispatch {step}")
        self.step = int(step)


class ReplicaFaultInjector(FaultInjector):
    """`train/faults.FaultInjector` re-aimed at a replica group.

    Same :class:`~repro.train.faults.FaultPlan` format, same fire-once
    and replayability guarantees; ``step`` indices count the GROUP's
    bucket dispatches (not training steps), ``node`` names the target
    replica rank.  ``kills(step)`` / ``stalls(step)`` fire and return
    this dispatch's events, keyed by replica rank.
    """

    def kills(self, step: int) -> Dict[int, FaultEvent]:
        out = {}
        for idx, ev in self.pending(step):
            if ev.kind == "preempt":
                self.fire(idx, ev)
                out[ev.node] = ev
        return out

    def stalls(self, step: int) -> Dict[int, FaultEvent]:
        out = {}
        for idx, ev in self.pending(step):
            if ev.kind == "stall":
                self.fire(idx, ev)
                out[ev.node] = ev
        return out


@dataclasses.dataclass
class Replica:
    """One generator worker in the group.

    ``mesh`` is the replica's device submesh on a real cluster (one
    node row via `launch.mesh.replica_meshes`); ``None`` when replicas
    share the host devices (tests, single-node deployments).
    ``compiled`` is the replica's OWN program cache — a respawned
    replacement recompiles, exactly like a fresh process would.
    """
    rank: int
    mesh: object = None
    healthy: bool = True
    compiled: Dict[int, object] = dataclasses.field(default_factory=dict)
    stats: Dict[str, int] = dataclasses.field(default_factory=lambda: {
        "dispatches": 0, "failures": 0, "stalls": 0, "respawns": 0})


class ReplicaGroup:
    """Failover dispatch over N replicas.

    Parameters
    ----------
    n / meshes
        Build ``n`` device-sharing replicas, or one per mesh in
        ``meshes`` (e.g. `launch.mesh.replica_meshes(node_mesh)`).
    injector
        Optional :class:`ReplicaFaultInjector` firing a scripted
        :class:`~repro.train.faults.FaultPlan` against the group.
    max_attempts / backoff_s
        Failover policy: how many replicas one bucket step may try, and
        the base of the exponential backoff slept between attempts.
    hedge_stall_ms
        Stalls scripted at or above this are hedged (the step re-routes
        to a peer after a ``hedge_stall_ms`` wait) instead of absorbed.
        ``None`` disables hedging — every stall is absorbed in place.
    sleep
        Injected for tests; defaults to ``time.sleep``.
    """

    def __init__(self, n: int = 2, *, meshes: Optional[Sequence] = None,
                 injector: Optional[ReplicaFaultInjector] = None,
                 max_attempts: int = 3, backoff_s: float = 0.01,
                 hedge_stall_ms: Optional[float] = None,
                 sleep: Callable[[float], None] = time.sleep):
        if meshes is not None:
            self.replicas = [Replica(r, mesh=m)
                             for r, m in enumerate(meshes)]
        else:
            self.replicas = [Replica(r) for r in range(int(n))]
        if not self.replicas:
            raise ValueError("a replica group needs at least one replica")
        self.injector = injector
        self.max_attempts = max(int(max_attempts), 1)
        self.backoff_s = float(backoff_s)
        self.hedge_stall_ms = hedge_stall_ms
        self._sleep = sleep
        self._step = 0
        self._rr = 0
        self.stats = {"dispatches": 0, "failovers": 0, "retries": 0,
                      "hedges": 0, "respawns": 0, "backoff_s": 0.0}

    # -- health --------------------------------------------------------------

    def healthy(self) -> List[Replica]:
        return [r for r in self.replicas if r.healthy]

    def health_report(self) -> dict:
        return {
            "total": len(self.replicas),
            "healthy": len(self.healthy()),
            "replicas": [{"rank": r.rank, "healthy": r.healthy,
                          **r.stats} for r in self.replicas],
        }

    # -- dispatch ------------------------------------------------------------

    def _pick(self, skip: set) -> Optional[Replica]:
        """Round-robin over healthy replicas not skipped this step."""
        n = len(self.replicas)
        for off in range(n):
            r = self.replicas[(self._rr + off) % n]
            if r.healthy and r.rank not in skip:
                self._rr = (self._rr + off + 1) % n
                return r
        return None

    def dispatch(self, run: Callable[[Replica], object]) -> object:
        """Run one bucket step on a healthy replica, failing over past
        scripted (or real) replica deaths with exponential backoff and
        hedging past scripted stalls.  ``run(replica)`` must be a pure
        function of the step's inputs — the engine's per-event fold_in
        RNG guarantees that — so a failover re-dispatch returns a
        bit-identical result.
        """
        step, self._step = self._step, self._step + 1
        kills = self.injector.kills(step) if self.injector else {}
        stalls = self.injector.stalls(step) if self.injector else {}
        respawn: List[Replica] = []
        skip: set = set()
        attempts = 0
        while True:
            rep = self._pick(skip)
            if rep is None:
                raise NoHealthyReplicas(step)
            if rep.rank in kills:
                ev = kills.pop(rep.rank)
                rep.healthy = False
                rep.stats["failures"] += 1
                if not ev.lose_node:
                    respawn.append(rep)
                attempts += 1
                self.stats["failovers"] += 1
                self._backoff(attempts)
                continue
            if rep.rank in stalls:
                ev = stalls.pop(rep.rank)
                rep.stats["stalls"] += 1
                if self.hedge_stall_ms is not None \
                        and ev.stall_ms >= self.hedge_stall_ms:
                    # hedge: charge a bounded wait, re-route to a peer
                    # (unless this is the only healthy replica left)
                    if len(self.healthy()) - len(skip) > 1:
                        self.stats["hedges"] += 1
                        self._sleep(self.hedge_stall_ms / 1e3)
                        skip.add(rep.rank)
                        continue
                self._sleep(ev.stall_ms / 1e3)       # absorbed in place
            try:
                result = run(rep)
            except Exception:
                # a REAL mid-bucket death (not scripted): same failover
                rep.healthy = False
                rep.stats["failures"] += 1
                attempts += 1
                self.stats["failovers"] += 1
                if attempts >= self.max_attempts:
                    raise
                self._backoff(attempts)
                continue
            rep.stats["dispatches"] += 1
            self.stats["dispatches"] += 1
            # scripted deaths that were not in this step's dispatch path
            # still happened — mark them before the step returns
            for rank, ev in kills.items():
                r = self.replicas[rank]
                if r.healthy:
                    r.healthy = False
                    r.stats["failures"] += 1
                    if not ev.lose_node:
                        respawn.append(r)
            for r in respawn:                # replacement came up: cold cache
                r.healthy = True
                r.compiled.clear()
                r.stats["respawns"] += 1
                self.stats["respawns"] += 1
            return result

    def _backoff(self, attempts: int) -> None:
        if attempts >= self.max_attempts and not self.healthy():
            return                            # about to raise, don't sleep
        delay = self.backoff_s * (2 ** max(attempts - 1, 0))
        self.stats["retries"] += 1
        self.stats["backoff_s"] += delay
        self._sleep(delay)
