"""Serving engines — two workloads, one discipline (state stays on device).

Two engines live here, matching the repo's two workload families:

- :class:`ServeEngine` (`serve/engine.py`) — the **LM** continuous-batching
  decode engine: a fixed slot pool over ONE compiled ``serve_step``; every
  slot advances a token per call, prompt insertion reuses free slots, and
  all per-slot state (KV cache / recurrent state, positions) lives on the
  accelerator in the precision policy's compute dtype.

- :class:`SimulateEngine` (`serve/simulate.py`) — the **GAN fast-simulation**
  engine, the deployment surface the paper trains 3DGAN for: event-
  generation requests are micro-batched into fixed, padded+masked batch
  buckets (one AOT-compiled, data-parallel-sharded generator step per
  bucket), shower tensors stay on device until a whole request is ready
  (one transfer per request), and a rolling :class:`PhysicsGate` reports
  the paper's Fig. 3/7 profile divergences per window to catch generator
  drift in production.

The split mirrors the workloads' shapes: LM serving is *stateful and
incremental* (a request is a sequence of dependent steps over a cache),
fast-sim serving is *stateless and bulk* (a request is an independent
batch of samples) — so the LM engine optimises slot reuse while the GAN
engine optimises bucket packing and transfer counts.

What they SHARE is the resilience layer (the front-end unification
hook): `serve/scheduler.Scheduler` owns deadlines, priorities,
admission control and load shedding for both engines, and
`serve/replicas.ReplicaGroup` owns health-checked failover dispatch —
see ``docs/fastsim_service.md`` for the semantics.
"""
from repro.serve.engine import Request, ServeEngine
from repro.serve.replicas import (NoHealthyReplicas, Replica,
                                  ReplicaFaultInjector, ReplicaGroup)
from repro.serve.scheduler import (Rejection, Scheduler, SchedulerConfig)
from repro.serve.simulate import PhysicsGate, SimRequest, SimulateEngine

__all__ = ["NoHealthyReplicas", "PhysicsGate", "Rejection", "Replica",
           "ReplicaFaultInjector", "ReplicaGroup", "Request", "Scheduler",
           "SchedulerConfig", "ServeEngine", "SimRequest", "SimulateEngine"]
