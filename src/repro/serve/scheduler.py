"""Request scheduler for the serving runtime: deadlines, priorities,
admission control, and continuous batching.

`serve/simulate.SimulateEngine` used to drain a host FIFO: every bucket
step was filled from the queue head, so one large request ahead of a
1-event request cost the small one the whole backlog's latency (the
"everything lands in the 128 bucket" p99 pathology in
``results/BENCH_serve_fastsim.json``), the queue could grow without
bound, and a request with a latency SLA had no way to express it.  This
module is the policy layer that replaces that FIFO — engine-agnostic, so
the GAN fast-sim engine and the LM slot engine can share it (the service
front-end unification hook):

- **deadlines** — a request may carry an absolute deadline; queued work
  whose deadline has passed is *rejected with a structured error*
  (:class:`Rejection`), never silently served late and never left to
  hang.  Ordering within a priority level is earliest-deadline-first.
- **priorities** — higher ``priority`` wins bucket admission; under
  overload or degraded operation the LOWEST priority sheds first.
- **admission control / load shedding** — ``max_queue_events`` bounds
  the backlog (derive it from the SLA: ``drain_rate_ev_s * sla_s``, see
  :meth:`SchedulerConfig.for_sla`).  An arrival over the bound first
  evicts strictly-lower-priority queued work (latest-deadline first);
  if that cannot make room the arrival itself is shed.  Optional
  feasibility check: an arrival whose deadline cannot be met even at the
  configured drain rate is rejected at submit time instead of wasting
  queue space.
- **continuous batching** — :meth:`plan_step` admits *compatible*
  requests into the next bucket step in scheduling order (promoted, then
  priority, then deadline), instead of strict FIFO drain.  Requests
  still split across steps and share buckets exactly as before.
- **age-based promotion** — an entry that has been passed over for
  ``promote_after_steps`` consecutive bucket steps jumps to the front of
  the order (FIFO among promoted), so an old small request can never
  starve behind a stream of large or higher-priority ones.

Determinism: the scheduler never reads the wall clock directly — it
calls the injected ``clock`` (default ``time.monotonic``).  Chaos tests
pass a fake clock, making deadline expiry and shed counts exactly
replayable; all ordering keys are (priority, deadline, submit sequence),
never timing races.

The default :class:`SchedulerConfig` (no bound, no deadlines, promotion
off) reproduces the legacy FIFO behavior bit-for-bit — the engine's
existing packing tests pin that equivalence.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Sequence, Tuple

REJECT_REASONS = ("overload", "deadline", "degraded", "capacity")


@dataclasses.dataclass(frozen=True)
class Rejection:
    """A structured shed/reject record — the service's answer when it
    cannot (or will not) serve a request, instead of a hang or a silent
    drop.  ``reason`` is one of :data:`REJECT_REASONS`:

    - ``overload``   — admission control shed it (queue bound exceeded);
    - ``deadline``   — its deadline expired (in queue, or infeasible at
      admission, or the result completed late);
    - ``degraded``   — shed by a degraded-mode policy (e.g. a PhysicsGate
      drift alarm keeping only high-priority traffic);
    - ``capacity``   — no healthy replica remained to run it.
    """
    rid: int
    reason: str
    detail: str
    t: float = 0.0
    priority: int = 0

    def __post_init__(self):
        if self.reason not in REJECT_REASONS:
            raise ValueError(
                f"reason must be one of {REJECT_REASONS}, got {self.reason!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Scheduling policy knobs (all off by default = legacy FIFO).

    ``max_queue_events``
        Admission bound on the queued-but-ungenerated event backlog;
        ``0`` disables admission control.  Derive from the SLA via
        :meth:`for_sla`.
    ``drain_rate_ev_s``
        Measured service throughput (events/s, e.g. ``events_per_s``
        from ``results/BENCH_serve_fastsim.json``).  When set, an
        arrival whose deadline is infeasible even if served immediately
        (``backlog / rate`` already past it) is rejected at admission.
    ``promote_after_steps``
        Age-based promotion: an entry passed over for this many
        consecutive bucket steps jumps the priority/deadline order
        (``0`` disables).  This is the anti-starvation rule — without
        it a stream of large high-priority requests can push a small
        old request's latency unboundedly.
    ``degrade_shed_below``
        Degraded-mode threshold: :meth:`Scheduler.shed_below` callers
        (gate-drift / overload ladders) shed entries with
        ``priority < degrade_shed_below``.
    """
    max_queue_events: int = 0
    drain_rate_ev_s: float = 0.0
    promote_after_steps: int = 0
    degrade_shed_below: int = 1

    @classmethod
    def for_sla(cls, drain_rate_ev_s: float, sla_s: float,
                **kw) -> "SchedulerConfig":
        """SLA-derived admission bound: a backlog longer than
        ``drain_rate_ev_s * sla_s`` events cannot drain inside the SLA
        even at full throughput, so admitting past it only manufactures
        deadline misses — shed at the door instead."""
        return cls(max_queue_events=max(int(drain_rate_ev_s * sla_s), 1),
                   drain_rate_ev_s=drain_rate_ev_s, **kw)


@dataclasses.dataclass
class _Entry:
    """One admitted unit of work and its scheduling state."""
    item: Any                      # caller's handle (engine cursor)
    rid: int
    remaining: int                 # events not yet packed into a step
    priority: int = 0
    deadline: Optional[float] = None   # absolute, in clock() time
    seq: int = 0                   # admission order (FIFO tiebreak)
    waited_steps: int = 0          # consecutive steps passed over


@dataclasses.dataclass(frozen=True)
class AdmitResult:
    """Outcome of :meth:`Scheduler.admit`: whether the arrival got in,
    plus every (item, Rejection) it produced — evicted lower-priority
    entries, or the arrival itself."""
    admitted: bool
    rejections: Tuple[Tuple[Any, Rejection], ...] = ()


class Scheduler:
    """Priority/deadline-aware bucket scheduler over admitted entries.

    The engine owns compilation and dispatch; the scheduler owns WHO is
    served WHEN: :meth:`admit` applies admission control, :meth:`expire`
    rejects dead work, :meth:`plan_step` picks the next bucket's
    occupants (pure — call :meth:`commit` once the step actually ran, so
    a failed dispatch leaves the queue intact), and :meth:`shed_below` /
    :meth:`drain` implement the degradation ladder's shedding.
    """

    def __init__(self, config: Optional[SchedulerConfig] = None, *,
                 clock=time.monotonic):
        self.config = config or SchedulerConfig()
        self.clock = clock
        self._entries: List[_Entry] = []
        self._seq = 0
        self.stats = {"admitted": 0, "planned_steps": 0, "promotions": 0,
                      "evictions": 0,
                      "rejected": {r: 0 for r in REJECT_REASONS}}

    # -- introspection -------------------------------------------------------

    def queue_depth(self) -> int:
        return len(self._entries)

    def backlog_events(self) -> int:
        return sum(e.remaining for e in self._entries)

    # -- admission -----------------------------------------------------------

    def _reject(self, entry: _Entry, reason: str, detail: str):
        rej = Rejection(entry.rid, reason, detail, t=self.clock(),
                        priority=entry.priority)
        self.stats["rejected"][reason] += 1
        return (entry.item, rej)

    def admit(self, item: Any, *, rid: int, n_events: int,
              priority: int = 0,
              deadline: Optional[float] = None) -> AdmitResult:
        """Admission-control one arrival.  ``deadline`` is ABSOLUTE (in
        ``clock()`` time); callers turn a relative SLA into one at
        submit.  May evict queued strictly-lower-priority entries to
        make room (lowest priority first, latest deadline first within a
        priority, newest last as the final tiebreak)."""
        cfg = self.config
        entry = _Entry(item, rid, int(n_events), int(priority), deadline,
                       seq=self._seq)
        self._seq += 1
        rejections: List[Tuple[Any, Rejection]] = []
        now = self.clock()

        if deadline is not None and deadline <= now:
            rejections.append(self._reject(
                entry, "deadline", "deadline already expired at admission"))
            return AdmitResult(False, tuple(rejections))
        if cfg.drain_rate_ev_s > 0 and deadline is not None:
            # feasibility: even served ahead of everyone, can it finish?
            if now + n_events / cfg.drain_rate_ev_s > deadline:
                rejections.append(self._reject(
                    entry, "deadline",
                    f"infeasible: {n_events} events need "
                    f"{n_events / cfg.drain_rate_ev_s:.2f}s at "
                    f"{cfg.drain_rate_ev_s:.0f} ev/s"))
                return AdmitResult(False, tuple(rejections))

        if cfg.max_queue_events > 0:
            if n_events > cfg.max_queue_events:
                rejections.append(self._reject(
                    entry, "overload",
                    f"{n_events} events exceeds the whole admission "
                    f"bound {cfg.max_queue_events}"))
                return AdmitResult(False, tuple(rejections))
            over = (self.backlog_events() + n_events
                    - cfg.max_queue_events)
            if over > 0:
                # evict strictly-lower-priority queued work first
                victims = sorted(
                    (e for e in self._entries if e.priority < priority),
                    key=lambda e: (e.priority,
                                   -(e.deadline if e.deadline is not None
                                     else float("inf")),
                                   -e.seq))
                freed = 0
                evicted = []
                for v in victims:
                    if freed >= over:
                        break
                    freed += v.remaining
                    evicted.append(v)
                if freed >= over:
                    for v in evicted:
                        self._entries.remove(v)
                        self.stats["evictions"] += 1
                        rejections.append(self._reject(
                            v, "overload",
                            f"evicted for priority-{priority} arrival "
                            f"rid={rid}"))
                else:
                    rejections.append(self._reject(
                        entry, "overload",
                        f"backlog {self.backlog_events()} + {n_events} "
                        f"events exceeds bound {cfg.max_queue_events}"))
                    return AdmitResult(False, tuple(rejections))

        self._entries.append(entry)
        self.stats["admitted"] += 1
        return AdmitResult(True, tuple(rejections))

    # -- deadline expiry & shedding ------------------------------------------

    def expire(self) -> List[Tuple[Any, Rejection]]:
        """Reject every queued entry whose deadline has passed — the
        structured alternative to serving it late (or hanging on it)."""
        now = self.clock()
        dead = [e for e in self._entries
                if e.deadline is not None and e.deadline <= now]
        out = []
        for e in dead:
            self._entries.remove(e)
            out.append(self._reject(
                e, "deadline",
                f"deadline expired in queue ({e.remaining} of its events "
                "ungenerated)"))
        return out

    def shed_below(self, priority: int, reason: str,
                   detail: str) -> List[Tuple[Any, Rejection]]:
        """Shed every queued entry with ``priority < priority`` — the
        degradation ladder's move (lowest priority leaves first)."""
        victims = sorted((e for e in self._entries if e.priority < priority),
                         key=lambda e: (e.priority, e.seq))
        out = []
        for v in victims:
            self._entries.remove(v)
            out.append(self._reject(v, reason, detail))
        return out

    def drain(self, reason: str, detail: str) -> List[Tuple[Any, Rejection]]:
        """Reject EVERYTHING queued (total outage: no healthy replicas)."""
        out = [self._reject(e, reason, detail) for e in self._entries]
        self._entries.clear()
        return out

    # -- continuous batching --------------------------------------------------

    def _order(self) -> List[_Entry]:
        cfg = self.config
        promoted, rest = [], []
        for e in self._entries:
            if cfg.promote_after_steps > 0 \
                    and e.waited_steps >= cfg.promote_after_steps:
                promoted.append(e)
            else:
                rest.append(e)
        promoted.sort(key=lambda e: e.seq)          # FIFO among promoted
        rest.sort(key=lambda e: (
            -e.priority,
            e.deadline if e.deadline is not None else float("inf"),
            e.seq))
        return promoted + rest

    def plan_step(self, buckets: Sequence[int]):
        """Plan the next bucket step: ``(bucket, [(item, start_offset_hint
        is the caller's business — (item, take)), ...])`` or ``None`` when
        nothing is queued.

        PURE with respect to queue state — the engine calls
        :meth:`commit` after the step's dispatch succeeds; a dispatch
        failure (dead replica group) leaves every entry intact so the
        work can be rejected or retried explicitly.
        """
        order = self._order()
        if not order:
            return None
        total = sum(e.remaining for e in order)
        bucket = None
        for b in buckets:
            if b >= total:
                bucket = b
                break
        if bucket is None:
            bucket = max(buckets)
        plan, row = [], 0
        for e in order:
            if row == bucket:
                break
            take = min(bucket - row, e.remaining)
            if take <= 0:
                continue
            plan.append((e, take))
            row += take
        return bucket, plan

    def pop_next(self) -> Optional[Any]:
        """Remove and return the first queued item in scheduling order —
        the slot-pool engines' admission primitive (`serve/engine.py`
        claims one WHOLE request per freed slot; no bucket packing).
        Ages the passed-over entries like :meth:`commit` so the
        promotion rule applies to both front-ends."""
        order = self._order()
        if not order:
            return None
        e = order[0]
        if e.waited_steps >= self.config.promote_after_steps > 0:
            self.stats["promotions"] += 1
        self._entries.remove(e)
        for other in self._entries:
            other.waited_steps += 1
        return e.item

    def commit(self, plan) -> None:
        """Apply a :meth:`plan_step` result after its dispatch succeeded:
        consume the planned events, retire finished entries, and age the
        passed-over ones (feeding the promotion rule)."""
        bucket, assignments = plan
        del bucket
        served = set()
        for e, take in assignments:
            e.remaining -= take
            served.add(id(e))
            if e.waited_steps >= self.config.promote_after_steps > 0:
                self.stats["promotions"] += 1
            e.waited_steps = 0
        self._entries = [e for e in self._entries if e.remaining > 0]
        for e in self._entries:
            if id(e) not in served:
                e.waited_steps += 1
        self.stats["planned_steps"] += 1
