"""Deterministic fault injection for the elastic runtime (paper §5.1).

The paper's cost story hinges on preemptible capacity (V100 spot nodes at
>3x below reserved, `cloud/costs.py`) — which only pays off if training
survives losing nodes.  This module is the TEST SUBSTRATE for that: a
scripted, replayable fault layer that the elastic trainer
(`train/elastic.py`) and the chaos suite (`tests/test_elastic.py`) drive
instead of waiting for real preemptions.

Design constraints, in order:

- **Deterministic.**  Faults fire at exact global STEP indices from a
  :class:`FaultPlan`, never from wall clock or randomness at run time.
  Replaying the same plan against the same seed reproduces the same
  trajectory bit-for-bit (the CI ``elastic-smoke`` job replays a committed
  trace; `tests/test_elastic.py` runs the fast traces twice).
- **Seedable.**  :meth:`FaultPlan.random` derives a plan from a seed via
  ``np.random.default_rng`` — fuzzing stays replayable.
- **Injected at the real seams.**  Preemptions and slow-node stalls are
  injected into the HOST BATCH STREAM (:meth:`FaultInjector.wrap`), so a
  preemption surfaces through `data/pipeline.Prefetcher`'s producer-thread
  error propagation exactly like a real node loss killing the input
  pipeline mid-prefetch; checkpoint corruption runs as a main-thread step
  hook (:meth:`FaultInjector.hook`) so WHICH snapshot gets corrupted is
  deterministic with respect to the async checkpoint writer.

Fault kinds:

``preempt``
    The node is gone.  Raises :class:`Preemption` through the batch
    stream; ``lose_node=True`` means the capacity is lost (the elastic
    trainer re-meshes onto the surviving ``(node, device)`` grid),
    ``False`` means a replacement respawns (restart on the same grid).
``stall``
    A slow node / input hiccup: the stream sleeps ``stall_ms`` before
    yielding that step's batch (on the Prefetcher's producer thread, so
    the stall is visible as consumer ``h2d_wait_ms``).  Numerics are
    unaffected — asserted by the chaos suite.
``corrupt``
    The latest on-disk snapshot is truncated (:func:`corrupt_latest`),
    forcing recovery to fall back to the previous one.

The SERVING side reuses the same plan format against its replica groups
(`serve/replicas.ReplicaFaultInjector`): a ``preempt`` event is a
replica kill (``node`` = replica rank; ``lose_node=True`` means the
replica stays dead, ``False`` means a replacement respawns with a cold
compile cache) and a ``stall`` event is a slow replica (``node`` picks
which one, ``stall_ms`` how slow) — so one trace format, one replay
discipline, and one CI determinism story cover both the training and
the serving chaos suites.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Iterable, Iterator, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("preempt", "stall", "corrupt")


class Preemption(RuntimeError):
    """A scripted node preemption, raised through the batch stream.

    ``step`` is the global step that never ran; ``node`` the dead node's
    row in the ``(node, device)`` mesh; ``lose_node`` whether its capacity
    is gone (shrink) or respawns (restart on the same topology).
    """

    def __init__(self, step: int, node: int = 0, lose_node: bool = True):
        super().__init__(
            f"node {node} preempted before step {step}"
            f" ({'capacity lost' if lose_node else 'respawning'})")
        self.step = int(step)
        self.node = int(node)
        self.lose_node = bool(lose_node)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault at an exact global step index."""
    step: int
    kind: str                    # "preempt" | "stall" | "corrupt"
    node: int = 0                # preempt: which node row dies
    lose_node: bool = True       # preempt: shrink (True) vs respawn (False)
    stall_ms: float = 0.0        # stall: producer-side sleep

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"kind must be one of {FAULT_KINDS}, got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered, replayable trace of :class:`FaultEvent`s.

    Serialises to/from JSON so CI can commit traces
    (``results/elastic_trace.json``) and replay them byte-for-byte.
    """
    events: Tuple[FaultEvent, ...] = ()
    seed: Optional[int] = None          # provenance when built by random()

    def at(self, step: int) -> List[Tuple[int, FaultEvent]]:
        """(index, event) pairs scheduled at ``step``, in plan order."""
        return [(i, e) for i, e in enumerate(self.events) if e.step == step]

    # -- serialisation ------------------------------------------------------

    def to_json(self) -> dict:
        return {"seed": self.seed,
                "events": [dataclasses.asdict(e) for e in self.events]}

    @classmethod
    def from_json(cls, payload: dict) -> "FaultPlan":
        events = tuple(FaultEvent(**e) for e in payload.get("events", ()))
        return cls(events=events, seed=payload.get("seed"))

    def save(self, path: str, extra: Optional[dict] = None):
        payload = dict(self.to_json(), **(extra or {}))
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # -- seedable generation ------------------------------------------------

    @classmethod
    def random(cls, seed: int, steps: int, *, n_preempt: int = 2,
               n_stall: int = 1, n_corrupt: int = 0, nodes: int = 2,
               stall_ms: float = 20.0) -> "FaultPlan":
        """A replayable plan: same (seed, steps, counts) => same plan.

        Fault steps are drawn without replacement from ``[1, steps)`` so
        step 0 (compile + first dispatch) always runs clean.
        """
        rng = np.random.default_rng(seed)
        total = n_preempt + n_stall + n_corrupt
        if steps < 2 or total == 0:
            return cls(events=(), seed=seed)
        picks = sorted(rng.choice(np.arange(1, steps), size=min(
            total, steps - 1), replace=False).tolist())
        events, i = [], 0
        for _ in range(n_preempt):
            if i >= len(picks):
                break
            events.append(FaultEvent(int(picks[i]), "preempt",
                                     node=int(rng.integers(nodes)),
                                     lose_node=bool(rng.integers(2))))
            i += 1
        for _ in range(n_stall):
            if i >= len(picks):
                break
            events.append(FaultEvent(int(picks[i]), "stall",
                                     stall_ms=float(stall_ms)))
            i += 1
        for _ in range(n_corrupt):
            if i >= len(picks):
                break
            events.append(FaultEvent(int(picks[i]), "corrupt"))
            i += 1
        return cls(events=tuple(sorted(events, key=lambda e: e.step)),
                   seed=seed)


def corrupt_latest(ckpt_root: str) -> Optional[int]:
    """Truncate the newest snapshot's array file (a torn write / bad disk).

    Returns the corrupted checkpoint's step, or None when no snapshot
    exists yet.  Recovery (`checkpoint.restore_latest`) must then fall
    back to the previous snapshot — the chaos suite asserts it does.
    """
    from repro.train import checkpoint as ckpt_lib
    steps = ckpt_lib.checkpoint_steps(ckpt_root)
    if not steps:
        return None
    path = os.path.join(ckpt_lib.step_dir(ckpt_root, steps[-1]),
                        "arrays.npz")
    with open(path, "r+b") as f:
        f.truncate(max(os.path.getsize(path) // 2, 1))
    return steps[-1]


class FaultInjector:
    """Fires a :class:`FaultPlan` against a training run, each event once.

    ``wrap`` handles stream-borne faults (stall, preempt) and is re-applied
    to the replayed stream after every recovery — fired events are tracked
    by plan index so a resumed run sailing past an old fault step does not
    re-fire it.  ``hook`` handles ``corrupt`` events on the main thread in
    step order (after the async checkpointer's own hook), waiting for the
    writer to drain first so WHICH snapshot gets corrupted is deterministic.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: List[FaultEvent] = []
        self._done: set = set()

    def pending(self, step: int) -> List[Tuple[int, FaultEvent]]:
        """Unfired (index, event) pairs scheduled at ``step`` — the
        once-only view subclasses and the serve-side injector consume."""
        return [(i, e) for i, e in self.plan.at(step) if i not in self._done]

    def fire(self, idx: int, event: FaultEvent) -> None:
        """Mark plan index ``idx`` fired (it will never fire again) and
        record the event in ``fired`` for replay/determinism asserts."""
        self._done.add(idx)
        self.fired.append(event)

    # internal aliases kept for the call sites below
    _pending = pending
    _fire = fire

    def wrap(self, batches: Iterable[dict],
             start_step: int = 0) -> Iterator[dict]:
        """Wrap a host batch stream starting at global ``start_step``.

        Yield order is preserved; a ``stall`` sleeps before yielding its
        step's batch, a ``preempt`` raises :class:`Preemption` instead of
        yielding it.  Under `data/pipeline.Prefetcher` both happen on the
        producer thread: stalls surface as consumer ``h2d_wait_ms`` and
        the Preemption rides the prefetcher's error propagation to the
        step loop — already-queued earlier batches still get consumed.
        """
        def gen():
            for i, batch in enumerate(batches):
                step = start_step + i
                for idx, ev in self._pending(step):
                    if ev.kind == "stall":
                        self._fire(idx, ev)
                        time.sleep(ev.stall_ms / 1e3)
                    elif ev.kind == "preempt":
                        self._fire(idx, ev)
                        raise Preemption(step, ev.node, ev.lose_node)
                yield batch
        return gen()

    def hook(self, checkpointer):
        """An `Engine.fit` hook firing ``corrupt`` events deterministically.

        Runs on the main thread after each step's dispatch; drains the
        async writer queue first so the "latest" snapshot at fire time is
        well-defined regardless of writer-thread scheduling.
        """
        def _hook(step: int, state):
            del state
            for idx, ev in self._pending(step):
                if ev.kind != "corrupt":
                    continue
                self._fire(idx, ev)
                checkpointer.wait()
                corrupt_latest(checkpointer.root)
        return _hook
