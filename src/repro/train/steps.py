"""jit-able train / serve step factories shared by every architecture.

This is the paper's "custom training loop" discipline applied framework-wide:
the ENTIRE step (loss, backward, clip, optimizer, any RNG) lives in one
compiled program, so nothing sequential is left on the host (paper §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import optimizers as opt_lib


def _split_microbatches(batch, n: int):
    """Reshape every batch leaf to a leading microbatch axis.

    The batch dim is dim 0 for every leaf except mrope ``positions``
    (3, B, S), whose batch dim is 1."""
    b0 = batch[next(k for k in ("tokens", "image", "audio_emb")
                    if k in batch)].shape[0]

    def leaf(k, x):
        if x.shape[0] == b0:
            return x.reshape(n, b0 // n, *x.shape[1:])
        assert x.ndim >= 2 and x.shape[1] == b0, (k, x.shape)
        y = x.reshape(x.shape[0], n, b0 // n, *x.shape[2:])
        return jnp.moveaxis(y, 1, 0)

    return {k: leaf(k, v) for k, v in batch.items()}


def make_train_step(model, cfg, optimizer, policy, mesh=None,
                    clip_norm: float = 1.0, remat: bool = True,
                    microbatches: int = 1, seq_shard: bool = True,
                    grad_reduce=None):
    """One fully-compiled train step (the paper's fused-loop discipline).

    ``microbatches`` > 1 runs gradient accumulation INSIDE the step via
    lax.scan — §Perf H6: live activation footprint shrinks by the
    microbatch factor while total compute/collective bytes are unchanged
    (the grad accumulator is param-sized and stays sharded like params).

    ``seq_shard``: residual-stream sequence sharding is ON for training by
    default (remat-saved activations shrink by the model-axis factor) and
    OFF for prefill/serve (§Perf: it only buys gathers there).  The flag
    is applied at TRACE time so it holds wherever the step is jitted.

    ``grad_reduce``: applied to the (accumulated) gradients before
    clipping and the optimizer update.  The data-parallel engine's
    custom loop passes an explicit psum-mean here (the step then runs as
    a per-device program under shard_map); leave ``None`` under jit,
    where GSPMD inserts the gradient all-reduce itself.  A reducer
    exposing ``wrap_params`` (``collectives.OverlapReduce``,
    ``grad_reduce="overlap"``) is applied to the params INSIDE the loss
    instead, so each bucket's collective issues mid-backward; the
    post-hoc call is then the identity.
    """
    from repro.parallel import sharding as sharding_lib

    wrap_params = getattr(grad_reduce, "wrap_params", None)

    def grad_of(params, mb):
        def loss(p):
            if wrap_params is not None:
                p = wrap_params(p)
            with sharding_lib.seq_sharding(seq_shard):
                return model.loss_fn(p, mb, cfg, policy=policy, mesh=mesh,
                                     remat=remat)
        return jax.value_and_grad(loss, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def body(acc, mb):
                g_acc, l_acc = acc
                (l, _), g = grad_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, l), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            l = l / microbatches
            metrics = {}
        else:
            (l, metrics), grads = grad_of(params, batch)
        if grad_reduce is not None:
            grads = grad_reduce(grads)
        if clip_norm:
            grads, gnorm = opt_lib.clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = opt_lib.global_norm(grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        metrics = dict(metrics, loss=l, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def grad_reduce_traffic(model, cfg, bucket_bytes=None) -> dict:
    """LM analogue of ``adversarial.grad_reduce_traffic``: one gradient
    reduction per step, param-tree-sized.  Feeds cloud/interconnect.
    ``bucket_bytes`` adds the overlap reducer's per-round tail-bucket
    bytes (see ``adversarial.grad_reduce_traffic``)."""
    import numpy as np
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0), cfg))
    leaves = jax.tree.leaves(shapes)
    nbytes = int(sum(np.prod(s.shape) * s.dtype.itemsize for s in leaves))
    out = {"rounds": [("step", nbytes)], "bytes_per_step": nbytes,
           "largest_round_bytes": nbytes}
    if bucket_bytes is not None:
        from repro.parallel import collectives
        out["tail_bytes"] = {"step": max(
            int(sum(np.prod(leaves[i].shape) * leaves[i].dtype.itemsize
                    for i in bucket))
            for bucket in collectives.plan_buckets(leaves, bucket_bytes))}
    return out


def make_serve_step(model, cfg, policy, mesh=None, window: int = 0):
    def serve_step(params, tokens1, cache, pos, extra):
        logits, cache = model.decode_step(
            params, tokens1, cache, pos, cfg, policy=policy, mesh=mesh,
            window=window, positions=extra.get("positions"))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def make_prefill_chunk_step(model, cfg, policy, mesh=None, window: int = 0):
    """Chunked batched serving prefill: one launch ingests a (B, C)
    prompt chunk per slot (ragged ``lens``; 0 = inactive slot) and
    returns each active slot's next token sampled from its last valid
    prompt position.  Only built for archs exporting ``prefill_chunk``."""
    def prefill_chunk_step(params, tokens, cache, pos, lens, extra):
        logits, cache = model.prefill_chunk(
            params, tokens, cache, pos, lens, cfg, policy=policy, mesh=mesh,
            window=window, positions=extra.get("positions"))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_chunk_step


def make_prefill_step(model, cfg, policy, mesh=None, window: int = 0):
    def prefill_step(params, batch):
        main = batch.get("audio_emb", batch.get("tokens"))
        return model.prefill(params, main, cfg, policy=policy, mesh=mesh,
                             window=window, positions=batch.get("positions"))

    return prefill_step
