"""Unified data-parallel training engine: the paper's two loop strategies.

The source paper's central comparison (§3-§4, Figs. 1-2) is between
TensorFlow's *built-in* distribution strategy (``MirroredStrategy`` /
``tf.distribute`` placing per-replica batches automatically) and a
*custom* training loop that controls exactly which elements land on each
worker.  This module is the JAX-native version of that comparison, built
from the pieces the repo already had:

- ``builtin`` loop — ``jax.jit`` + ``NamedSharding`` over the mesh's data
  axes.  The step is written as a GLOBAL-batch program; the XLA GSPMD
  partitioner decides how per-device batches are placed and inserts the
  gradient all-reduce itself (the ``tf.distribute`` analogue).
- ``custom`` loop — ``shard_map`` over the same mesh.  The step body is a
  PER-DEVICE program: each replica receives an explicitly-assigned batch
  shard, folds its replica index into the RNG so it draws its own
  generator inputs (the paper's "every replica initialises its own
  inputs"), computes local gradients, and reduces them with an explicit
  ``psum``-based mean before the (replicated) optimizer update.

Both loops share the rest of the paper's optimisations: the fully-fused
Algorithm-1 step (`core/adversarial.py`), gradient accumulation via
``microbatches``, mixed-precision policies (`substrate/precision.py`),
and double-buffered host->device prefetch (`data/pipeline.py`).

Public API
----------

``Task``
    A workload the engine can train: ``init(rng) -> state`` plus a
    ``make_step(grad_reduce, mesh)`` factory returning a pure
    ``step(state, batch, rng) -> (state, metrics)``.  Two constructors
    are provided: :func:`gan_task` (the paper's 3DGAN, Algorithm 1) and
    :func:`lm_task` (any LM arch via ``train/steps.py``).

``Engine``
    Binds a mesh and a loop mode, and compiles/runs tasks::

        from repro.launch.mesh import make_dev_mesh
        from repro.optim import optimizers as opt_lib
        from repro.train import engine as engine_lib
        from repro.configs import calo3dgan

        cfg = calo3dgan.reduced()
        task = engine_lib.gan_task(cfg, opt_lib.rmsprop(1e-4),
                                   opt_lib.rmsprop(1e-4))
        eng = engine_lib.Engine(make_dev_mesh(), loop="custom")
        state, metrics = eng.fit(task, sim.batches(cfg.batch_size),
                                 steps=100, rng=jax.random.key(0))

    Lower-level pieces (``init_state`` / ``compile_step`` / ``data_iter``)
    are exposed for benchmarks, and :meth:`Engine.build` produces an
    AOT-lowerable artifact for the multi-pod dry-run / weak-scaling
    compile studies.

The engine implements PURE data parallelism — parameters and optimizer
state replicated, batch sharded — which is exactly the paper's mirrored
strategy.  Model/FSDP sharding for the big LM archs keeps living in
``launch/build.py``; the engine is the substrate the scaling PRs
(multi-host, async checkpointing, pipeline stages) plug into.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Iterable, Iterator, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data import pipeline
from repro.parallel import collectives, sharding
from repro.train import metrics as metrics_lib
from repro.train import steps as steps_lib

LOOPS = ("builtin", "custom")

# batch leaves whose batch dimension is not dim 0 (mrope ``positions``
# carries batch on dim 1); tasks may override via Task.batch_dims
DEFAULT_BATCH_DIMS: Mapping[str, int] = {"positions": 1}


class LMState(NamedTuple):
    """Replicated LM train state carried through the engine loop."""
    params: Any
    opt_state: Any


@dataclasses.dataclass(frozen=True)
class Task:
    """A trainable workload, decoupled from how the engine distributes it.

    ``make_step(grad_reduce, mesh)`` must return a PURE function
    ``step(state, batch, rng) -> (state, metrics)``:

    - in the builtin loop it is called with ``grad_reduce=None`` and the
      real mesh (the step may place sharding constraints; GSPMD inserts
      gradient all-reduces automatically);
    - in the custom loop it is called with ``mesh=None`` and a
      ``grad_reduce`` callable (psum-mean over the data axes) that the
      step MUST apply to gradients before every optimizer update.
    """
    name: str
    init: Callable[[jax.Array], Any]
    make_step: Callable[..., Callable]
    batch_dims: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_BATCH_DIMS))


def gan_task(cfg, g_optimizer, d_optimizer, *, policy=None,
             microbatches: int = 1) -> Task:
    """The paper's workload: 3DGAN Algorithm 1 as a fully-fused step.

    Example::

        task = gan_task(calo3dgan.config(), opt_lib.rmsprop(1e-4),
                        opt_lib.rmsprop(1e-4), policy=get_policy("bf16"))
    """
    from repro.core import adversarial

    def init(rng):
        return adversarial.init_state(rng, cfg, g_optimizer, d_optimizer,
                                      policy=policy)

    def make_step(grad_reduce=None, mesh=None):
        return adversarial.make_fused_step(
            cfg, g_optimizer, d_optimizer, mesh=mesh, policy=policy,
            grad_reduce=grad_reduce, microbatches=microbatches)

    return Task("gan", init, make_step)


def lm_task(model, cfg, optimizer, *, policy, microbatches: int = 1,
            remat: bool = True) -> Task:
    """Any LM architecture routed through ``steps.make_train_step``.

    The engine is pure data parallelism, so residual-stream sequence
    sharding stays off and params are replicated.

    Example::

        cfg = config_base.reduced_config("qwen2-1.5b")
        task = lm_task(api.get_model(cfg), cfg, opt_lib.adamw(3e-4),
                       policy=get_policy("bf16"))
    """

    def init(rng):
        params = model.init(rng, cfg)
        return LMState(params, optimizer.init(params))

    def make_step(grad_reduce=None, mesh=None):
        inner = steps_lib.make_train_step(
            model, cfg, optimizer, policy, mesh=mesh, remat=remat,
            microbatches=microbatches, seq_shard=False,
            grad_reduce=grad_reduce)

        def step(state, batch, rng):
            del rng  # LM loss is deterministic given the batch
            params, opt_state, metrics = inner(state.params,
                                               state.opt_state, batch)
            return LMState(params, opt_state), metrics

        return step

    return Task("lm", init, make_step)


@dataclasses.dataclass
class Built:
    """AOT-lowerable step artifact (mirrors launch.build.BuiltStep)."""
    fn: Any                 # the jitted step
    args: tuple             # ShapeDtypeStruct args for .lower(*args)
    kind: str

    def lower(self):
        return self.fn.lower(*self.args)


class Engine:
    """Data-parallel training engine bound to one mesh and one loop mode.

    Parameters
    ----------
    mesh
        The device mesh.  Batches are sharded over its data axes
        (``("pod", "data")`` when present), params stay replicated.
    loop
        ``"builtin"`` (jit + NamedSharding, compiler-placed batches) or
        ``"custom"`` (shard_map, explicit per-device batches + psum).
    dp_axes
        Override which mesh axes carry the batch.  The GAN dry-run path
        uses ``tuple(mesh.axis_names)`` — every chip is a pure-DP
        replica, exactly as the paper runs 3DGAN on 256/512 chips.
    donate
        Donate the input state buffers to each step (default True).
    grad_reduce
        Reduction strategy for the gradients (``"flat"`` |
        ``"hierarchical"`` | ``"overlap"`` | a callable).  In the custom
        loop ``flat`` is the classic psum-mean over all data axes,
        ``hierarchical`` is the 2-level cluster schedule (intra-node psum
        over the fast axis, bucketed psums over the slow ``node`` axis —
        see ``collectives.make_grad_reduce``), and ``overlap`` issues the
        same hierarchical buckets in reverse parameter order from INSIDE
        the backward pass (``collectives.OverlapReduce`` — each bucket's
        collective fires as soon as its gradients exist); all are
        numerically interchangeable.  In the builtin loop GSPMD owns
        reduction placement (the paper's point about built-in
        strategies), so ``hierarchical`` only regroups the gradient
        stream into buckets (``collectives.bucket_transform``) and
        ``overlap`` does the same regrouping inside the backward
        (``collectives.overlap_transform``) — identity numerics either
        way.
    bucket_mb
        Inter-node bucket size in MiB for the hierarchical and overlap
        strategies.
    """

    def __init__(self, mesh: Mesh, loop: str = "builtin", *,
                 dp_axes: Optional[tuple] = None, donate: bool = True,
                 grad_reduce="flat", bucket_mb: float = 4.0):
        if loop not in LOOPS:
            raise ValueError(f"loop must be one of {LOOPS}, got {loop!r}")
        if (isinstance(grad_reduce, str)
                and grad_reduce not in collectives.GRAD_REDUCE_STRATEGIES):
            raise ValueError(
                f"grad_reduce must be one of "
                f"{collectives.GRAD_REDUCE_STRATEGIES} or a callable, "
                f"got {grad_reduce!r}")
        self.mesh = mesh
        self.loop = loop
        self.donate = donate
        self.grad_reduce = grad_reduce
        self.bucket_bytes = int(bucket_mb * (1 << 20))
        axes = dp_axes if dp_axes is not None else sharding.batch_axes(mesh)
        self.axes: tuple = tuple(axes) if axes else ()
        if grad_reduce == "hierarchical" and loop == "custom" \
                and len(self.axes) < 2:
            raise ValueError(
                "hierarchical grad_reduce needs a 2-level mesh "
                f"(node, device); this engine's data axes are {self.axes} "
                "— build the mesh with launch.mesh.make_node_mesh")
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        # filled in by fit(): dispatch + input-pipeline observability for
        # the async loop (h2d_wait_ms = consumer-side stall the prefetch
        # overlap failed to hide, per logging window and in total)
        self.last_fit_stats = {"steps": 0, "host_transfers": 0,
                               "h2d_wait_ms": 0.0, "h2d_wait_ms_windows": []}

    # -- batch placement ----------------------------------------------------

    def batch_pspecs(self, batch_like: Mapping[str, Any],
                     batch_dims: Optional[Mapping[str, int]] = None) -> dict:
        """PartitionSpec per batch leaf: data axes on the batch dim.

        In the builtin loop a leaf whose batch dim does not divide the
        data-axis size is silently replicated (GSPMD handles it); the
        custom loop requires exact divisibility — per-device batch
        assignment is the point — and raises ``ValueError`` otherwise.
        """
        dims = dict(DEFAULT_BATCH_DIMS, **(batch_dims or {}))
        out = {}
        for k, v in batch_like.items():
            bdim = dims.get(k, 0)
            entries = [None] * len(v.shape)
            divisible = self.axes and v.shape[bdim] % self.n_shards == 0
            if self.axes and not divisible and self.loop == "custom":
                raise ValueError(
                    f"custom loop requires batch dim {bdim} of {k!r} "
                    f"(= {v.shape[bdim]}) divisible by the "
                    f"{self.n_shards} data shards")
            if divisible and v.shape[bdim] > 1:
                entries[bdim] = (self.axes if len(self.axes) > 1
                                 else self.axes[0])
            out[k] = P(*entries)
        return out

    def batch_shardings(self, batch_like: Mapping[str, Any],
                        batch_dims: Optional[Mapping[str, int]] = None) -> dict:
        """NamedSharding per batch leaf — feed to ``pipeline.prefetch``."""
        return {k: NamedSharding(self.mesh, s)
                for k, s in self.batch_pspecs(batch_like, batch_dims).items()}

    def data_iter(self, batches: Iterable[dict], *, size: int = 2,
                  batch_dims: Optional[Mapping[str, int]] = None) -> Iterator[dict]:
        """Double-buffered host->device prefetch with per-mode sharding.

        Wraps ``data.pipeline.prefetch``: the producer thread issues the
        ``device_put`` for the NEXT batch (sharded over the data axes)
        while the CURRENT step runs — the paper's host/accelerator
        overlap, identical for both loops.  The returned ``Prefetcher``
        exposes ``stats["h2d_wait_ms"]`` (consumer stalls).
        """
        it = iter(batches)
        try:
            first = next(it)
        except StopIteration:
            return pipeline.prefetch(iter(()))
        shardings = self.batch_shardings(first, batch_dims)
        return pipeline.prefetch(itertools.chain([first], it), size=size,
                                 sharding=shardings)

    # -- state & step compilation -------------------------------------------

    def state_pspecs(self, state_like):
        """PartitionSpec per state leaf: replicated everywhere EXCEPT
        ZeRO-1 shard-major leaves — arrays under an optimizer's
        ``"zero1"`` subtree whose leading dim equals the data-shard count
        (`optim.optimizers.zero1`'s ``(N, L)`` layout) are sharded over
        the data axes on dim 0.  That placement is the ZeRO-1 memory
        story: each device physically holds 1/N of the master params and
        optimizer moments."""
        if not self.axes or self.n_shards <= 1:
            return jax.tree.map(lambda _: P(), state_like)
        ax = self.axes if len(self.axes) > 1 else self.axes[0]

        def spec(path, leaf):
            in_zero1 = any(getattr(e, "key", None) == "zero1" for e in path)
            if in_zero1 and getattr(leaf, "ndim", 0) >= 1 \
                    and leaf.shape[0] == self.n_shards:
                return P(ax)
            return P()

        return jax.tree_util.tree_map_with_path(spec, state_like)

    def _state_shardings(self, state_like):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_pspecs(state_like),
                            is_leaf=lambda x: isinstance(x, P))

    def init_state(self, task: Task, rng: jax.Array):
        """Initialise the task state: replicated over the whole mesh,
        except ZeRO-1 state shards (see :meth:`state_pspecs`)."""
        state = task.init(rng)
        return jax.device_put(state, self._state_shardings(state))

    def _grad_reduce(self, tree):
        """Explicit gradient reduction for the custom loop, per strategy:
        flat psum-mean over all data axes, or the hierarchical 2-level
        bucketed schedule (collectives.make_grad_reduce)."""
        if not self.axes:
            return tree
        fn = collectives.make_grad_reduce(self.grad_reduce, self.mesh,
                                          self.axes,
                                          bucket_bytes=self.bucket_bytes)
        return fn(tree)

    def compile_step(self, task: Task, batch_like: Mapping[str, Any]):
        """Compile ``step(state, batch, rng) -> (state, metrics)``.

        ``batch_like`` fixes the batch pytree (real arrays or
        ``ShapeDtypeStruct`` leaves are both fine — only shapes are read).
        State and metrics are replicated in both modes; the returned
        callable donates its state argument when ``donate=True``.
        """
        rep = NamedSharding(self.mesh, P())
        b_specs = self.batch_pspecs(batch_like, task.batch_dims)
        b_shard = {k: NamedSharding(self.mesh, s) for k, s in b_specs.items()}
        donate = (0,) if self.donate else ()
        # state placement: replicated except ZeRO-1 shard-major leaves
        state_shapes = jax.eval_shape(lambda: task.init(jax.random.key(0)))
        s_specs = self.state_pspecs(state_shapes)
        s_shard = self._state_shardings(state_shapes)

        if self.loop == "builtin":
            # GSPMD inserts the gradient all-reduce itself; hierarchical
            # mode only re-expresses the grads at bucket granularity.
            # A user-supplied callable is honored exactly as in the
            # custom loop.
            if callable(self.grad_reduce):
                reduce = self.grad_reduce
            elif self.grad_reduce == "hierarchical":
                reduce = collectives.bucket_transform(self.bucket_bytes)
            elif self.grad_reduce == "overlap":
                reduce = collectives.overlap_transform(self.bucket_bytes)
            else:
                reduce = None
            step = task.make_step(grad_reduce=reduce, mesh=self.mesh)
            return jax.jit(step, in_shardings=(s_shard, b_shard, rep),
                           out_shardings=(s_shard, rep),
                           donate_argnums=donate)

        # the reducer OBJECT is passed through (not a bound method) so
        # the overlap strategy's wrap_params protocol reaches the step
        reduce = (collectives.make_grad_reduce(
            self.grad_reduce, self.mesh, self.axes,
            bucket_bytes=self.bucket_bytes) if self.axes
            else (self.grad_reduce if callable(self.grad_reduce) else None))
        local = task.make_step(grad_reduce=reduce, mesh=None)
        axes, shape = self.axes, dict(self.mesh.shape)

        def local_step(state, batch, rng):
            if axes:
                # each replica draws its OWN generator inputs (paper §3)
                idx = jnp.int32(0)
                for a in axes:
                    idx = idx * shape[a] + jax.lax.axis_index(a)
                rng = jax.random.fold_in(rng, idx)
            state, metrics = local(state, batch, rng)
            if axes:    # per-replica scalars -> global means for logging
                metrics = jax.lax.pmean(metrics, axes)
            return state, metrics

        smapped = shard_map(local_step, mesh=self.mesh,
                            in_specs=(s_specs, b_specs, P()),
                            out_specs=(s_specs, P()), check_rep=False)
        return jax.jit(smapped, in_shardings=(s_shard, b_shard, rep),
                       out_shardings=(s_shard, rep), donate_argnums=donate)

    def build(self, task: Task, batch_shapes: Mapping[str, Any]) -> Built:
        """AOT artifact: jitted step + ShapeDtypeStruct args for .lower().

        Used by the weak-scaling benchmark and the multi-pod dry-run to
        compile either loop for meshes far larger than this host.
        """
        fn = self.compile_step(task, batch_shapes)
        state_shapes = jax.eval_shape(lambda: task.init(jax.random.key(0)))
        rng_shape = jax.eval_shape(lambda: jax.random.key(0))
        return Built(fn, (state_shapes, batch_shapes, rng_shape),
                     f"{task.name}_{self.loop}")

    # -- the training loop ---------------------------------------------------

    def fit(self, task: Task, batches: Iterable[dict], steps: int, *,
            rng: jax.Array, state=None, log=None, log_every: int = 1,
            sync_every: Optional[int] = None, prefetch_size: int = 2,
            start_step: int = 0, hooks: tuple = ()):
        """Run ``steps`` training steps; returns (state, last_metrics).

        Composes the whole paper pipeline: replicated init, compiled
        step (builtin or custom), sharded double-buffered prefetch, and
        windowed metric logging via ``log.log(i, **window_means)``.

        The loop is ASYNC-DISPATCH: per-step metrics are folded into
        device-side sums (`metrics_lib.MetricAccumulator`) and the host
        transfer happens once every ``log_every`` steps, so with
        ``log_every > 1`` no step blocks on a device->host sync — the
        device runs ahead of the Python loop and the prefetch overlap the
        engine was built for actually materialises.  ``log_every=1``
        reproduces the old per-step logging cadence.

        ``sync_every`` is the escape hatch: force a device sync every N
        steps to bound run-ahead (keeps the dispatch queue shallow and
        device errors attributable) independently of the logging window.

        **Elastic resume.**  Per-step RNG is BIT-PINNED to the global step
        index: the fit key splits once into (init_key, step_rng) and step
        ``g`` always uses ``fold_in(step_rng, g)`` — a pure function of
        (rng, g), independent of how many fit() calls the run was chopped
        into.  A preempted run that restores checkpointed ``state`` and
        passes ``start_step=<completed steps>`` with the SAME ``rng``
        replays the exact key sequence the uninterrupted run would have
        used (`train/elastic.py` relies on this for bit-identical
        recovery).  ``hooks`` are callables ``hook(global_step, state)``
        invoked after each step's dispatch (async, non-blocking) — the
        async checkpointer's cadence hook and the fault injector's
        corrupt hook plug in here.

        ``self.last_fit_stats`` records {"steps", "host_transfers",
        "h2d_wait_ms", "h2d_wait_ms_windows"} for the most recent fit —
        the dispatch-count observability the async tests assert on, plus
        the per-window consumer stall of the device prefetcher (time a
        step had to WAIT for its batch; ~0 when the producer-side
        ``device_put`` fully overlaps compute).
        """
        if log_every < 1:
            raise ValueError(f"log_every must be >= 1, got {log_every}")
        it = iter(batches)
        try:
            first = next(it)
        except StopIteration:
            raise ValueError("fit() got an empty batches iterable") from None
        step = self.compile_step(task, first)
        # init/step keys derive from ONE split of the fit key; per-step
        # keys fold in the GLOBAL step index so a resumed fit (same rng,
        # start_step = completed steps) replays the identical sequence
        init_key, step_rng = jax.random.split(rng)
        if state is None:
            state = self.init_state(task, init_key)
        stream = self.data_iter(itertools.chain([first], it),
                                size=prefetch_size,
                                batch_dims=task.batch_dims)
        metrics: dict = {}
        acc = metrics_lib.MetricAccumulator()
        transfers = 0
        last = -1
        h2d_windows: list = []
        h2d_marked = 0.0

        def _close_window():
            nonlocal h2d_marked
            waited = stream.stats["h2d_wait_ms"]
            h2d_windows.append(waited - h2d_marked)
            h2d_marked = waited

        for i, batch in zip(range(steps), stream):
            last = i
            gstep = start_step + i
            k = jax.random.fold_in(step_rng, gstep)
            state, metrics = step(state, batch, k)
            for hook in hooks:
                hook(gstep, state)
            if log is not None:
                acc.update(metrics)
                if (i + 1) % log_every == 0 or i == steps - 1:
                    log.log(gstep, **acc.means())  # ONE transfer per window
                    transfers += 1
                    acc.reset()
                    _close_window()
            if sync_every is not None and (i + 1) % sync_every == 0:
                jax.block_until_ready(metrics)
        if log is not None and acc.count:
            # the batch stream ran dry before ``steps``: flush the
            # trailing partial window so no step goes unlogged
            log.log(start_step + last, **acc.means())
            transfers += 1
            _close_window()
        self.last_fit_stats = {
            "steps": last + 1, "host_transfers": transfers,
            "h2d_wait_ms": stream.stats["h2d_wait_ms"],
            "h2d_put_ms": stream.stats["put_ms"],
            "h2d_wait_ms_windows": h2d_windows,
        }
        return state, metrics
