"""Elastic preemption-tolerant training (paper §5.1's cost story, made real).

`cloud/costs.py` pins preemptible V100 capacity at >3x below reserved and
`cloud/planner.recommend` already picks it — but that row of the cost
frontier is only reachable if training SURVIVES losing nodes.
:class:`ElasticEngine` closes that gap: it drives `train/engine.Engine`
segments under the async checkpointer (`train/checkpoint.py`) and, when a
:class:`~repro.train.faults.Preemption` surfaces through the batch
stream, recovers and resumes:

1. **flush** — drain the async writer so the newest snapshot is on disk;
2. **re-mesh** — if the dead node's capacity is lost, rebuild the
   ``(node, device)`` mesh over the survivors
   (`launch.mesh.shrink_node_mesh` semantics via ``make_node_mesh`` on
   the reduced grid) and a fresh Engine over it;
3. **reshard** — `checkpoint.restore_latest` (corrupt snapshots fall back
   to the previous one) and ``device_put`` the state replicated onto the
   new mesh;
4. **resume bit-pinned** — ``Engine.fit(start_step=<ckpt step>)`` with
   the SAME run rng replays the exact per-step key sequence (fold_in of
   the global step), and the caller-supplied ``make_batches(start)``
   replays the data stream — so a builtin-loop run reaches final losses
   bit-identical to an uninterrupted one (custom-loop: within float
   tolerance after a re-mesh, because the replica count changes which
   replica-index keys the generator noise folds in).

The report it returns (recoveries, lost steps, recovery seconds,
fallbacks, re-meshes) is what `tools/run_elastic.py` turns into
``results/BENCH_elastic.json`` — the measured elastic overhead that
`cloud/planner.apply_elastic_overhead` folds back into the frontier.
"""
from __future__ import annotations

import signal as signal_lib
import time
from typing import Callable, Iterable, Optional, Sequence

import jax
import numpy as np

from repro.launch import mesh as mesh_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import engine as engine_lib
from repro.train.faults import FaultInjector, Preemption


def _zeros_template(task, rng):
    """A host-side zeros pytree shaped like the task state — the restore
    template (abstract init: no device compute, no real params)."""
    shapes = jax.eval_shape(task.init, rng)
    return jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), shapes)


class ElasticEngine:
    """Preemption-tolerant wrapper around `Engine.fit` segments.

    Parameters mirror :class:`~repro.train.engine.Engine` plus the
    checkpoint policy.  ``nodes`` x ``devices_per_node`` is the STARTING
    virtual topology; preemptions with ``lose_node=True`` shrink it one
    node row at a time (never below one node — a last-node preemption
    restarts on the same grid, modelling a respawned replacement).
    """

    def __init__(self, nodes: int, devices_per_node: int, *,
                 loop: str = "builtin", ckpt_dir: str, ckpt_every: int = 2,
                 keep: int = 3, grad_reduce="flat", bucket_mb: float = 4.0,
                 donate: bool = True, prefetch_size: int = 2,
                 ckpt_extra: Optional[dict] = None, ckpt_retries: int = 0,
                 ckpt_mirror: Optional[str] = None):
        self.nodes = int(nodes)
        self.devices_per_node = int(devices_per_node)
        self.loop = loop
        self.ckpt_every = int(ckpt_every)
        self.grad_reduce = grad_reduce
        self.bucket_mb = bucket_mb
        self.donate = donate
        self.prefetch_size = prefetch_size
        self.ckpt = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep,
                                               extra=dict(ckpt_extra or {}),
                                               retries=ckpt_retries,
                                               mirror=ckpt_mirror)

    def _engine(self) -> engine_lib.Engine:
        mesh = mesh_lib.make_node_mesh(self.nodes, self.devices_per_node)
        return engine_lib.Engine(mesh, self.loop,
                                 dp_axes=("node", "device"),
                                 donate=self.donate,
                                 grad_reduce=self.grad_reduce,
                                 bucket_mb=self.bucket_mb)

    def fit(self, task, make_batches: Callable[[int], Iterable[dict]],
            steps: int, *, rng: jax.Array,
            injector: Optional[FaultInjector] = None, log=None,
            log_every: int = 1,
            handle_signals: Optional[Sequence[int]] = None,
            resume: bool = False):
        """Train ``steps`` global steps, riding through scripted faults.

        ``make_batches(start)`` must return the host batch stream for
        global steps ``start, start+1, ...`` — the deterministic-replay
        contract (a seeded generator with a skip, or a list slice).
        Returns ``(state, report)``.

        ``handle_signals`` (e.g. ``(signal.SIGTERM, signal.SIGINT)``)
        installs wall-clock preemption handlers for the duration of the
        fit: the cloud's shutdown warning is converted into the SAME
        deterministic :class:`Preemption` path as a scripted fault — the
        handler only sets a flag; at the NEXT step boundary the engine
        snapshots the completed state, flushes the writer, and exits 0
        (``SystemExit``).  A relaunch with the same arguments resumes
        from that snapshot bit-pinned, exactly like a scripted
        ``lose_node=False`` preemption.  Previous handlers are restored
        on exit.

        ``resume=True`` restores the newest valid snapshot (primary or
        mirror) from ``ckpt_dir`` before the first step — how the
        respawned job after a signal exit (or any crash) picks the run
        back up; a missing/empty checkpoint dir just starts from step 0.
        """
        eng = self._engine()
        self.ckpt.extra["topology"] = [self.nodes, self.devices_per_node]
        hooks = [self.ckpt.hook(self.ckpt_every)]
        if injector is not None:
            hooks.append(injector.hook(self.ckpt))
        template = _zeros_template(task, jax.random.key(0))

        self._signal: Optional[int] = None
        installed = {}
        if handle_signals:
            def _on_signal(signum, frame):
                del frame               # async-signal-safe: flag only
                self._signal = signum

            def _signal_hook(step: int, state):
                # step boundary: convert the flag into the Preemption
                # path with a snapshot of the COMPLETED state first
                if self._signal is not None:
                    self.ckpt.save(step + 1, state)
                    raise Preemption(step + 1, node=0, lose_node=False)

            for s in handle_signals:
                installed[s] = signal_lib.signal(s, _on_signal)
            hooks.append(_signal_hook)

        report = {"recoveries": [], "lost_steps": 0, "recovery_s": 0.0,
                  "fallbacks": 0, "remeshes": 0, "restarts": 0,
                  "preemptions": 0}
        try:
            return self._fit_loop(task, make_batches, steps, rng, injector,
                                  log, log_every, eng, hooks, template,
                                  report, resume)
        finally:
            for s, h in installed.items():
                signal_lib.signal(s, h)

    def _fit_loop(self, task, make_batches, steps, rng, injector, log,
                  log_every, eng, hooks, template, report, resume):
        state, metrics, start = None, {}, 0
        if resume:
            ckpt_step, tree, _man, skipped = \
                ckpt_lib.restore_latest_mirrored(
                    self.ckpt.root, self.ckpt.mirror, template,
                    reshard=ckpt_lib.zero1_reshard)
            report["fallbacks"] += skipped
            if tree is not None:
                state = jax.device_put(tree, eng._state_shardings(tree))
                start = ckpt_step
                report["resumed_from"] = ckpt_step
        while start < steps:
            stream = make_batches(start)
            if injector is not None:
                stream = injector.wrap(stream, start_step=start)
            try:
                state, metrics = eng.fit(
                    task, stream, steps - start, rng=rng, state=state,
                    start_step=start, hooks=tuple(hooks), log=log,
                    log_every=log_every, prefetch_size=self.prefetch_size)
                start = steps
            except Preemption as p:
                t0 = time.perf_counter()
                self.ckpt.wait()            # newest snapshot is on disk
                if self._signal is not None:
                    # wall-clock preemption: snapshot is flushed; hand
                    # the machine back with a clean exit (the respawned
                    # job resumes from the checkpoint)
                    print(f"elastic: signal {self._signal} -> "
                          f"checkpointed step {p.step}, exiting 0",
                          flush=True)
                    raise SystemExit(0)
                report["preemptions"] += 1
                if p.lose_node and self.nodes > 1:
                    self.nodes -= 1         # capacity gone: re-mesh
                    report["remeshes"] += 1
                    eng = self._engine()
                    self.ckpt.extra["topology"] = [self.nodes,
                                                   self.devices_per_node]
                else:                       # replacement respawns
                    report["restarts"] += 1
                ckpt_step, tree, _man, skipped = \
                    ckpt_lib.restore_latest_mirrored(
                        self.ckpt.root, self.ckpt.mirror, template,
                        reshard=ckpt_lib.zero1_reshard)
                report["fallbacks"] += skipped
                if tree is None:            # no valid snapshot: from scratch
                    state, start = None, 0
                else:                       # reshard onto the new mesh
                    state = jax.device_put(
                        tree, eng._state_shardings(tree))
                    start = ckpt_step
                dt = time.perf_counter() - t0
                report["recovery_s"] += dt
                report["lost_steps"] += p.step - start
                report["recoveries"].append({
                    "preempt_step": p.step, "node": p.node,
                    "lose_node": p.lose_node, "resume_step": start,
                    "lost_steps": p.step - start, "recovery_s": dt,
                    "topology": [self.nodes, self.devices_per_node],
                    "ckpt_fallbacks": skipped})
        self.ckpt.wait()
        report["topology_final"] = [self.nodes, self.devices_per_node]
        report["ckpt_stats"] = {k: v for k, v in self.ckpt.stats.items()
                                if k != "writer_thread"}
        return state, {"metrics": metrics, **report}
