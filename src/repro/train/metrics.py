"""Structured metric logging (the Grafana/Prometheus stand-in) and the
device-side windowed accumulator behind the engine's async-dispatch loop."""
from __future__ import annotations

import json
import time
from typing import Optional

import jax
import jax.numpy as jnp


class MetricAccumulator:
    """Windowed metric accumulation WITHOUT per-step host syncs.

    ``update`` folds one step's (device-resident) scalar metrics into
    running device-side sums — that is an async dispatch, so the host
    keeps issuing work while the device computes.  ``means`` does ONE
    ``jax.device_get`` for the whole window and returns host floats;
    call it once per logging window, not per step.

    Sums accumulate in f32 even when the step emits bf16/fp16 metrics
    (a bf16 running sum stops moving once the sum outgrows the
    increment's 8-bit mantissa — a 100-step window of ~1.0 losses would
    drift visibly).  The cast happens AT ``add`` time, not at drain:
    every increment lands at full precision.
    """

    def __init__(self):
        self.sums = None
        self.count = 0

    @staticmethod
    def _f32(metrics) -> dict:
        return {k: jnp.asarray(v).astype(jnp.float32)
                for k, v in dict(metrics).items()}

    def update(self, metrics) -> None:
        self.count += 1
        if self.sums is None:
            self.sums = self._f32(metrics)
        else:
            m = self._f32(metrics)
            self.sums = {k: jnp.add(self.sums[k], m[k])
                         for k in self.sums}

    def means(self) -> dict:
        """Host-side means of the current window (one device transfer)."""
        if not self.count:
            return {}
        host = jax.device_get(self.sums)
        return {k: float(v) / self.count for k, v in host.items()}

    def reset(self) -> None:
        self.sums = None
        self.count = 0


class MetricLog:
    def __init__(self, path: Optional[str] = None, print_every: int = 10):
        self.path = path
        self.print_every = print_every
        self.rows = []
        self._t0 = time.time()

    def log(self, step: int, **metrics):
        row = {"step": step, "t": round(time.time() - self._t0, 3)}
        row.update({k: float(v) for k, v in metrics.items()})
        self.rows.append(row)
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(row) + "\n")
        if self.print_every and step % self.print_every == 0:
            parts = " ".join(f"{k}={v:.4g}" for k, v in row.items()
                             if k not in ("step", "t"))
            print(f"[step {step:6d} t={row['t']:8.1f}s] {parts}", flush=True)

    def series(self, key: str):
        return [r[key] for r in self.rows if key in r]
