"""Structured metric logging (the Grafana/Prometheus stand-in)."""
from __future__ import annotations

import json
import time
from typing import Optional


class MetricLog:
    def __init__(self, path: Optional[str] = None, print_every: int = 10):
        self.path = path
        self.print_every = print_every
        self.rows = []
        self._t0 = time.time()

    def log(self, step: int, **metrics):
        row = {"step": step, "t": round(time.time() - self._t0, 3)}
        row.update({k: float(v) for k, v in metrics.items()})
        self.rows.append(row)
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(row) + "\n")
        if self.print_every and step % self.print_every == 0:
            parts = " ".join(f"{k}={v:.4g}" for k, v in row.items()
                             if k not in ("step", "t"))
            print(f"[step {step:6d} t={row['t']:8.1f}s] {parts}", flush=True)

    def series(self, key: str):
        return [r[key] for r in self.rows if key in r]
