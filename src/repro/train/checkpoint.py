"""Checkpointing: pytree <-> directory of .npz, plus async snapshots.

Two layers:

- **Synchronous primitives** (`save` / `restore` / `manifest`): arrays in
  one compressed npz keyed by flattened path; the tree structure is
  restored by matching paths against a freshly-initialised template (so
  code evolution that preserves param names keeps old ckpts loadable).
  ``restore`` is STRICT: a checkpoint/template leaf mismatch in either
  direction raises with the offending key paths — silent partial restores
  were how resumed runs drifted.

- **`AsyncCheckpointer`**: elastic-training snapshots OFF the critical
  path.  ``save(step, state)`` dispatches a cheap device-side copy of the
  live (possibly donated) buffers and enqueues it to a writer thread; the
  writer performs the device->host transfer, writes into a temp directory
  and atomically renames it to ``step-XXXXXXXX`` (a torn write never
  becomes the "latest" snapshot), records a manifest (step / topology /
  precision), and prunes to a bounded keep-last-K.  The training loop
  never blocks and never reads from device — asserted by the chaos suite
  with the same transfer-guard + dispatch-counter discipline as
  `tests/test_engine.py`.

Recovery (`restore_latest`) walks snapshots newest-first and falls back
past corrupt/truncated ones, which together with `train/faults.py`'s
``corrupt`` events makes the fallback path a tested code path, not a
hope.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_key(path) -> str:
    return "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_key(path): np.asarray(leaf) for path, leaf in flat}


def save(path: str, tree, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    arrays = _flatten(tree)
    np.savez_compressed(os.path.join(path, "arrays.npz"), **arrays)
    meta = {"step": int(step), "keys": sorted(arrays),
            "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=1)


def restore(path: str, template, *, reshard=None):
    """Restore into the structure of `template` (shapes must match).

    STRICT: leaves present in the checkpoint but not the template, or
    required by the template but missing from the checkpoint, raise
    ``ValueError`` naming the offending key paths — a template that
    disagrees with the saved tree is a code/config mismatch the caller
    must see, never a silent partial restore.

    ``reshard``: optional hook ``(key, array, template_shape) -> array |
    None`` consulted ONLY on a shape mismatch.  Returning an array of the
    template shape accepts the leaf (how ZeRO-1 ``(N, L)`` shards restore
    onto a different device count — see :func:`zero1_reshard`); returning
    None keeps the strict ``ValueError``.
    """
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = [_path_key(p) for p, _ in paths]
    missing = [k for k in keys if k not in arrays]
    extra = sorted(set(arrays) - set(keys))
    if missing or extra:
        raise ValueError(
            f"checkpoint/template mismatch at {path}: "
            f"missing from checkpoint: {missing or 'none'}; "
            f"not in template: {extra or 'none'}")
    leaves = []
    for key, (p, leaf) in zip(keys, paths):
        a = arrays[key]
        shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        if a.shape != shape:
            resharded = reshard(key, a, shape) if reshard is not None \
                else None
            if resharded is None or tuple(resharded.shape) != shape:
                raise ValueError(f"{key}: ckpt {a.shape} vs template {shape}")
            a = resharded
        # `getattr` first so abstract templates (jax.eval_shape output,
        # ShapeDtypeStruct) work alongside concrete arrays and scalars
        dtype = getattr(leaf, "dtype", None) or np.asarray(leaf).dtype
        leaves.append(a.astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def zero1_reshard(key: str, arr, shape):
    """Reshard hook for ZeRO-1 ``(N, L)`` state leaves saved on a
    different device count (`optim.optimizers.zero1`).

    The flat concatenation ``arr.reshape(-1)`` is the logical state; rows
    are just how it was dealt across N devices, and the tail beyond the
    parameter count is zero padding by construction (zero grads keep
    element-wise moments at zero).  So restoring onto N' devices is
    truncate-or-extend to ``N' * L'`` then reshape — bit-exact on every
    logical entry.  Truncation is only accepted when the dropped tail IS
    zero (anything else means the layouts genuinely disagree, e.g. a
    different model — the strict error must fire); non-ZeRO leaves
    return None and keep the strict contract.
    """
    if "zero1" not in key or arr.ndim != 2 or len(shape) != 2:
        return None
    flat = arr.reshape(-1)
    cap = int(shape[0]) * int(shape[1])
    if flat.size > cap:
        if np.any(flat[cap:] != 0):
            return None                 # dropped tail isn't padding
        flat = flat[:cap]
    elif flat.size < cap:
        flat = np.concatenate(
            [flat, np.zeros(cap - flat.size, dtype=arr.dtype)])
    return flat.reshape(shape)


def latest_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


def manifest(path: str) -> dict:
    """The checkpoint's manifest (step, keys, caller-supplied extra)."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def manifest_precision(path: str, default: str = "f32") -> str:
    """The precision policy the checkpoint was trained under.

    `launch/train.py --ckpt` records it in ``extra["precision"]``;
    manifests written before that field existed default to f32 (what
    those checkpoints were actually trained in).
    """
    return manifest(path).get("extra", {}).get("precision", default)


def restore_gan_generator(path: str, cfg):
    """Load trained 3DGAN generator params for serving.

    The train->serve handoff: `launch/train.py --ckpt` saves
    ``state.g_params``; this restores them against a freshly-initialised
    template for ``cfg`` (shapes must match — i.e. the serving config must
    be the training config), ready for `serve.simulate.SimulateEngine`.
    Use :func:`manifest_precision` (or
    ``SimulateEngine.from_checkpoint``) to serve at the precision the
    generator trained in.
    """
    from repro.core import gan
    template = gan.init_generator(jax.random.key(0), cfg)
    return restore(path, template)


# ---------------------------------------------------------------------------
# Async snapshot store (elastic training)
# ---------------------------------------------------------------------------

_STEP_DIR = re.compile(r"^step-(\d{8})$")


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step-{int(step):08d}")


def checkpoint_steps(root: str) -> List[int]:
    """Completed snapshot steps under ``root``, ascending.  Temp dirs
    (in-flight writes) are invisible — only atomically-renamed snapshots
    count."""
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        m = _STEP_DIR.match(name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def restore_latest(root: str, template, *,
                   reshard=None) -> Tuple[int, Any, Optional[dict], int]:
    """Newest VALID snapshot: ``(step, tree, manifest, n_skipped)``.

    Walks snapshots newest-first; a snapshot that fails to load (torn
    write, truncated npz, missing manifest, leaf mismatch) is skipped and
    the previous one is tried — the corrupt-checkpoint fallback.  Returns
    ``(0, None, None, n_skipped)`` when no valid snapshot exists.
    ``reshard`` is forwarded to :func:`restore` (ZeRO-1 shard layouts).
    """
    skipped = 0
    for step in reversed(checkpoint_steps(root)):
        path = step_dir(root, step)
        try:
            tree = restore(path, template, reshard=reshard)
            man = manifest(path)
            return step, tree, man, skipped
        except Exception:
            skipped += 1
    return 0, None, None, skipped


def restore_latest_mirrored(root: str, mirror: Optional[str], template, *,
                            reshard=None) -> Tuple[int, Any, Optional[dict],
                                                   int]:
    """Newest valid snapshot across a primary root AND its mirror.

    The bidirectional fallback for :class:`AsyncCheckpointer`'s mirror
    directory (a cross-host replication stand-in): for each step,
    newest-first, try the primary's copy then the mirror's — so a
    corrupt or missing snapshot on EITHER side falls back to the other
    before falling back to an older step.  Same return contract as
    :func:`restore_latest`; ``mirror=None`` degrades to it exactly.
    """
    candidates = set(checkpoint_steps(root))
    if mirror:
        candidates |= set(checkpoint_steps(mirror))
    skipped = 0
    for step in sorted(candidates, reverse=True):
        for base in (root, mirror):
            if not base:
                continue
            path = step_dir(base, step)
            if not os.path.isdir(path):
                continue
            try:
                return (step, restore(path, template, reshard=reshard),
                        manifest(path), skipped)
            except Exception:
                skipped += 1
    return 0, None, None, skipped


class AsyncCheckpointer:
    """Keep-last-K snapshot writer off the training critical path.

    ``save(step, state)`` costs the main thread only a device-side copy
    DISPATCH (the copy protects the snapshot from the engine's buffer
    donation) plus a queue put; the writer thread owns the device->host
    transfer and all filesystem work.  Snapshots appear atomically via
    temp-dir + ``os.rename`` and carry a manifest with the step, the
    topology that wrote them, and the precision policy — recovery uses it
    to decide how to reshard and at what precision to resume.

    Shard-aware: ZeRO-1 sharded optimizer state (`optimizers.zero1`'s
    ``(N, L)`` leaves) is snapshotted as the full logical array
    (``np.asarray`` gathers sharded buffers), so a snapshot written at
    one device count restores onto any other via
    :func:`zero1_reshard` — elastic re-mesh and resume stay bit-pinned
    on every logical state entry.

    Write resilience: ``retries`` re-attempts a failed snapshot write
    with exponential backoff (``retry_backoff_s * 2^attempt``) before
    surfacing the error — a transient filesystem hiccup (cloud disk
    detach/reattach, NFS blip) costs a retry, not the snapshot.  An
    optional ``mirror`` directory receives a second atomic copy of every
    snapshot (the cross-host replication stand-in); mirror failures are
    counted, never fatal, and recovery via
    :func:`restore_latest_mirrored` falls back across both sides.

    ``stats``: {"saved", "pruned", "snapshot_ms" (main-thread dispatch
    cost), "write_ms" (writer-thread transfer+IO), "write_retries",
    "mirror_saved", "mirror_errors", "writer_thread"}.
    Writer-side exceptions are re-raised on :meth:`wait`.
    """

    def __init__(self, root: str, *, keep: int = 3,
                 extra: Optional[dict] = None, retries: int = 0,
                 retry_backoff_s: float = 0.05,
                 mirror: Optional[str] = None, sleep=time.sleep):
        os.makedirs(root, exist_ok=True)
        self.root = root
        self.keep = max(int(keep), 1)
        self.extra = dict(extra or {})
        self.retries = max(int(retries), 0)
        self.retry_backoff_s = float(retry_backoff_s)
        self.mirror = mirror
        if mirror:
            os.makedirs(mirror, exist_ok=True)
        self._sleep = sleep
        self.stats = {"saved": 0, "pruned": 0, "snapshot_ms": 0.0,
                      "write_ms": 0.0, "write_retries": 0,
                      "mirror_saved": 0, "mirror_errors": 0,
                      "writer_thread": None}
        self._q: queue.Queue = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._drain, daemon=True,
                                        name="ckpt-writer")
        self._thread.start()

    def save(self, step: int, state, extra: Optional[dict] = None):
        """Enqueue a snapshot of ``state`` as checkpoint ``step``.

        Non-blocking: dispatches ``jnp.copy`` per leaf (so later donation
        of the live buffers cannot tear the snapshot) and hands the copies
        to the writer thread.
        """
        t0 = time.perf_counter()
        snap = jax.tree.map(jnp.copy, state)
        self.stats["snapshot_ms"] += 1e3 * (time.perf_counter() - t0)
        self._q.put((int(step), snap, dict(self.extra, **(extra or {}))))

    def hook(self, every: int):
        """An `Engine.fit` hook saving every ``every`` completed steps.

        Checkpoint ``step`` counts COMPLETED steps (the state after global
        step ``g`` is checkpoint ``g + 1``), so a resume passes it
        straight back as ``start_step``.
        """
        every = max(int(every), 1)

        def _hook(step: int, state):
            if (step + 1) % every == 0:
                self.save(step + 1, state)
        return _hook

    # -- writer thread ------------------------------------------------------

    def _drain(self):
        self.stats["writer_thread"] = threading.current_thread()
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, snap, extra = item
            try:
                t0 = time.perf_counter()
                host = jax.tree.map(np.asarray, snap)   # d2h, writer-side
                self._publish(self.root, step, host, extra)
                self.stats["write_ms"] += 1e3 * (time.perf_counter() - t0)
                self.stats["saved"] += 1
                self._prune(self.root)
                if self.mirror:
                    try:
                        self._publish(self.mirror, step, host, extra)
                        self.stats["mirror_saved"] += 1
                        self._prune(self.mirror)
                    except BaseException:   # mirror loss is non-fatal
                        self.stats["mirror_errors"] += 1
            except BaseException as e:                  # surface on wait()
                self._err = e
            finally:
                self._q.task_done()

    def _publish(self, root: str, step: int, host, extra: dict):
        """Atomic snapshot publish into ``root`` with retry + backoff.
        A partially-written temp dir from a failed attempt is removed
        before the next try; the rename is the only visible event."""
        for attempt in range(self.retries + 1):
            tmp = os.path.join(root, f".tmp-step-{step:08d}-{os.getpid()}")
            try:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                save(tmp, host, step=step, extra=extra)
                final = step_dir(root, step)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)                   # atomic publish
                return
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                if attempt >= self.retries:
                    raise
                self.stats["write_retries"] += 1
                self._sleep(self.retry_backoff_s * (2 ** attempt))

    def _prune(self, root: Optional[str] = None):
        root = root or self.root
        steps = checkpoint_steps(root)
        for step in steps[:-self.keep]:
            shutil.rmtree(step_dir(root, step), ignore_errors=True)
            self.stats["pruned"] += 1

    # -- lifecycle ----------------------------------------------------------

    def wait(self):
        """Block until every enqueued snapshot is on disk; re-raise any
        writer-side error."""
        self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=30.0)
