"""Checkpointing: pytree <-> directory of .npz + msgpack-free manifest.

Arrays are saved in one compressed npz keyed by flattened path; the tree
structure is restored by matching paths against a freshly-initialised
template (so code evolution that preserves param names keeps old ckpts
loadable).
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    arrays = _flatten(tree)
    np.savez_compressed(os.path.join(path, "arrays.npz"), **arrays)
    meta = {"step": int(step), "keys": sorted(arrays),
            "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=1)


def restore(path: str, template):
    """Restore into the structure of `template` (shapes must match)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(x)) for x in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        a = arrays[key]
        if a.shape != np.shape(leaf):
            raise ValueError(f"{key}: ckpt {a.shape} vs template {np.shape(leaf)}")
        leaves.append(a.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


def manifest(path: str) -> dict:
    """The checkpoint's manifest (step, keys, caller-supplied extra)."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def manifest_precision(path: str, default: str = "f32") -> str:
    """The precision policy the checkpoint was trained under.

    `launch/train.py --ckpt` records it in ``extra["precision"]``;
    manifests written before that field existed default to f32 (what
    those checkpoints were actually trained in).
    """
    return manifest(path).get("extra", {}).get("precision", default)


def restore_gan_generator(path: str, cfg):
    """Load trained 3DGAN generator params for serving.

    The train->serve handoff: `launch/train.py --ckpt` saves
    ``state.g_params``; this restores them against a freshly-initialised
    template for ``cfg`` (shapes must match — i.e. the serving config must
    be the training config), ready for `serve.simulate.SimulateEngine`.
    Use :func:`manifest_precision` (or
    ``SimulateEngine.from_checkpoint``) to serve at the precision the
    generator trained in.
    """
    from repro.core import gan
    template = gan.init_generator(jax.random.key(0), cfg)
    return restore(path, template)
