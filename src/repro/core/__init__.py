"""The paper's core workload: the 3DGAN model (`gan.py`), Algorithm-1
adversarial training steps (`adversarial.py` — naive baseline and the
fully-fused custom-loop rewrite), and the physics validation used both
at training time and by the serving gate (`validation.py`)."""
