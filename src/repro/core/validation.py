"""Physics validation: calorimeter energy response, GAN vs Monte Carlo.

Reproduces the paper's Fig. 3 / Fig. 7 comparisons numerically:

- longitudinal profile: energy sum per depth layer (z),
- transverse profile: energy sum per x (and y) cell, compared in both the
  bulk (linear scale) and at the volume edges (log scale — the region the
  paper reports degrading above 64 GPUs),
- total response: E_CAL / E_p.

Each comparison returns a scalar divergence so tests/benchmarks can assert
"agreement remains overall very good" quantitatively.
"""
from __future__ import annotations

import numpy as np


def longitudinal_profile(images: np.ndarray) -> np.ndarray:
    """images: (B, X, Y, Z, 1) -> mean profile over z, normalised."""
    prof = np.asarray(images).sum(axis=(1, 2, 4)).mean(axis=0)
    return prof / max(prof.sum(), 1e-12)


def transverse_profile(images: np.ndarray, axis: str = "x") -> np.ndarray:
    a = {"x": (2, 3, 4), "y": (1, 3, 4)}[axis]
    prof = np.asarray(images).sum(axis=a).mean(axis=0)
    return prof / max(prof.sum(), 1e-12)


def energy_response(images: np.ndarray, e_p: np.ndarray) -> np.ndarray:
    return np.asarray(images).sum(axis=(1, 2, 3, 4)) / np.asarray(e_p)


def profile_divergence(p: np.ndarray, q: np.ndarray, eps=1e-9) -> float:
    """Symmetrised KL between two normalised profiles (scalar 'how far')."""
    p = np.clip(p, eps, None)
    q = np.clip(q, eps, None)
    p, q = p / p.sum(), q / q.sum()
    return float(0.5 * (np.sum(p * np.log(p / q)) + np.sum(q * np.log(q / p))))


def edge_ratio_error(p: np.ndarray, q: np.ndarray, edge_cells: int = 5) -> float:
    """Relative error of the edge mass (the paper's >64-GPU failure mode is
    visible here first: edges are orders of magnitude below the core)."""
    pe = p[:edge_cells].sum() + p[-edge_cells:].sum()
    qe = q[:edge_cells].sum() + q[-edge_cells:].sum()
    return float(abs(pe - qe) / max(qe, 1e-12))


def validation_report(gan_images, mc_images, gan_ep, mc_ep) -> dict:
    rep = {}
    for name, fn in (("longitudinal", longitudinal_profile),
                     ("transverse_x", lambda im: transverse_profile(im, "x")),
                     ("transverse_y", lambda im: transverse_profile(im, "y"))):
        pg, pm = fn(gan_images), fn(mc_images)
        rep[f"{name}_kl"] = profile_divergence(pg, pm)
        rep[f"{name}_edge_err"] = edge_ratio_error(pg, pm)
    rg = energy_response(gan_images, gan_ep)
    rm = energy_response(mc_images, mc_ep)
    rep["response_mean_gan"] = float(rg.mean())
    rep["response_mean_mc"] = float(rm.mean())
    rep["response_rel_err"] = float(abs(rg.mean() - rm.mean())
                                    / max(rm.mean(), 1e-12))
    return rep
