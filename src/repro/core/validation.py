"""Physics validation: calorimeter energy response, GAN vs Monte Carlo.

Reproduces the paper's Fig. 3 / Fig. 7 comparisons numerically:

- longitudinal profile: energy sum per depth layer (z),
- transverse profile: energy sum per x (and y) cell, compared in both the
  bulk (linear scale) and at the volume edges (log scale — the region the
  paper reports degrading above 64 GPUs),
- total response: E_CAL / E_p.

Each comparison returns a scalar divergence so tests/benchmarks can assert
"agreement remains overall very good" quantitatively.

Two halves:

- host-side numpy comparisons (`validation_report` and friends) used by the
  training benchmarks,
- device-side accumulators (`profile_sums` / `gate_report` /
  `reference_profiles`) behind the serving engine's rolling physics gate
  (`serve/simulate.py`): per-step masked profile sums stay on the
  accelerator, the host drains ONE small pytree per gate window and turns
  it into the same divergences the training benchmarks report.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def longitudinal_profile(images: np.ndarray) -> np.ndarray:
    """images: (B, X, Y, Z, 1) -> mean profile over z, normalised."""
    prof = np.asarray(images).sum(axis=(1, 2, 4)).mean(axis=0)
    return prof / max(prof.sum(), 1e-12)


def transverse_profile(images: np.ndarray, axis: str = "x") -> np.ndarray:
    a = {"x": (2, 3, 4), "y": (1, 3, 4)}[axis]
    prof = np.asarray(images).sum(axis=a).mean(axis=0)
    return prof / max(prof.sum(), 1e-12)


def energy_response(images: np.ndarray, e_p: np.ndarray) -> np.ndarray:
    return np.asarray(images).sum(axis=(1, 2, 3, 4)) / np.asarray(e_p)


def profile_divergence(p: np.ndarray, q: np.ndarray, eps=1e-9) -> float:
    """Symmetrised KL between two normalised profiles (scalar 'how far')."""
    p = np.clip(p, eps, None)
    q = np.clip(q, eps, None)
    p, q = p / p.sum(), q / q.sum()
    return float(0.5 * (np.sum(p * np.log(p / q)) + np.sum(q * np.log(q / p))))


def edge_ratio_error(p: np.ndarray, q: np.ndarray, edge_cells: int = 5) -> float:
    """Relative error of the edge mass (the paper's >64-GPU failure mode is
    visible here first: edges are orders of magnitude below the core)."""
    pe = p[:edge_cells].sum() + p[-edge_cells:].sum()
    qe = q[:edge_cells].sum() + q[-edge_cells:].sum()
    return float(abs(pe - qe) / max(qe, 1e-12))


# ---------------------------------------------------------------------------
# Device-side gate accumulators (serving: one small drain per window)
# ---------------------------------------------------------------------------


def profile_sums(images, e_p, mask=None) -> dict:
    """Masked per-batch profile accumulators, computed ON DEVICE.

    ``images``: (B, X, Y, Z, 1); ``mask``: (B,) — padded bucket rows
    contribute nothing.  The returned pytree of small jnp arrays is meant
    to be summed across steps (still on device) and drained once per gate
    window; after normalisation the profiles equal what the host-side
    ``longitudinal_profile`` / ``transverse_profile`` compute over the
    same (unpadded) events.
    """
    img = images.astype(jnp.float32)
    if mask is not None:
        m = mask.astype(jnp.float32)
        img = img * m[:, None, None, None, None]
        ep = e_p.astype(jnp.float32) * m
        n = m.sum()
    else:
        ep = e_p.astype(jnp.float32)
        n = jnp.float32(img.shape[0])
    # per-event response (E_CAL_i / E_p_i) summed, NOT sum(E_CAL)/sum(E_p):
    # the reference (`energy_response(...).mean()`) weights events equally,
    # so an energy-weighted ratio-of-sums would report spurious drift
    # whenever response varies with E_p across a window's request mix
    resp = img.sum(axis=(1, 2, 3, 4)) / jnp.maximum(
        e_p.astype(jnp.float32), 1e-12)
    return {
        "longitudinal": img.sum(axis=(1, 2, 4)).sum(axis=0),   # (Z,)
        "transverse_x": img.sum(axis=(2, 3, 4)).sum(axis=0),   # (X,)
        "transverse_y": img.sum(axis=(1, 3, 4)).sum(axis=0),   # (Y,)
        "response": resp.sum(),
        "e_cal": img.sum(),
        "e_p": ep.sum(),
        "count": n,
    }


def reference_profiles(images, e_p) -> dict:
    """The Monte-Carlo side of the serving gate (host numpy, computed once)."""
    return {
        "longitudinal": longitudinal_profile(images),
        "transverse_x": transverse_profile(images, "x"),
        "transverse_y": transverse_profile(images, "y"),
        "response_mean": float(np.mean(energy_response(images, e_p))),
    }


def gate_report(sums: dict, reference: dict) -> dict:
    """Drained (host) gate sums -> the same divergences `validation_report`
    computes at training time, against a fixed MC reference."""
    rep = {}
    for name in ("longitudinal", "transverse_x", "transverse_y"):
        prof = np.asarray(sums[name], np.float64)
        prof = prof / max(prof.sum(), 1e-12)
        rep[f"{name}_kl"] = profile_divergence(prof, reference[name])
        rep[f"{name}_edge_err"] = edge_ratio_error(prof, reference[name])
    resp = float(sums["response"]) / max(float(sums["count"]), 1e-12)
    rep["response_mean"] = resp
    rep["response_rel_err"] = float(abs(resp - reference["response_mean"])
                                    / max(reference["response_mean"], 1e-12))
    rep["count"] = float(sums["count"])
    return rep


def validation_report(gan_images, mc_images, gan_ep, mc_ep) -> dict:
    rep = {}
    for name, fn in (("longitudinal", longitudinal_profile),
                     ("transverse_x", lambda im: transverse_profile(im, "x")),
                     ("transverse_y", lambda im: transverse_profile(im, "y"))):
        pg, pm = fn(gan_images), fn(mc_images)
        rep[f"{name}_kl"] = profile_divergence(pg, pm)
        rep[f"{name}_edge_err"] = edge_ratio_error(pg, pm)
    rg = energy_response(gan_images, gan_ep)
    rm = energy_response(mc_images, mc_ep)
    rep["response_mean_gan"] = float(rg.mean())
    rep["response_mean_mc"] = float(rm.mean())
    rep["response_rel_err"] = float(abs(rg.mean() - rm.mean())
                                    / max(rm.mean(), 1e-12))
    return rep
