"""3DGAN — three-dimensional convolutional ACGAN for calorimeter simulation.

Generator: (latent ⊕ E_p ⊕ theta) -> dense -> stack of stride-2 3-D
transposed convolutions -> crop -> softplus (energies are non-negative).

Discriminator: stride-2 3-D convolutions -> heads:
  - validity logit (real/fake),
  - E_p regression (ACGAN auxiliary),
  - theta regression (ACGAN auxiliary).
The total-deposit E_CAL constraint is computed analytically from the image
(as in 3DGAN) and compared to the label in the loss.

All convs run in NDHWC / DHWIO layout (TPU-native).  The hot-spot conv3d has
a Pallas implicit-GEMM kernel under kernels/conv3d (used when enabled; the
lax.conv path is the reference).
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.substrate import layers

DN = ("NDHWC", "DHWIO", "NDHWC")

# Pallas fused implicit-GEMM conv path (kernels/conv3d).  Resolution order:
#   1. cfg.use_pallas_conv when not None (per-model config),
#   2. the process-wide setting (set_pallas_conv / use_pallas_conv ctx),
#   3. the REPRO_PALLAS_CONV environment variable (default: off — the CPU
#      stand-in runs the kernels in interpret mode, which is slow; flip on
#      for the TPU target where the MXU-tiled GEMM is the point).
_PALLAS_CONV: list = [None]


def _env_pallas_conv() -> bool:
    return os.environ.get("REPRO_PALLAS_CONV", "0").lower() \
        not in ("", "0", "false", "no")


def pallas_conv_enabled(cfg=None) -> bool:
    """Resolve the Pallas-conv toggle: config > global setter > env."""
    if cfg is not None and getattr(cfg, "use_pallas_conv", None) is not None:
        return bool(cfg.use_pallas_conv)
    if _PALLAS_CONV[0] is not None:
        return bool(_PALLAS_CONV[0])
    return _env_pallas_conv()


def set_pallas_conv(on: Optional[bool]):
    """Set the process-wide toggle (None reverts to the env default).
    Returns the previous value for save/restore."""
    prev = _PALLAS_CONV[0]
    _PALLAS_CONV[0] = on
    return prev


class use_pallas_conv:
    """Scoped toggle (kept for interactive use; config/env are the
    jit-friendly routes — they resolve BEFORE tracing)."""

    def __init__(self, on: bool = True):
        self.on = on

    def __enter__(self):
        self.prev = set_pallas_conv(self.on)

    def __exit__(self, *a):
        set_pallas_conv(self.prev)


def _conv_layer(x, w, b=None, stride=1, *, activation="none", slope=0.2,
                transpose=False, pallas=None):
    """One conv layer; on the Pallas path conv+bias+activation are ONE
    fused kernel launch, on the lax path the same math is left to XLA."""
    if pallas is None:
        pallas = pallas_conv_enabled()
    if pallas:
        from repro.kernels.conv3d import (conv3d_bias_act,
                                          conv3d_transpose_bias_act)
        op = conv3d_transpose_bias_act if transpose else conv3d_bias_act
        bias = b if b is not None else jnp.zeros((w.shape[-1],), x.dtype)
        # w stays in param dtype: the kernel casts for compute, the custom
        # vjp hands back dw in param dtype (bf16 policy safe)
        return op(x, w, bias, stride, activation, slope, None)
    out = (jax.lax.conv_transpose(x, w.astype(x.dtype), (stride,) * 3,
                                  "SAME", dimension_numbers=DN)
           if transpose else
           jax.lax.conv_general_dilated(x, w.astype(x.dtype), (stride,) * 3,
                                        "SAME", dimension_numbers=DN))
    if b is not None:
        out = out + b.astype(out.dtype)
    if activation == "leaky_relu":
        out = jax.nn.leaky_relu(out, slope)
    elif activation == "softplus":
        out = jax.nn.softplus(out)
    return out


def _start_dims(image_shape, ups: int) -> Tuple[int, int, int]:
    f = 2 ** ups
    return tuple(-(-d // f) for d in image_shape)


# ---------------------------------------------------------------------------
# Generator
# ---------------------------------------------------------------------------


def init_generator(key, cfg):
    chs = cfg.gen_channels
    ups = len(chs) - 1
    d0 = _start_dims(cfg.image_shape, ups)
    in_dim = cfg.latent_dim + 2
    ks = jax.random.split(key, len(chs) + 2)
    p = {"fc": layers.init_dense(ks[0], in_dim,
                                 d0[0] * d0[1] * d0[2] * chs[0], bias=True,
                                 scale=0.05)}
    for i in range(ups):
        p[f"up{i}"] = {
            "w": layers.normal_init(ks[i + 1], (3, 3, 3, chs[i], chs[i + 1]), 0.05),
            "b": jnp.zeros((chs[i + 1],), jnp.float32),
            "gn": layers.init_norm(chs[i + 1], "layernorm"),
        }
    p["out"] = {"w": layers.normal_init(ks[-1], (3, 3, 3, chs[-1], 1), 0.05),
                "b": jnp.zeros((1,), jnp.float32)}
    return p


def generator_axes(cfg):
    chs = cfg.gen_channels
    ups = len(chs) - 1
    p = {"fc": layers.dense_axes("embed", "mlp", bias=True)}
    for i in range(ups):
        p[f"up{i}"] = {"w": (None, None, None, None, None), "b": (None,),
                       "gn": layers.norm_axes("layernorm")}
    p["out"] = {"w": (None, None, None, None, None), "b": (None,)}
    return p


def generate(p, noise, e_p, theta, cfg):
    """noise: (B, latent); e_p/theta raw units -> image (B, X, Y, Z, 1)."""
    chs = cfg.gen_channels
    ups = len(chs) - 1
    d0 = _start_dims(cfg.image_shape, ups)
    pallas = pallas_conv_enabled(cfg)
    e_n = (e_p / 100.0)[:, None].astype(noise.dtype)
    t_n = theta[:, None].astype(noise.dtype)
    z = jnp.concatenate([noise, e_n, t_n], axis=-1)
    x = layers.apply_dense(p["fc"], z)
    x = jax.nn.leaky_relu(x, 0.2)
    x = x.reshape(-1, *d0, chs[0])
    for i in range(ups):
        # bias folds into the kernel epilogue; the activation cannot (a
        # layernorm sits between), so it stays outside
        x = _conv_layer(x, p[f"up{i}"]["w"], p[f"up{i}"]["b"], 2,
                        transpose=True, pallas=pallas)
        x = layers.apply_norm(p[f"up{i}"]["gn"], x, "layernorm")
        x = jax.nn.leaky_relu(x, 0.2)
    X, Y, Z = cfg.image_shape
    x = x[:, :X, :Y, :Z]
    # softplus keeps cell energies non-negative (fused into the conv
    # epilogue on the Pallas path); scale with E_p so the generator does
    # not have to learn the dynamic range from scratch
    x = _conv_layer(x, p["out"]["w"], p["out"]["b"], 1,
                    activation="softplus", pallas=pallas)
    return x * (e_n[:, None, None, None] * 0.025)


# ---------------------------------------------------------------------------
# Discriminator
# ---------------------------------------------------------------------------


def init_discriminator(key, cfg):
    chs = cfg.disc_channels
    ks = jax.random.split(key, len(chs) + 3)
    p = {}
    c_in = 1
    for i, c in enumerate(chs):
        p[f"conv{i}"] = {
            "w": layers.normal_init(ks[i], (3, 3, 3, c_in, c), 0.05),
            "b": jnp.zeros((c,), jnp.float32),
            "ln": layers.init_norm(c, "layernorm"),
        }
        c_in = c
    X, Y, Z = cfg.image_shape
    f = 2 ** len(chs)
    flat = (-(-X // f)) * (-(-Y // f)) * (-(-Z // f)) * chs[-1]
    p["validity"] = layers.init_dense(ks[-3], flat, 1, bias=True)
    p["energy"] = layers.init_dense(ks[-2], flat, 1, bias=True)
    p["angle"] = layers.init_dense(ks[-1], flat, 1, bias=True)
    return p


def discriminator_axes(cfg):
    p = {}
    for i in range(len(cfg.disc_channels)):
        p[f"conv{i}"] = {"w": (None, None, None, None, None), "b": (None,),
                         "ln": layers.norm_axes("layernorm")}
    for head in ("validity", "energy", "angle"):
        p[head] = layers.dense_axes("embed", None, bias=True)
    return p


def discriminate(p, img, cfg):
    """img: (B, X, Y, Z, 1) -> (validity_logit, e_p_pred, theta_pred)."""
    x = jnp.log1p(img * 50.0)          # compress the energy dynamic range
    n = len(cfg.disc_channels)
    pallas = pallas_conv_enabled(cfg)
    for i in range(n):
        x = _conv_layer(x, p[f"conv{i}"]["w"], p[f"conv{i}"]["b"], 2,
                        pallas=pallas)
        x = layers.apply_norm(p[f"conv{i}"]["ln"], x, "layernorm")
        x = jax.nn.leaky_relu(x, 0.2)
    x = x.reshape(x.shape[0], -1)
    validity = layers.apply_dense(p["validity"], x)[:, 0]
    e_pred = jax.nn.softplus(layers.apply_dense(p["energy"], x)[:, 0]) * 100.0
    t_pred = layers.apply_dense(p["angle"], x)[:, 0] + jnp.pi / 2
    return validity, e_pred, t_pred


# ---------------------------------------------------------------------------
# Losses (ACGAN with physics constraints, 3DGAN-style)
# ---------------------------------------------------------------------------


def bce_logits(logit, target):
    return jnp.mean(jnp.maximum(logit, 0) - logit * target
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def mape(pred, true):
    return jnp.mean(jnp.abs(pred - true) / jnp.maximum(jnp.abs(true), 1e-3))


def disc_loss(d_params, g_out_or_real, labels, cfg, real: bool):
    e_p, theta, ecal = labels
    v, e_pred, t_pred = discriminate(d_params, g_out_or_real, cfg)
    # loss math in f32 regardless of compute dtype (bf16 policy)
    v, e_pred, t_pred = (t.astype(jnp.float32) for t in (v, e_pred, t_pred))
    target = 1.0 if real else 0.0
    l_bce = bce_logits(v, target)
    l_e = mape(e_pred, e_p)
    l_t = jnp.mean(jnp.abs(t_pred - theta))
    ecal_img = jnp.sum(g_out_or_real.astype(jnp.float32), axis=(1, 2, 3, 4))
    l_ecal = mape(ecal_img, ecal)
    total = (l_bce + cfg.aux_energy_weight * l_e / 10.0
             + cfg.aux_angle_weight * l_t + cfg.aux_ecal_weight * l_ecal)
    acc = jnp.mean(((v > 0) == (target > 0.5)).astype(jnp.float32))
    return total, {"bce": l_bce, "e": l_e, "t": l_t, "ecal": l_ecal, "acc": acc}


def gen_loss(g_params, d_params, noise, labels, cfg):
    e_p, theta, ecal = labels
    img = generate(g_params, noise, e_p, theta, cfg)
    v, e_pred, t_pred = discriminate(d_params, img, cfg)
    v, e_pred, t_pred = (t.astype(jnp.float32) for t in (v, e_pred, t_pred))
    l_bce = bce_logits(v, 1.0)         # want D to call fakes real
    l_e = mape(e_pred, e_p)
    l_t = jnp.mean(jnp.abs(t_pred - theta))
    ecal_img = jnp.sum(img.astype(jnp.float32), axis=(1, 2, 3, 4))
    l_ecal = mape(ecal_img, ecal)
    total = (l_bce + cfg.aux_energy_weight * l_e / 10.0
             + cfg.aux_angle_weight * l_t + cfg.aux_ecal_weight * l_ecal)
    return total, {"bce": l_bce, "e": l_e, "t": l_t, "ecal": l_ecal}
