"""The paper's contribution: accelerating the adversarial training process.

Two implementations of Algorithm 1 (§3):

``naive_step`` — the ``keras.train_on_batch`` baseline.  The generator-input
initialisation (latent sampling + label concat) and the fake-image round trip
run SEQUENTIALLY ON THE HOST between separately-compiled device calls.  With
N replicas the host work grows with the global batch => the linear bottleneck
of Fig. 1.

``fused_step`` — the custom-training-loop rewrite.  The ENTIRE Algorithm-1
body is one compiled function: on-device RNG (jax.random), fake generation,
both discriminator updates and both generator updates.  Nothing sequential
remains on the host; under pjit the per-replica noise is generated on each
device's own batch shard, which is exactly the paper's "tf.function includes
all previously sequential steps".

Both follow Algorithm 1 faithfully: D on real, D on fake, then G twice.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan
from repro.optim import optimizers as opt_lib
from repro.substrate import precision as precision_lib


def _freeze_pallas_conv(cfg):
    """Pin the Pallas fused-conv decision into the config at STEP
    CONSTRUCTION time.  The toggle is otherwise ambient (global setter /
    env var); resolving it here means the traced program is deterministic
    no matter when jit recompiles the step."""
    resolved = gan.pallas_conv_enabled(cfg)
    if getattr(cfg, "use_pallas_conv", resolved) == resolved:
        return cfg
    try:
        return dataclasses.replace(cfg, use_pallas_conv=resolved)
    except TypeError:
        return cfg                      # config without the field


def grad_reduce_traffic(cfg, bucket_bytes: Optional[int] = None) -> dict:
    """Per-step gradient-reduction payload of the fused Algorithm-1 step.

    Each phase reduces its OWN gradients before its optimizer update —
    D params twice (D-real, D-fake), G params ``gen_steps_per_disc``
    times — so the cross-node interconnect model (cloud/interconnect.py)
    prices the step as a SEQUENCE of smaller all-reduces, not one big
    one.  Returns {"rounds": [(name, bytes), ...], "bytes_per_step",
    "largest_round_bytes"}; shapes only, nothing is allocated.

    With ``bucket_bytes`` set, also returns ``"tail_bytes"`` — per round,
    the bytes of the reverse-order overlap reducer's reduction that stay
    EXPOSED no matter how early buckets are issued
    (``collectives.OverlapReduce`` granularity is whole
    ``plan_buckets`` buckets):

    - D rounds map to 0: the following generator-phase compute (the
      generator forward making the next fakes) is independent of the D
      gradients, so their reductions hide under it.
    - G rounds map to their LARGEST bucket: the fused step runs the
      ``gen_steps_per_disc`` G updates back-to-back in a scan whose next
      iteration immediately consumes the updated params, and the last
      one ends the step — there is no independent compute left for the
      slowest bucket (with an oversize first layer, nearly the whole
      round) to hide under.

    Feeding this real plan to ``interconnect.exposed_comm_s`` is what
    makes the modeled overlap term track the measured schedule
    (``jaxpr_cost.collective_schedule``) instead of assuming a uniform
    ``bytes / n_buckets`` tail.
    """
    from repro.parallel import collectives

    g_shapes = jax.eval_shape(
        lambda: gan.init_generator(jax.random.key(0), cfg))
    d_shapes = jax.eval_shape(
        lambda: gan.init_discriminator(jax.random.key(0), cfg))

    def tree_bytes(t):
        return int(sum(np.prod(s.shape) * s.dtype.itemsize
                       for s in jax.tree.leaves(t)))

    def largest_bucket_bytes(t):
        leaves = jax.tree.leaves(t)
        return max(
            int(sum(np.prod(leaves[i].shape) * leaves[i].dtype.itemsize
                    for i in bucket))
            for bucket in collectives.plan_buckets(leaves, bucket_bytes))

    gb, db = tree_bytes(g_shapes), tree_bytes(d_shapes)
    rounds = [("d_real", db), ("d_fake", db)]
    rounds += [(f"g{i}", gb) for i in range(cfg.gen_steps_per_disc)]
    out = {"rounds": rounds,
           "bytes_per_step": sum(b for _, b in rounds),
           "largest_round_bytes": max(b for _, b in rounds)}
    if bucket_bytes is not None:
        gt = largest_bucket_bytes(g_shapes)
        out["tail_bytes"] = {name: (0 if name.startswith("d_") else gt)
                             for name, _ in rounds}
    return out


class GANState(NamedTuple):
    g_params: dict
    d_params: dict
    g_opt: dict
    d_opt: dict
    step: jax.Array
    # dynamic loss-scale state (precision_lib.LossScaleState) when the
    # policy enables it; None keeps the pytree identical to the pre-policy
    # layout, so old checkpoints and f32 runs are untouched
    loss_scale: Any = None


def init_state(rng, cfg, g_optimizer, d_optimizer, policy=None) -> GANState:
    """Master params + optimizer state are ALWAYS f32; ``policy`` only
    adds the loss-scale state its scaling mode needs."""
    kg, kd = jax.random.split(rng)
    g_params = gan.init_generator(kg, cfg)
    d_params = gan.init_discriminator(kd, cfg)
    return GANState(g_params, d_params, g_optimizer.init(g_params),
                    d_optimizer.init(d_params), jnp.zeros((), jnp.int32),
                    precision_lib.init_loss_scale(policy))


# ---------------------------------------------------------------------------
# Naive (keras.train_on_batch analogue)
# ---------------------------------------------------------------------------


class NaiveStep:
    """Host-orchestrated adversarial step with per-call compiled pieces.

    The host work (`_host_generator_inputs`) and device round trips between
    the pieces are intentional — they ARE the measured baseline.
    """

    def __init__(self, cfg, g_optimizer, d_optimizer, seed=0):
        cfg = _freeze_pallas_conv(cfg)
        self.cfg = cfg
        self.g_opt_lib = g_optimizer
        self.d_opt_lib = d_optimizer
        self.np_rng = np.random.default_rng(seed)

        @jax.jit
        def d_update(d_params, d_opt, img, e_p, theta, ecal, real_flag):
            def loss(dp):
                return gan.disc_loss(dp, img, (e_p, theta, ecal), cfg,
                                     real=True)[0] * real_flag + \
                       gan.disc_loss(dp, img, (e_p, theta, ecal), cfg,
                                     real=False)[0] * (1 - real_flag)
            l, grads = jax.value_and_grad(loss)(d_params)
            upd, d_opt = d_optimizer.update(grads, d_opt, d_params)
            return opt_lib.apply_updates(d_params, upd), d_opt, l

        @jax.jit
        def g_update(g_params, g_opt, d_params, noise, e_p, theta, ecal):
            def loss(gp):
                return gan.gen_loss(gp, d_params, noise,
                                    (e_p, theta, ecal), cfg)[0]
            l, grads = jax.value_and_grad(loss)(g_params)
            upd, g_opt = g_optimizer.update(grads, g_opt, g_params)
            return opt_lib.apply_updates(g_params, upd), g_opt, l

        @jax.jit
        def predict(g_params, noise, e_p, theta):
            return gan.generate(g_params, noise, e_p, theta, cfg)

        self._d_update, self._g_update, self._predict = d_update, g_update, predict

    def host_generator_inputs(self, batch_size):
        """The sequential host-side init the paper identifies as the
        bottleneck: numpy RNG + label concat, once per replica batch."""
        cfg = self.cfg
        noise = self.np_rng.normal(0, 1, (batch_size, cfg.latent_dim)) \
            .astype(np.float32)
        e_p = self.np_rng.uniform(10.0, 500.0, batch_size).astype(np.float32)
        theta = self.np_rng.uniform(np.deg2rad(60), np.deg2rad(120),
                                    batch_size).astype(np.float32)
        return noise, e_p, theta

    def __call__(self, state: GANState, batch) -> tuple:
        cfg = self.cfg
        img, e_p, theta, ecal = (batch["image"], batch["e_p"],
                                 batch["theta"], batch["ecal"])
        bs = img.shape[0]
        ecal_frac = float(np.mean(np.asarray(ecal) / np.asarray(e_p)))

        # -- generator input init: HOST, sequential --------------------
        noise, f_ep, f_th = self.host_generator_inputs(bs)
        fake_ecal = f_ep * ecal_frac
        # -- generate fakes; round-trip through host (train_on_batch) --
        fake = np.asarray(self._predict(state.g_params, noise, f_ep, f_th))
        # -- D on real, D on fake --------------------------------------
        d_params, d_opt, d_lr = self._d_update(
            state.d_params, state.d_opt, img, e_p, theta, ecal,
            jnp.float32(1.0))
        d_params, d_opt, d_lf = self._d_update(
            d_params, d_opt, fake, f_ep, f_th, fake_ecal, jnp.float32(0.0))
        # -- G twice (fresh host-side inputs each time: Algorithm 1) ---
        g_params, g_opt = state.g_params, state.g_opt
        g_ls = []
        for _ in range(cfg.gen_steps_per_disc):
            noise, f_ep, f_th = self.host_generator_inputs(bs)
            g_params, g_opt, g_l = self._g_update(
                g_params, g_opt, d_params, noise, f_ep, f_th,
                f_ep * ecal_frac)
            g_ls.append(float(g_l))
        new = GANState(g_params, d_params, g_opt, d_opt, state.step + 1)
        return new, {"d_loss_real": float(d_lr), "d_loss_fake": float(d_lf),
                     "g_loss": float(np.mean(g_ls))}


# ---------------------------------------------------------------------------
# Fused custom loop (the paper's optimisation)
# ---------------------------------------------------------------------------


def make_fused_step(cfg, g_optimizer, d_optimizer, mesh=None, policy=None,
                    grad_reduce=None, microbatches=1):
    """One compiled program for the full Algorithm-1 body.

    ``mesh``: when given, the on-device generator inputs (noise + labels)
    are sharding-constrained over ALL mesh axes — each replica samples its
    own shard (the paper's "every replica initialises its own inputs"),
    and GSPMD keeps the whole fake-image path batch-sharded.  The engine's
    custom loop passes ``mesh=None`` instead: there the step body is a
    per-device program under shard_map and ``batch`` is already local.

    ``policy``: mixed-precision policy (paper §4: bf16 on the MXU).  The
    batch AND both networks' params are cast to ``policy.compute_dtype``
    at phase entry, so every conv (Pallas kernels included — they keep
    their f32 VMEM accumulators) and every norm runs at compute precision;
    losses, gradients, master params and optimizer state stay f32 (§Perf
    G1: halves the memory-bound term).  When ``policy.loss_scale`` is
    nonzero, each phase's loss is scaled before the backward pass, its
    UNSCALED reduced gradients are checked for finiteness, and a
    nonfinite phase SKIPS its optimizer update (params/opt state carried
    through unchanged) while halving the dynamic scale — the state rides
    in ``GANState.loss_scale`` (see `substrate/precision.py`).

    ``grad_reduce``: applied to the gradients of EVERY phase (D-real,
    D-fake, each G step) before its optimizer update — the engine's
    custom loop passes an explicit psum-mean over the data axes here,
    keeping params replicated without GSPMD's help.  A reducer exposing
    ``wrap_params`` (``collectives.OverlapReduce``) is routed through the
    loss instead: the params are tagged before differentiation so each
    bucket's collective issues inside the backward pass, and the post-hoc
    call becomes the identity.

    ``microbatches``: gradient accumulation INSIDE each phase.  The batch
    (and the fake-input sampling) is split into this many microbatches;
    each phase averages its gradients over them via lax.scan before the
    single optimizer update, so Algorithm 1's update order is preserved
    while the live activation footprint shrinks by the microbatch factor.
    """
    cfg = _freeze_pallas_conv(cfg)      # kernel route fixed at trace time
    M = int(microbatches)
    assert M >= 1, microbatches
    reduce_grads = grad_reduce if grad_reduce is not None else (lambda g: g)
    wrap_params = getattr(reduce_grads, "wrap_params", None)
    compute_dtype = policy.compute_dtype if policy is not None else None
    to_compute = (policy.cast_to_compute if compute_dtype is not None
                  else (lambda t: t))
    scaling = policy is not None and bool(policy.loss_scale)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        _axes = tuple(mesh.axis_names)

        def _shard_batchdim(x):
            spec = P(_axes, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
    else:
        def _shard_batchdim(x):
            return x

    def fused_step(state: GANState, batch, rng):
        img, e_p, theta, ecal = (batch["image"], batch["e_p"],
                                 batch["theta"], batch["ecal"])
        if compute_dtype is not None:
            img = img.astype(compute_dtype)      # G1: bf16 conv stacks
        bs = img.shape[0]
        assert bs % M == 0, (bs, M)
        mb = bs // M
        ecal_frac = jnp.mean(ecal / e_p)
        keys = jax.random.split(rng, (1 + cfg.gen_steps_per_disc) * M)
        d_keys = keys[:M]
        g_keys = keys[M:].reshape(cfg.gen_steps_per_disc, M)

        def sample_inputs(k):
            k1, k2, k3 = jax.random.split(k, 3)
            noise = jax.random.normal(k1, (mb, cfg.latent_dim),
                                      compute_dtype or jnp.float32)
            f_ep = jax.random.uniform(k2, (mb,), jnp.float32, 10.0, 500.0)
            f_th = jax.random.uniform(k3, (mb,), jnp.float32,
                                      jnp.deg2rad(60.0), jnp.deg2rad(120.0))
            return (_shard_batchdim(noise), _shard_batchdim(f_ep),
                    _shard_batchdim(f_th))

        def accum(loss_fn, params, xs):
            """Mean (loss, aux, grads) of ``loss_fn(params, x)`` over the
            leading microbatch axis of ``xs`` (lax.scan when M > 1)."""
            vg = jax.value_and_grad(loss_fn, has_aux=True)
            x0 = jax.tree.map(lambda v: v[0], xs)
            if M == 1:
                (l, aux), g = vg(params, x0)
                return l, aux, g
            sds = jax.eval_shape(vg, params, x0)
            zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

            def body(acc, x):
                return jax.tree.map(jnp.add, acc, vg(params, x)), None

            ((l, aux), g), _ = jax.lax.scan(body, zeros, xs)
            return (l / M, jax.tree.map(lambda v: v / M, aux),
                    jax.tree.map(lambda v: v / M, g))

        real = jax.tree.map(
            lambda x: x.reshape(M, mb, *x.shape[1:]),
            {"image": img, "e_p": e_p, "theta": theta, "ecal": ecal})

        # scaling is only live when the state actually carries the
        # LossScaleState (a trace-time structure fact), so a state built
        # without the policy keeps the exact pre-policy program
        ls = state.loss_scale if scaling else None

        def phase(loss_fn, params, xs, opt_state, optimizer, ls):
            """One Algorithm-1 phase: accumulate grads, reduce, update.

            Under a scaling policy the loss is multiplied by the dynamic
            scale before the backward pass; the reduced UNSCALED grads
            are checked for finiteness (after the psum, so every replica
            agrees) and a nonfinite phase skips its update entirely.
            Returns (loss, aux, params, opt_state, ls, finite).
            """
            if wrap_params is not None:
                # overlap: each bucket's collective fires mid-backward;
                # psum is linear so reducing the SCALED grads then
                # unscaling matches the post-hoc order within rounding
                base_loss = loss_fn
                loss_fn = lambda p, x: base_loss(wrap_params(p), x)
            if ls is None:
                l, aux, g = accum(loss_fn, params, xs)
                upd, new_opt = optimizer.update(reduce_grads(g), opt_state,
                                                params)
                return (l, aux, opt_lib.apply_updates(params, upd), new_opt,
                        None, jnp.float32(1.0))

            def scaled(p, x):
                l_, aux_ = loss_fn(p, x)
                return l_ * ls.scale, aux_

            l, aux, g = accum(scaled, params, xs)
            g = reduce_grads(precision_lib.unscale(ls, g))
            finite = precision_lib.all_finite(g)
            upd, new_opt = optimizer.update(g, opt_state, params)
            new_params = precision_lib.select_finite(
                finite, opt_lib.apply_updates(params, upd), params)
            new_opt = precision_lib.select_finite(finite, new_opt, opt_state)
            ls2 = precision_lib.next_loss_scale(ls, finite,
                                                policy.growth_interval)
            return (l / ls.scale, aux, new_params, new_opt, ls2,
                    finite.astype(jnp.float32))

        g_params_c = to_compute(state.g_params)   # fake-path G, nondiff

        # ---- D on real ------------------------------------------------
        def d_loss_real(dp, x):
            return gan.disc_loss(to_compute(dp), x["image"],
                                 (x["e_p"], x["theta"], x["ecal"]), cfg,
                                 real=True)
        d_lr, d_mr, d_params, d_opt, ls, fin_r = phase(
            d_loss_real, state.d_params, real, state.d_opt, d_optimizer, ls)

        # ---- D on fake (generation INSIDE the compiled program) -------
        def d_loss_fake(dp, k):
            noise, f_ep, f_th = sample_inputs(k)
            fake = gan.generate(g_params_c, noise, f_ep, f_th, cfg)
            return gan.disc_loss(to_compute(dp), jax.lax.stop_gradient(fake),
                                 (f_ep, f_th, f_ep * ecal_frac), cfg,
                                 real=False)
        d_lf, d_mf, d_params, d_opt, ls, fin_f = phase(
            d_loss_fake, d_params, d_keys, d_opt, d_optimizer, ls)

        d_params_c = to_compute(d_params)         # G-phase D, nondiff

        # ---- G twice ---------------------------------------------------
        def one_g(carry, ks):
            g_params, g_opt, ls = carry

            def loss(gp, k):
                noise, f_ep, f_th = sample_inputs(k)
                return gan.gen_loss(to_compute(gp), d_params_c, noise,
                                    (f_ep, f_th, f_ep * ecal_frac), cfg)
            g_l, _, g_params, g_opt, ls, fin = phase(
                loss, g_params, ks, g_opt, g_optimizer, ls)
            return (g_params, g_opt, ls), (g_l, fin)

        (g_params, g_opt, ls), (g_ls, g_fins) = jax.lax.scan(
            one_g, (state.g_params, state.g_opt, ls), g_keys)

        new = GANState(g_params, d_params, g_opt, d_opt, state.step + 1,
                       ls if scaling else state.loss_scale)
        metrics = {"d_loss_real": d_lr, "d_loss_fake": d_lf,
                   "g_loss": jnp.mean(g_ls), "d_acc_real": d_mr["acc"],
                   "d_acc_fake": d_mf["acc"]}
        if ls is not None:
            n_phases = 2.0 + cfg.gen_steps_per_disc
            metrics["loss_scale"] = ls.scale
            metrics["nonfinite_skips"] = (
                n_phases - (fin_r + fin_f + jnp.sum(g_fins)))
        return new, metrics

    return fused_step
