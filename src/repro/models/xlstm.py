"""xLSTM LM (xlstm-125m): alternating mLSTM / sLSTM residual blocks.

``cfg.layer_pattern`` is a string over {"x": mLSTM, "s": sLSTM}; blocks are
grouped by kind and each kind is stacked + scanned (uniform params), with the
original interleaving preserved by running per-kind scans over contiguous
runs of the pattern.  For the 12-layer config we simply python-loop — HLO is
small because each block is O(1) ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import sharding
from repro.substrate import layers, ssm

_EXPAND = 2


def _pattern(cfg):
    pat = cfg.layer_pattern or "x" * cfg.n_layers
    assert len(pat) == cfg.n_layers
    return pat


def init(rng, cfg):
    pat = _pattern(cfg)
    keys = jax.random.split(rng, cfg.n_layers + 2)
    blocks = []
    for i, ch in enumerate(pat):
        if ch == "x":
            b = {"ln": layers.init_norm(cfg.d_model, cfg.norm_type),
                 "mlstm": ssm.init_mlstm(keys[i], cfg.d_model, cfg.n_heads,
                                         _EXPAND)}
        else:
            b = {"ln": layers.init_norm(cfg.d_model, cfg.norm_type),
                 "slstm": ssm.init_slstm(keys[i], cfg.d_model, cfg.n_heads)}
        blocks.append(b)
    return {
        "embed": layers.init_embed(keys[-2], cfg.vocab, cfg.d_model),
        "blocks": blocks,
        "ln_f": layers.init_norm(cfg.d_model, cfg.norm_type),
        "head": {"w": layers.normal_init(keys[-1], (cfg.d_model, cfg.vocab))},
    }


def logical_axes(cfg):
    pat = _pattern(cfg)
    blocks = []
    for ch in pat:
        if ch == "x":
            blocks.append({"ln": layers.norm_axes(cfg.norm_type),
                           "mlstm": ssm.mlstm_axes()})
        else:
            blocks.append({"ln": layers.norm_axes(cfg.norm_type),
                           "slstm": ssm.slstm_axes()})
    return {
        "embed": layers.embed_axes(),
        "blocks": blocks,
        "ln_f": layers.norm_axes(cfg.norm_type),
        "head": {"w": ("embed", "vocab")},
    }


def _apply_block(b, x, cfg, state=None, return_state=False):
    h = layers.apply_norm(b["ln"], x, cfg.norm_type)
    if "mlstm" in b:
        out = ssm.apply_mlstm(b["mlstm"], h, cfg.n_heads, chunk=cfg.ssm.chunk,
                              init_state=state, return_state=return_state)
    else:
        out = ssm.apply_slstm(b["slstm"], h, cfg.n_heads,
                              init_state=state, return_state=return_state)
    if return_state:
        y, st = out
        return x + y, st
    return x + out


def forward(params, tokens, cfg, *, policy, mesh=None, remat=True, **_):
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)
    for b in cparams["blocks"]:
        fn = (jax.checkpoint(lambda bb, xx: _apply_block(bb, xx, cfg))
              if remat else (lambda bb, xx: _apply_block(bb, xx, cfg)))
        x = fn(b, x)
        x = sharding.constrain_batch(x, mesh, seq_dim=1)
    h = layers.apply_norm(cparams["ln_f"], x, cfg.norm_type)
    return h, jnp.zeros((), jnp.float32), cparams


def loss_fn(params, batch, cfg, *, policy, mesh=None, remat=True):
    from repro.models.lm import chunked_softmax_xent
    tokens = batch["tokens"]
    h, aux, cparams = forward(params, tokens, cfg, policy=policy, mesh=mesh,
                              remat=remat)
    targets = tokens[:, 1:]
    valid = jnp.ones_like(targets, jnp.float32)
    ce = chunked_softmax_xent(h[:, :-1], cparams["head"]["w"], targets, valid)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving — recurrent state cache (O(1) per token: why long_500k works)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_len=0, dtype=jnp.bfloat16):
    """State cache: per-block recurrent state (independent of max_len)."""
    pat = _pattern(cfg)
    di = _EXPAND * cfg.d_model
    dh = di // cfg.n_heads
    states = []
    for ch in pat:
        if ch == "x":
            states.append(ssm.mlstm_init_state(batch, cfg.n_heads, dh))
        else:
            states.append(ssm.slstm_init_state(batch, cfg.d_model))
    return {"states": states}


def cache_logical_axes(cfg):
    pat = _pattern(cfg)
    states = []
    for ch in pat:
        if ch == "x":
            states.append(ssm.MLSTMState(
                C=("batch", "heads", None, None), n=("batch", "heads", None),
                m=("batch", "heads")))
        else:
            states.append(ssm.SLSTMState(
                c=("batch", "inner"), n=("batch", "inner"),
                h=("batch", "inner"), m=("batch", "inner")))
    return {"states": states}


def prefill(params, tokens, cfg, *, policy, mesh=None, **_):
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)
    states = []
    for b in cparams["blocks"]:
        x, st = _apply_block(b, x, cfg, return_state=True)
        states.append(st)
    h = layers.apply_norm(cparams["ln_f"], x, cfg.norm_type)
    logits = h[:, -1:] @ cparams["head"]["w"].astype(h.dtype)
    return logits.astype(jnp.float32), {"states": states}


def decode_step(params, tokens1, cache, pos, cfg, *, policy, mesh=None, **_):
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens1, policy.compute_dtype)
    new_states = []
    for b, st in zip(cparams["blocks"], cache["states"]):
        h = layers.apply_norm(b["ln"], x, cfg.norm_type)
        if "mlstm" in b:
            y, st2 = ssm.mlstm_step(b["mlstm"], h, st, cfg.n_heads)
        else:
            y, st2 = ssm.slstm_step(b["slstm"], h, st, cfg.n_heads)
        x = x + y
        new_states.append(st2)
    h = layers.apply_norm(cparams["ln_f"], x, cfg.norm_type)
    logits = h @ cparams["head"]["w"].astype(h.dtype)
    return logits.astype(jnp.float32), {"states": new_states}
