"""Decoder-only language model covering the dense, MoE and VLM families.

- dense:  qwen2-1.5b, phi4-mini-3.8b, granite-20b (MQA), nemotron-4-15b
- moe:    dbrx-132b, olmoe-1b-7b  (block FFN -> substrate.moe)
- vlm:    qwen2-vl-72b (M-RoPE positions; vision frontend stubbed — the
          model can consume precomputed patch embeddings via ``embeds``)

Layers are STACKED and applied with lax.scan so HLO size is O(1) in depth
(80-layer dry-runs compile quickly); each block is rematerialised.
The LM head + cross-entropy are computed in sequence chunks so the full
(B, S, vocab) logits tensor is never materialised.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel import sharding
from repro.substrate import attention as attn_lib
from repro.substrate import layers, moe as moe_lib

# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------


def init_block(key, cfg):
    ks = jax.random.split(key, 3)
    p = {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm_type),
        "attn": attn_lib.init_attn(ks[0], cfg),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm_type),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["ffn"] = layers.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type)
    return p


def block_axes(cfg):
    p = {
        "ln1": layers.norm_axes(cfg.norm_type),
        "attn": attn_lib.attn_axes(cfg),
        "ln2": layers.norm_axes(cfg.norm_type),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_axes(cfg)
    else:
        p["ffn"] = layers.ffn_axes(cfg.ffn_type)
    return p


def _attend(q, k, v, *, causal, window, seq_len, use_pallas=False):
    return attn_lib.attend(q, k, v, causal=causal, window=window,
                           use_pallas=use_pallas, seq_len=seq_len)


def apply_block(p, x, cos, sin, cfg, *, window=0, mesh=None):
    """x: (B, S, d) -> (x', aux)."""
    B, S, _ = x.shape
    # H2 (§Perf): force TP-only sharding on the per-layer weight slice so
    # FSDP storage shards are ALL-GATHERED here (small) instead of XLA
    # all-reducing activation-sized partial contractions (huge).
    if mesh is not None:
        p = sharding.constrain_tree(p, block_axes(cfg), mesh,
                                    sharding.TP_RULES)
    h = layers.apply_norm(p["ln1"], x, cfg.norm_type)
    q, k, v = attn_lib.project_qkv(p["attn"], h, cfg)
    q = attn_lib.apply_rope(q, cos, sin) if cos is not None else q
    k = attn_lib.apply_rope(k, cos, sin) if cos is not None else k
    # H5 (§Perf): head-sharded, full-seq activations inside the block —
    # ONLY when heads divide the model axis; otherwise the constraint
    # would force full replication (it cost phi4 3x peak memory).
    h5 = (mesh is not None
          and cfg.n_heads % sharding.mesh_axis_size(mesh, "model") == 0
          and cfg.n_kv_heads > 1)   # MQA: replicated K/V resharding loses
    if h5:
        q = sharding.constrain_act(q, mesh, ("batch", None, "heads", None))
        k = sharding.constrain_act(k, mesh, ("batch", None, "kv_heads", None))
        v = sharding.constrain_act(v, mesh, ("batch", None, "kv_heads", None))
    o = _attend(q, k, v, causal=True, window=window, seq_len=S,
                use_pallas=cfg.use_pallas_attn)
    if h5:
        o = sharding.constrain_act(o, mesh, ("batch", None, "heads", None))
    o = layers.apply_dense(p["attn"]["wo"], o.reshape(B, S, cfg.q_dim))
    x = x + o
    x = sharding.constrain_batch(x, mesh, seq_dim=1)
    h = layers.apply_norm(p["ln2"], x, cfg.norm_type)
    if cfg.moe is not None:
        f, aux, _ = moe_lib.apply_moe(p["moe"], h, cfg)
    else:
        f, aux = layers.apply_ffn(p["ffn"], h, cfg.ffn_type), jnp.zeros((), jnp.float32)
    x = x + f
    return sharding.constrain_batch(x, mesh, seq_dim=1), aux


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init(rng, cfg):
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    p = {
        "embed": layers.init_embed(k_emb, cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(lambda k: init_block(k, cfg))(block_keys),
        "ln_f": layers.init_norm(cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        p["head"] = {"w": layers.normal_init(k_head, (cfg.d_model, cfg.vocab))}
    return p


def logical_axes(cfg):
    p = {
        "embed": layers.embed_axes(),
        "blocks": sharding.stacked(block_axes(cfg)),
        "ln_f": layers.norm_axes(cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        p["head"] = {"w": ("embed", "vocab")}
    return p


def _rope_for(cfg, positions, dtype):
    if cfg.rope_theta <= 0:
        return None, None
    if cfg.mrope:
        return attn_lib.mrope_cos_sin(positions, cfg.d_head, cfg.rope_theta,
                                      cfg.mrope_sections, dtype)
    return attn_lib.rope_cos_sin(positions, cfg.d_head, cfg.rope_theta, dtype)


def backbone(params, x, cfg, *, positions, mesh=None, remat=True, window=0):
    """x: (B, S, d) embedded input -> (hidden (B,S,d), aux)."""
    cos, sin = _rope_for(cfg, positions, x.dtype)

    def body(carry, block_p):
        h, aux = carry
        h, a = apply_block(block_p, h, cos, sin, cfg, window=window, mesh=mesh)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = layers.apply_norm(params["ln_f"], x, cfg.norm_type)
    return x, aux


def _head_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["emb"].T
    return params["head"]["w"]


def forward(params, tokens, cfg, *, policy, positions=None, embeds=None,
            mesh=None, remat=True, window=0):
    """Returns final hidden states (NOT logits — see chunked loss)."""
    cparams = policy.cast_to_compute(params)
    if embeds is not None:
        x = embeds.astype(policy.compute_dtype)
        if tokens is not None:      # VLM: patch embeds replace a token prefix
            tok_emb = layers.apply_embed(cparams["embed"], tokens,
                                         policy.compute_dtype)
            x = jnp.concatenate([x, tok_emb], axis=1)
    else:
        x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    B, S, _ = x.shape
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.broadcast_to(pos[None], (3, B, S)) if cfg.mrope else pos
    x = sharding.constrain_batch(x, mesh, seq_dim=1)
    h, aux = backbone(cparams, x, cfg, positions=positions, mesh=mesh,
                      remat=remat, window=window)
    return h, aux, cparams


def chunked_softmax_xent(h, head_w, targets, valid, chunk=512):
    """Cross-entropy over vocab without materialising (B, S, V).

    h: (B,S,d) hidden; head_w: (d,V); targets: (B,S) int; valid: (B,S) bool.
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = S // chunk

    def body(carry, i):
        loss_sum, cnt = carry
        hs = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        ts = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(valid, i * chunk, chunk, axis=1)
        logits = (hs @ head_w.astype(hs.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * vs
        return (loss_sum + jnp.sum(nll), cnt + jnp.sum(vs)), None

    (loss_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    rem = S - n * chunk
    if rem:
        logits = (h[:, n * chunk:] @ head_w.astype(h.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, targets[:, n * chunk:, None], axis=-1)[..., 0]
        nll = (lse - tgt) * valid[:, n * chunk:]
        loss_sum += jnp.sum(nll)
        cnt += jnp.sum(valid[:, n * chunk:])
    return loss_sum / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Serving: KV-cache prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    """KV cache pytree. For sliding-window serving, max_len = window and the
    cache is a ring buffer (rope is applied to k at write time, so ring order
    does not matter)."""
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_logical_axes(cfg):
    # seq dim -> 'model' (cache_seq rule): a 32k-long KV cache is by far the
    # biggest decode-time tensor; kv_heads rarely divide the model axis (GQA
    # kv<=8 vs model=16) so the sequence axis carries the model parallelism.
    return {"k": (None, "batch", "cache_seq", "kv_heads", None),
            "v": (None, "batch", "cache_seq", "kv_heads", None)}


def prefill(params, tokens, cfg, *, policy, positions=None, embeds=None,
            mesh=None, window=0, max_len=None):
    """Run the full prompt, return (last-token logits, cache).

    ``max_len``: serving capacity — the returned cache is right-padded so
    decode_step can append (decode writes at absolute position; for
    windowed serving pass max_len=window and the last `window` entries are
    stored position-aligned, matching decode's ``pos % window`` ring)."""
    cparams = policy.cast_to_compute(params)
    if embeds is not None:
        x = embeds.astype(policy.compute_dtype)
    else:
        x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    B, S, _ = x.shape
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.broadcast_to(pos[None], (3, B, S)) if cfg.mrope else pos
    cos, sin = _rope_for(cfg, positions, x.dtype)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)

    def body(h, block_p):
        if mesh is not None:                      # H2: see apply_block
            block_p = sharding.constrain_tree(block_p, block_axes(cfg),
                                              mesh, sharding.TP_RULES)
        hn = layers.apply_norm(block_p["ln1"], h, cfg.norm_type)
        q, k, v = attn_lib.project_qkv(block_p["attn"], hn, cfg)
        q = attn_lib.apply_rope(q, cos, sin) if cos is not None else q
        k = attn_lib.apply_rope(k, cos, sin) if cos is not None else k
        o = _attend(q, k, v, causal=True, window=window, seq_len=S,
                    use_pallas=cfg.use_pallas_attn)
        o = layers.apply_dense(block_p["attn"]["wo"], o.reshape(B, S, cfg.q_dim))
        h = h + o
        hn = layers.apply_norm(block_p["ln2"], h, cfg.norm_type)
        if cfg.moe is not None:
            f, _, _ = moe_lib.apply_moe(block_p["moe"], hn, cfg)
        else:
            f = layers.apply_ffn(block_p["ffn"], hn, cfg.ffn_type)
        h = sharding.constrain_batch(h + f, mesh, seq_dim=1)
        return h, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    body = jax.checkpoint(body)
    h, (ks, vs) = jax.lax.scan(body, x, cparams["blocks"])
    h = layers.apply_norm(cparams["ln_f"], h, cfg.norm_type)
    logits = (h[:, -1:] @ _head_matrix(cparams, cfg).astype(h.dtype))
    if max_len is not None:
        cap = min(max_len, window) if window else max_len
        if S >= cap:        # keep last `cap`, position-aligned ring slots
            ks = jnp.roll(ks[:, :, S - cap:], S % cap, axis=2)
            vs = jnp.roll(vs[:, :, S - cap:], S % cap, axis=2)
        else:
            pad = ((0, 0), (0, 0), (0, cap - S), (0, 0), (0, 0))
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


def decode_step(params, tokens1, cache, pos, cfg, *, policy, positions=None,
                mesh=None, window=0):
    """One decode step.  tokens1: (B, 1); pos: scalar int OR (B,) int
    vector of per-sequence absolute positions (ragged continuous
    batching: every slot decodes at its own depth); cache: {"k","v"}
    (L, B, T, KH, D).  Returns (logits, cache)."""
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens1, policy.compute_dtype)
    B = x.shape[0]
    T = cache["k"].shape[2]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos), (B,))       # (B,)
    if positions is None:
        pos_b = pos_vec[:, None]
        positions = (jnp.broadcast_to(pos_b[None], (3, B, 1))
                     if cfg.mrope else pos_b)
    cos, sin = _rope_for(cfg, positions, x.dtype)
    write_idx = pos_vec % T if window else pos_vec           # (B,)
    kv_len = jnp.minimum(pos_vec + 1, T)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)

    def _write(c, new):
        """Per-row cache write at each sequence's own position."""
        return jax.vmap(
            lambda cb, nb, i: jax.lax.dynamic_update_slice_in_dim(
                cb, nb, i, axis=0))(c, new.astype(c.dtype), write_idx)

    def body(h, xs):
        block_p, kc, vc = xs
        hn = layers.apply_norm(block_p["ln1"], h, cfg.norm_type)
        q, k, v = attn_lib.project_qkv(block_p["attn"], hn, cfg)
        q = attn_lib.apply_rope(q, cos, sin) if cos is not None else q
        k = attn_lib.apply_rope(k, cos, sin) if cos is not None else k
        kc = _write(kc, k)
        vc = _write(vc, v)
        o = attn_lib.attend(
            q, kc.astype(q.dtype), vc.astype(q.dtype), causal=False,
            kv_len=kv_len, use_pallas=cfg.use_pallas_attn)
        o = layers.apply_dense(block_p["attn"]["wo"], o.reshape(B, 1, cfg.q_dim))
        h = h + o
        hn = layers.apply_norm(block_p["ln2"], h, cfg.norm_type)
        if cfg.moe is not None:
            f, _, _ = moe_lib.apply_moe(block_p["moe"], hn, cfg)
        else:
            f = layers.apply_ffn(block_p["ffn"], hn, cfg.ffn_type)
        return h + f, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, x, (cparams["blocks"],
                                         cache["k"], cache["v"]))
    h = layers.apply_norm(cparams["ln_f"], h, cfg.norm_type)
    logits = h @ _head_matrix(cparams, cfg).astype(h.dtype)
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


def prefill_chunk(params, tokens, cache, pos, lens, cfg, *, policy,
                  positions=None, mesh=None, window=0):
    """Batched chunked prefill: run C prompt positions for every active
    slot in ONE launch, writing K/V straight into each slot's cache region.

    tokens: (B, C) prompt chunk per slot; pos: (B,) absolute cache
    position of each slot's chunk start; lens: (B,) valid tokens of this
    chunk per slot (0 = slot not prefilling — its cache row and logits
    are left untouched / unused).  Requires pos + lens <= T (the engine
    caps prompts at the cache capacity, so chunk writes never wrap the
    ring).  Returns (last-valid-token logits (B, 1, V), cache).
    """
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    B, C, _ = x.shape
    T = cache["k"].shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    kv_len = pos + lens                                      # (B,)
    qpos = pos[:, None] + jnp.arange(C)[None]                # (B, C)
    if positions is None:
        positions = (jnp.broadcast_to(qpos[None], (3, B, C))
                     if cfg.mrope else qpos)
    cos, sin = _rope_for(cfg, positions, x.dtype)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)

    t = jnp.arange(T)
    write_mask = (t[None] >= pos[:, None]) & (t[None] < kv_len[:, None])
    gather_idx = jnp.clip(t[None] - pos[:, None], 0, C - 1)  # (B, T)

    def _write(c, new):
        """Masked scatter of the chunk into [pos, pos+lens) per row — a
        gather + where rather than dynamic_update_slice, so rows whose
        chunk tail is padding (i >= lens) never touch the cache and
        inactive rows (lens = 0) are bit-identical no-ops."""
        g = jnp.take_along_axis(new.astype(c.dtype),
                                gather_idx[:, :, None, None], axis=1)
        return jnp.where(write_mask[:, :, None, None], g, c)

    def body(h, xs):
        block_p, kc, vc = xs
        if mesh is not None:                      # H2: see apply_block
            block_p = sharding.constrain_tree(block_p, block_axes(cfg),
                                              mesh, sharding.TP_RULES)
        hn = layers.apply_norm(block_p["ln1"], h, cfg.norm_type)
        q, k, v = attn_lib.project_qkv(block_p["attn"], hn, cfg)
        q = attn_lib.apply_rope(q, cos, sin) if cos is not None else q
        k = attn_lib.apply_rope(k, cos, sin) if cos is not None else k
        kc = _write(kc, k)
        vc = _write(vc, v)
        o = attn_lib.attend(
            q, kc.astype(q.dtype), vc.astype(q.dtype), causal=True,
            kv_len=kv_len, q_offset=pos, use_pallas=cfg.use_pallas_attn)
        o = layers.apply_dense(block_p["attn"]["wo"], o.reshape(B, C, cfg.q_dim))
        h = h + o
        hn = layers.apply_norm(block_p["ln2"], h, cfg.norm_type)
        if cfg.moe is not None:
            f, _, _ = moe_lib.apply_moe(block_p["moe"], hn, cfg)
        else:
            f = layers.apply_ffn(block_p["ffn"], hn, cfg.ffn_type)
        return h + f, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, x, (cparams["blocks"],
                                         cache["k"], cache["v"]))
    h = layers.apply_norm(cparams["ln_f"], h, cfg.norm_type)
    last = jnp.clip(lens - 1, 0, C - 1)                      # (B,)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)  # (B,1,d)
    logits = h_last @ _head_matrix(cparams, cfg).astype(h.dtype)
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


def loss_fn(params, batch, cfg, *, policy, mesh=None, remat=True):
    tokens = batch["tokens"]
    positions = batch.get("positions")
    embeds = batch.get("embeds")
    h, aux, cparams = forward(params, tokens, cfg, policy=policy,
                              positions=positions, embeds=embeds,
                              mesh=mesh, remat=remat)
    # next-token prediction over the token region (embeds prefix has no labels)
    if embeds is not None:
        h = h[:, embeds.shape[1]:]
    targets = tokens[:, 1:]
    hh = h[:, :-1]
    valid = jnp.ones_like(targets, jnp.float32)
    head_w = _head_matrix(cparams, cfg)
    ce = chunked_softmax_xent(hh, head_w, targets, valid)
    return ce + aux, {"ce": ce, "aux": aux}
