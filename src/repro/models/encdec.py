"""Whisper-style encoder-decoder (whisper-base).

The mel-spectrogram + conv feature extractor is a STUB per assignment: the
model consumes precomputed frame embeddings (B, frames, d_model) provided by
``input_specs`` / the data pipeline.  Positions are learned absolute
embeddings (whisper uses sinusoidal for the encoder — we keep one learned
table each; the backbone semantics are what matters here).

Serving: decode_32k exercises cross-attention over a 32 768-frame encoder
memory (how whisper serves long audio); decoder self-attention cache is
capped at cfg.max_target_positions (448).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import sharding
from repro.substrate import attention as attn_lib
from repro.substrate import layers


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm_type),
        "attn": attn_lib.init_attn(ks[0], cfg),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm_type),
        "ffn": layers.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm_type),
        "self_attn": attn_lib.init_attn(ks[0], cfg),
        "ln_x": layers.init_norm(cfg.d_model, cfg.norm_type),
        "cross_attn": attn_lib.init_attn(ks[1], cfg),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm_type),
        "ffn": layers.init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def _enc_block_axes(cfg):
    return {"ln1": layers.norm_axes(cfg.norm_type),
            "attn": attn_lib.attn_axes(cfg),
            "ln2": layers.norm_axes(cfg.norm_type),
            "ffn": layers.ffn_axes(cfg.ffn_type)}


def _dec_block_axes(cfg):
    return {"ln1": layers.norm_axes(cfg.norm_type),
            "self_attn": attn_lib.attn_axes(cfg),
            "ln_x": layers.norm_axes(cfg.norm_type),
            "cross_attn": attn_lib.attn_axes(cfg),
            "ln2": layers.norm_axes(cfg.norm_type),
            "ffn": layers.ffn_axes(cfg.ffn_type)}


def init(rng, cfg):
    ks = jax.random.split(rng, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_pos": layers.normal_init(ks[2], (cfg.max_source_positions,
                                              cfg.d_model), 0.01),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "enc_ln": layers.init_norm(cfg.d_model, cfg.norm_type),
        "embed": layers.init_embed(ks[3], cfg.vocab, cfg.d_model),
        "dec_pos": layers.normal_init(ks[4], (cfg.max_target_positions,
                                              cfg.d_model), 0.01),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "dec_ln": layers.init_norm(cfg.d_model, cfg.norm_type),
    }


def logical_axes(cfg):
    return {
        "enc_pos": (None, "embed"),
        "enc_blocks": sharding.stacked(_enc_block_axes(cfg)),
        "enc_ln": layers.norm_axes(cfg.norm_type),
        "embed": layers.embed_axes(),
        "dec_pos": (None, "embed"),
        "dec_blocks": sharding.stacked(_dec_block_axes(cfg)),
        "dec_ln": layers.norm_axes(cfg.norm_type),
    }


def _attend(q, k, v, causal, S):
    if max(S, k.shape[1]) <= 1024:
        return attn_lib.dot_attention(q, k, v, causal=causal)
    return attn_lib.blockwise_attention(q, k, v, causal=causal)


def encode(cparams, audio_emb, cfg, mesh=None, remat=True):
    B, F, _ = audio_emb.shape
    pos = cparams["enc_pos"]
    if F <= pos.shape[0]:
        x = audio_emb + pos[None, :F].astype(audio_emb.dtype)
    else:   # long-audio serving: tile the positional table
        reps = -(-F // pos.shape[0])
        x = audio_emb + jnp.tile(pos, (reps, 1))[None, :F].astype(audio_emb.dtype)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)

    def body(h, bp):
        hn = layers.apply_norm(bp["ln1"], h, cfg.norm_type)
        q, k, v = attn_lib.project_qkv(bp["attn"], hn, cfg)
        o = _attend(q, k, v, causal=False, S=F)
        h = h + layers.apply_dense(bp["attn"]["wo"], o.reshape(B, F, cfg.q_dim))
        hn = layers.apply_norm(bp["ln2"], h, cfg.norm_type)
        h = h + layers.apply_ffn(bp["ffn"], hn, cfg.ffn_type)
        return sharding.constrain_batch(h, mesh, seq_dim=1), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, cparams["enc_blocks"])
    return layers.apply_norm(cparams["enc_ln"], x, cfg.norm_type)


def decode(cparams, tokens, memory, cfg, mesh=None, remat=True):
    B, T = tokens.shape
    x = layers.apply_embed(cparams["embed"], tokens, memory.dtype)
    x = x + cparams["dec_pos"][None, :T].astype(x.dtype)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)
    F = memory.shape[1]

    def body(h, bp):
        hn = layers.apply_norm(bp["ln1"], h, cfg.norm_type)
        q, k, v = attn_lib.project_qkv(bp["self_attn"], hn, cfg)
        o = _attend(q, k, v, causal=True, S=T)
        h = h + layers.apply_dense(bp["self_attn"]["wo"],
                                   o.reshape(B, T, cfg.q_dim))
        hn = layers.apply_norm(bp["ln_x"], h, cfg.norm_type)
        q = layers.apply_dense(bp["cross_attn"]["wq"], hn).reshape(
            B, T, cfg.n_heads, cfg.d_head)
        mk = layers.apply_dense(bp["cross_attn"]["wk"], memory).reshape(
            B, F, cfg.n_kv_heads, cfg.d_head)
        mv = layers.apply_dense(bp["cross_attn"]["wv"], memory).reshape(
            B, F, cfg.n_kv_heads, cfg.d_head)
        o = _attend(q, mk, mv, causal=False, S=T)
        h = h + layers.apply_dense(bp["cross_attn"]["wo"],
                                   o.reshape(B, T, cfg.q_dim))
        hn = layers.apply_norm(bp["ln2"], h, cfg.norm_type)
        h = h + layers.apply_ffn(bp["ffn"], hn, cfg.ffn_type)
        return sharding.constrain_batch(h, mesh, seq_dim=1), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, cparams["dec_blocks"])
    return layers.apply_norm(cparams["dec_ln"], x, cfg.norm_type)


def loss_fn(params, batch, cfg, *, policy, mesh=None, remat=True):
    from repro.models.lm import chunked_softmax_xent
    cparams = policy.cast_to_compute(params)
    audio = batch["audio_emb"].astype(policy.compute_dtype)
    tokens = batch["tokens"]
    memory = encode(cparams, audio, cfg, mesh, remat)
    h = decode(cparams, tokens, memory, cfg, mesh, remat)
    targets = tokens[:, 1:]
    valid = jnp.ones_like(targets, jnp.float32)
    head_w = cparams["embed"]["emb"].T            # whisper ties emb/head
    ce = chunked_softmax_xent(h[:, :-1], head_w, targets, valid, chunk=128)
    return ce, {"ce": ce, "aux": jnp.zeros(())}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    """decode cache: self-KV (<=448) + cross-KV over `max_len` frames."""
    T = cfg.max_target_positions
    self_shape = (cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.d_head)
    cross_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"self_k": jnp.zeros(self_shape, dtype),
            "self_v": jnp.zeros(self_shape, dtype),
            "cross_k": jnp.zeros(cross_shape, dtype),
            "cross_v": jnp.zeros(cross_shape, dtype)}


def cache_logical_axes(cfg):
    # cross-attention memory carries the 32k frames -> shard its seq dim
    ax = (None, "batch", "cache_seq", "kv_heads", None)
    return {"self_k": ax, "self_v": ax, "cross_k": ax, "cross_v": ax}


def prefill(params, audio_emb, cfg, *, policy, mesh=None, **_):
    """Encode audio and precompute per-layer cross-attention K/V."""
    cparams = policy.cast_to_compute(params)
    memory = encode(cparams, audio_emb.astype(policy.compute_dtype), cfg, mesh)
    B, F, _ = memory.shape

    def per_layer(bp):
        mk = layers.apply_dense(bp["cross_attn"]["wk"], memory).reshape(
            B, F, cfg.n_kv_heads, cfg.d_head)
        mv = layers.apply_dense(bp["cross_attn"]["wv"], memory).reshape(
            B, F, cfg.n_kv_heads, cfg.d_head)
        return mk.astype(jnp.bfloat16), mv.astype(jnp.bfloat16)

    ck, cv = jax.vmap(per_layer)(cparams["dec_blocks"])
    T = cfg.max_target_positions
    self_shape = (cfg.n_layers, B, T, cfg.n_kv_heads, cfg.d_head)
    return memory, {"self_k": jnp.zeros(self_shape, jnp.bfloat16),
                    "self_v": jnp.zeros(self_shape, jnp.bfloat16),
                    "cross_k": ck, "cross_v": cv}


def decode_step(params, tokens1, cache, pos, cfg, *, policy, mesh=None, **_):
    """pos: scalar OR (B,) per-sequence positions (ragged batching)."""
    cparams = policy.cast_to_compute(params)
    B = tokens1.shape[0]
    x = layers.apply_embed(cparams["embed"], tokens1, policy.compute_dtype)
    pos_vec = jnp.broadcast_to(jnp.asarray(pos), (B,))
    tpos = jnp.minimum(pos_vec, cfg.max_target_positions - 1)   # (B,)
    x = x + cparams["dec_pos"][tpos][:, None].astype(x.dtype)
    kv_len = jnp.minimum(pos_vec + 1, cfg.max_target_positions)

    def body(h, xs):
        bp, sk, sv, ck, cv = xs
        hn = layers.apply_norm(bp["ln1"], h, cfg.norm_type)
        q, k, v = attn_lib.project_qkv(bp["self_attn"], hn, cfg)
        sk = jax.vmap(lambda cb, nb, i: jax.lax.dynamic_update_slice_in_dim(
            cb, nb, i, axis=0))(sk, k.astype(sk.dtype), tpos)
        sv = jax.vmap(lambda cb, nb, i: jax.lax.dynamic_update_slice_in_dim(
            cb, nb, i, axis=0))(sv, v.astype(sv.dtype), tpos)
        o = attn_lib.dot_attention(q, sk.astype(q.dtype), sv.astype(q.dtype),
                                   causal=False, kv_len=kv_len)
        h = h + layers.apply_dense(bp["self_attn"]["wo"],
                                   o.reshape(B, 1, cfg.q_dim))
        hn = layers.apply_norm(bp["ln_x"], h, cfg.norm_type)
        q = layers.apply_dense(bp["cross_attn"]["wq"], hn).reshape(
            B, 1, cfg.n_heads, cfg.d_head)
        o = attn_lib.dot_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                   causal=False)
        h = h + layers.apply_dense(bp["cross_attn"]["wo"],
                                   o.reshape(B, 1, cfg.q_dim))
        hn = layers.apply_norm(bp["ln2"], h, cfg.norm_type)
        h = h + layers.apply_ffn(bp["ffn"], hn, cfg.ffn_type)
        return h, (sk, sv)

    x, (sks, svs) = jax.lax.scan(
        body, x, (cparams["dec_blocks"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    h = layers.apply_norm(cparams["dec_ln"], x, cfg.norm_type)
    logits = h @ cparams["embed"]["emb"].T.astype(h.dtype)
    return logits.astype(jnp.float32), {
        "self_k": sks, "self_v": svs,
        "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
