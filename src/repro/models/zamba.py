"""Zamba2 hybrid LM: Mamba2 backbone + ONE shared attention block.

The shared block (its params are reused at every application — zamba2's
parameter-sharing trick) runs on concat(hidden, initial_embedding) (2*d) and
projects back to d.  Mamba2 layers are stacked and scanned; the shared block
fires every ``cfg.shared_attn_every`` layers via lax.cond inside the scan, so
HLO contains exactly one mamba block + one attention block regardless of
depth.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.parallel import sharding
from repro.substrate import attention as attn_lib
from repro.substrate import layers, ssm


def _shared_cfg(cfg):
    """Attention geometry of the shared block: runs at width 2*d_model."""
    return dataclasses.replace(
        cfg, d_model=2 * cfg.d_model, d_head=2 * cfg.d_model // cfg.n_heads,
        qkv_bias=False)


def init(rng, cfg):
    ks = jax.random.split(rng, 6)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    scfg = _shared_cfg(cfg)
    d2 = 2 * cfg.d_model
    return {
        "embed": layers.init_embed(ks[1], cfg.vocab, cfg.d_model),
        "mamba": jax.vmap(
            lambda k: {"ln": layers.init_norm(cfg.d_model, "rmsnorm"),
                       "m": ssm.init_mamba2(k, cfg.d_model, cfg.ssm)})(layer_keys),
        "shared": {
            "ln": layers.init_norm(d2, "rmsnorm"),
            "attn": attn_lib.init_attn(ks[2], scfg),
            "out": layers.init_dense(ks[3], d2, cfg.d_model),
            "ln2": layers.init_norm(cfg.d_model, "rmsnorm"),
            "ffn": layers.init_ffn(ks[4], cfg.d_model, cfg.d_ff, cfg.ffn_type),
        },
        "ln_f": layers.init_norm(cfg.d_model, "rmsnorm"),
        "head": {"w": layers.normal_init(ks[5], (cfg.d_model, cfg.vocab))},
    }


def logical_axes(cfg):
    scfg = _shared_cfg(cfg)
    return {
        "embed": layers.embed_axes(),
        "mamba": sharding.stacked({"ln": layers.norm_axes("rmsnorm"),
                                   "m": ssm.mamba2_axes()}),
        "shared": {
            "ln": layers.norm_axes("rmsnorm"),
            "attn": attn_lib.attn_axes(scfg),
            "out": layers.dense_axes("heads", "embed"),
            "ln2": layers.norm_axes("rmsnorm"),
            "ffn": layers.ffn_axes(cfg.ffn_type),
        },
        "ln_f": layers.norm_axes("rmsnorm"),
        "head": {"w": ("embed", "vocab")},
    }


def _apply_shared(sp, x, x0, cfg, cos, sin, cache=None, pos=None):
    """Shared attention block on concat(x, x0); returns (delta, new kv)."""
    scfg = _shared_cfg(cfg)
    B, S, _ = x.shape
    h = jnp.concatenate([x, x0], axis=-1)
    h = layers.apply_norm(sp["ln"], h, "rmsnorm")
    q, k, v = attn_lib.project_qkv(sp["attn"], h, scfg)
    if cos is not None:
        q, k = attn_lib.apply_rope(q, cos, sin), attn_lib.apply_rope(k, cos, sin)
    if cache is None:
        o = attn_lib.attend(q, k, v, causal=True, seq_len=S,
                            use_pallas=cfg.use_pallas_attn)
        new_kv = (k, v)
    else:
        kc, vc, kv_len = cache
        idx = jnp.broadcast_to(jnp.asarray(pos), (B,))       # per-row slots
        kc = jax.vmap(lambda cb, nb, i: jax.lax.dynamic_update_slice_in_dim(
            cb, nb, i, axis=0))(kc, k.astype(kc.dtype), idx)
        vc = jax.vmap(lambda cb, nb, i: jax.lax.dynamic_update_slice_in_dim(
            cb, nb, i, axis=0))(vc, v.astype(vc.dtype), idx)
        o = attn_lib.attend(q, kc.astype(q.dtype), vc.astype(q.dtype),
                            causal=False,
                            kv_len=jnp.broadcast_to(kv_len, (B,)),
                            use_pallas=cfg.use_pallas_attn)
        new_kv = (kc, vc)
    o = layers.apply_dense(sp["out"], o.reshape(B, S, scfg.q_dim))
    x = x + o
    hn = layers.apply_norm(sp["ln2"], x, "rmsnorm")
    x = x + layers.apply_ffn(sp["ffn"], hn, cfg.ffn_type)
    return x, new_kv


def forward(params, tokens, cfg, *, policy, mesh=None, remat=True, **_):
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    x = sharding.constrain_batch(x, mesh, seq_dim=1)
    x0 = x
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = attn_lib.rope_cos_sin(pos, _shared_cfg(cfg).d_head,
                                     cfg.rope_theta, x.dtype)
    every = max(cfg.shared_attn_every, 1)
    shared = cparams["shared"]

    def body(carry, xs):
        h, idx = carry
        block = xs
        hn = layers.apply_norm(block["ln"], h, "rmsnorm")
        h = h + ssm.apply_mamba2(block["m"], hn, cfg.d_model, cfg.ssm,
                                 use_pallas=cfg.use_pallas_ssm)
        h = jax.lax.cond(
            idx % every == 0,
            lambda hh: _apply_shared(shared, hh, x0, cfg, cos, sin)[0],
            lambda hh: hh, h)
        h = sharding.constrain_batch(h, mesh, seq_dim=1)
        return (h, idx + 1), None

    if remat:
        body = jax.checkpoint(body)
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)),
                             cparams["mamba"])
    h = layers.apply_norm(cparams["ln_f"], x, "rmsnorm")
    return h, jnp.zeros((), jnp.float32), cparams


def loss_fn(params, batch, cfg, *, policy, mesh=None, remat=True):
    from repro.models.lm import chunked_softmax_xent
    tokens = batch["tokens"]
    h, aux, cparams = forward(params, tokens, cfg, policy=policy, mesh=mesh,
                              remat=remat)
    targets = tokens[:, 1:]
    valid = jnp.ones_like(targets, jnp.float32)
    ce = chunked_softmax_xent(h[:, :-1], cparams["head"]["w"], targets, valid)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: mamba states + shared-block KV ring buffer
# ---------------------------------------------------------------------------

_SHARED_WINDOW = 4096   # the shared block attends over a sliding window when
                        # serving beyond-context lengths (long_500k)


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    n_shared = len(_shared_idx(cfg))
    win = min(max_len, _SHARED_WINDOW)
    scfg = _shared_cfg(cfg)
    kv_shape = (n_shared, batch, win, scfg.n_kv_heads, scfg.d_head)
    st = ssm.mamba2_init_state(cfg.d_model, cfg.ssm, batch)
    return {
        "mamba": ssm.Mamba2State(
            ssm=jnp.zeros((cfg.n_layers,) + st.ssm.shape, jnp.float32),
            conv=jnp.zeros((cfg.n_layers,) + st.conv.shape, dtype)),
        "shared_k": jnp.zeros(kv_shape, dtype),
        "shared_v": jnp.zeros(kv_shape, dtype),
    }


def cache_logical_axes(cfg):
    return {
        "mamba": ssm.Mamba2State(
            ssm=(None, "batch", "inner", None, None),
            conv=(None, "batch", None, "inner")),
        "shared_k": (None, "batch", "cache_seq", "kv_heads", None),
        "shared_v": (None, "batch", "cache_seq", "kv_heads", None),
    }


def _shared_idx(cfg):
    every = max(cfg.shared_attn_every, 1)
    return [i for i in range(cfg.n_layers) if i % every == 0]


def decode_step(params, tokens1, cache, pos, cfg, *, policy, mesh=None, **_):
    """pos: scalar OR (B,) per-sequence positions (ragged batching)."""
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens1, policy.compute_dtype)
    x0 = x
    B = x.shape[0]
    win = cache["shared_k"].shape[2]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos), (B,))
    write_idx = pos_vec % win                                # (B,)
    kv_len = jnp.minimum(pos_vec + 1, win)
    pos_b = pos_vec[:, None]
    cos, sin = attn_lib.rope_cos_sin(pos_b, _shared_cfg(cfg).d_head,
                                     cfg.rope_theta, x.dtype)
    shared_ids = _shared_idx(cfg)
    new_m_ssm, new_m_conv = [], []
    sk, sv = cache["shared_k"], cache["shared_v"]
    si = 0
    for i in range(cfg.n_layers):
        block = jax.tree.map(lambda t: t[i], cparams["mamba"])
        st = ssm.Mamba2State(ssm=cache["mamba"].ssm[i],
                             conv=cache["mamba"].conv[i])
        hn = layers.apply_norm(block["ln"], x, "rmsnorm")
        y, st2 = ssm.mamba2_step(block["m"], hn, st, cfg.d_model, cfg.ssm)
        x = x + y
        new_m_ssm.append(st2.ssm)
        new_m_conv.append(st2.conv)
        if i in shared_ids:
            x, (kc, vc) = _apply_shared(
                cparams["shared"], x, x0, cfg, cos, sin,
                cache=(sk[si], sv[si], kv_len), pos=write_idx)
            sk = sk.at[si].set(kc)
            sv = sv.at[si].set(vc)
            si += 1
    h = layers.apply_norm(cparams["ln_f"], x, "rmsnorm")
    logits = h @ cparams["head"]["w"].astype(h.dtype)
    new_cache = {
        "mamba": ssm.Mamba2State(ssm=jnp.stack(new_m_ssm),
                                 conv=jnp.stack(new_m_conv)),
        "shared_k": sk, "shared_v": sv,
    }
    return logits.astype(jnp.float32), new_cache


def _apply_shared_chunk(sp, x, x0, cfg, cos, sin, kc, vc, pos, kv_len,
                        write_mask, gather_idx):
    """Shared block over a prompt chunk against the per-slot KV ring:
    masked-scatter the chunk's K/V into [pos, pos+lens) per row, then
    offset-causal ragged attention (see models.lm.prefill_chunk)."""
    scfg = _shared_cfg(cfg)
    B, C, _ = x.shape
    h = jnp.concatenate([x, x0], axis=-1)
    h = layers.apply_norm(sp["ln"], h, "rmsnorm")
    q, k, v = attn_lib.project_qkv(sp["attn"], h, scfg)
    q, k = attn_lib.apply_rope(q, cos, sin), attn_lib.apply_rope(k, cos, sin)

    def _write(c, new):
        g = jnp.take_along_axis(new.astype(c.dtype),
                                gather_idx[:, :, None, None], axis=1)
        return jnp.where(write_mask[:, :, None, None], g, c)

    kc, vc = _write(kc, k), _write(vc, v)
    o = attn_lib.attend(q, kc.astype(q.dtype), vc.astype(q.dtype),
                        causal=True, kv_len=kv_len, q_offset=pos,
                        use_pallas=cfg.use_pallas_attn)
    o = layers.apply_dense(sp["out"], o.reshape(B, C, scfg.q_dim))
    x = x + o
    hn = layers.apply_norm(sp["ln2"], x, "rmsnorm")
    x = x + layers.apply_ffn(sp["ffn"], hn, cfg.ffn_type)
    return x, (kc, vc)


def prefill_chunk(params, tokens, cache, pos, lens, cfg, *, policy,
                  mesh=None, **_):
    """Batched chunked prefill for the hybrid arch.

    tokens: (B, C); pos/lens: (B,) chunk start positions / valid lengths
    (0 = inactive slot: its mamba state, KV ring rows and logits are
    untouched).  Requires pos + lens <= win (the engine prefills from
    pos 0 with prompts capped at capacity, so chunk writes never wrap
    the shared ring).

    The mamba recurrence is inherently sequential, but it is CHEAP per
    position — the win here is running all C positions of all B slots
    through ONE launch (a lax.scan of ``mamba2_step`` collecting the
    per-position states) instead of C global decode steps.  Ragged tails
    are handled by gathering each row's state at its own ``lens - 1``
    position, so padded tokens never corrupt the recurrent state.
    """
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    x0 = x
    B, C, _ = x.shape
    win = cache["shared_k"].shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    kv_len = pos + lens
    qpos = pos[:, None] + jnp.arange(C)[None]                # (B, C)
    cos, sin = attn_lib.rope_cos_sin(qpos, _shared_cfg(cfg).d_head,
                                     cfg.rope_theta, x.dtype)
    t = jnp.arange(win)
    write_mask = (t[None] >= pos[:, None]) & (t[None] < kv_len[:, None])
    gather_idx = jnp.clip(t[None] - pos[:, None], 0, C - 1)  # (B, win)
    sel = jnp.clip(lens - 1, 0, C - 1)                       # (B,)
    active = lens > 0
    rows = jnp.arange(B)

    def _pick(stacked, old):
        """Each row's state after ITS last valid token; inactive rows
        keep their old state bit-identically."""
        picked = stacked[sel, rows]                          # (B, ...)
        m = active.reshape((B,) + (1,) * (picked.ndim - 1))
        return jnp.where(m, picked.astype(old.dtype), old)

    shared_ids = _shared_idx(cfg)
    new_m_ssm, new_m_conv = [], []
    sk, sv = cache["shared_k"], cache["shared_v"]
    si = 0
    for i in range(cfg.n_layers):
        block = jax.tree.map(lambda t_: t_[i], cparams["mamba"])
        st0 = ssm.Mamba2State(ssm=cache["mamba"].ssm[i],
                              conv=cache["mamba"].conv[i])
        hn = layers.apply_norm(block["ln"], x, "rmsnorm")

        def step(st, x1, block=block):
            y1, st2 = ssm.mamba2_step(block["m"], x1[:, None], st,
                                      cfg.d_model, cfg.ssm)
            return st2, (y1[:, 0], st2)

        _, (ys, sts) = jax.lax.scan(step, st0, jnp.moveaxis(hn, 1, 0))
        x = x + jnp.moveaxis(ys, 0, 1)
        new_m_ssm.append(_pick(sts.ssm, st0.ssm))
        new_m_conv.append(_pick(sts.conv, st0.conv))
        if i in shared_ids:
            x, (kc, vc) = _apply_shared_chunk(
                cparams["shared"], x, x0, cfg, cos, sin, sk[si], sv[si],
                pos, kv_len, write_mask, gather_idx)
            sk = sk.at[si].set(kc)
            sv = sv.at[si].set(vc)
            si += 1
    h = layers.apply_norm(cparams["ln_f"], x, "rmsnorm")
    h_last = jnp.take_along_axis(h, sel[:, None, None], axis=1)  # (B,1,d)
    logits = h_last @ cparams["head"]["w"].astype(h.dtype)
    new_cache = {
        "mamba": ssm.Mamba2State(ssm=jnp.stack(new_m_ssm),
                                 conv=jnp.stack(new_m_conv)),
        "shared_k": sk, "shared_v": sv,
    }
    return logits.astype(jnp.float32), new_cache


def prefill(params, tokens, cfg, *, policy, mesh=None, max_len=None, **_):
    """Prefill as scan-over-layers with stacked state collection.

    A python loop over the 38 layers kept ~1.3 GB/layer of intermediates
    live simultaneously (32 GB temp at 32k prefill); lax.scan bounds the
    live set to ONE layer (§Perf zamba hillclimb: temp 32 GB -> ~8 GB).

    ``max_len``: total serving capacity (>= S) — the shared-attn ring
    buffer is sized min(max_len, _SHARED_WINDOW) and entries are stored at
    their POSITION-ALIGNED ring slot (token p -> slot p % win) so
    decode_step's ``pos % win`` writes continue the ring coherently."""
    cparams = policy.cast_to_compute(params)
    x = layers.apply_embed(cparams["embed"], tokens, policy.compute_dtype)
    x0 = x
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = attn_lib.rope_cos_sin(pos, _shared_cfg(cfg).d_head,
                                     cfg.rope_theta, x.dtype)
    scfg = _shared_cfg(cfg)
    win = min(max_len or S, _SHARED_WINDOW)
    every = max(cfg.shared_attn_every, 1)
    shared = cparams["shared"]

    def body(carry, block):
        h, idx = carry
        hn = layers.apply_norm(block["ln"], h, "rmsnorm")
        y, st = ssm.apply_mamba2(block["m"], hn, cfg.d_model, cfg.ssm,
                                 return_state=True)
        h = h + y

        def _ring(k):
            """Last `win` entries at their position-aligned ring slots."""
            if S >= win:
                return jnp.roll(k[:, -win:], S % win, axis=1)
            return jnp.pad(k, ((0, 0), (0, win - S), (0, 0), (0, 0)))

        def with_shared(hh):
            hh2, (k, v) = _apply_shared(shared, hh, x0, cfg, cos, sin)
            return (hh2, _ring(k).astype(jnp.bfloat16),
                    _ring(v).astype(jnp.bfloat16))

        def without_shared(hh):
            z = jnp.zeros((B, win, scfg.n_kv_heads, scfg.d_head),
                          jnp.bfloat16)
            return hh, z, z

        h, k, v = jax.lax.cond(idx % every == 0, with_shared,
                               without_shared, h)
        h = sharding.constrain_batch(h, mesh)
        return (h, idx + 1), (st.ssm, st.conv.astype(jnp.bfloat16), k, v)

    (x, _), (ssm_s, conv_s, ks, vs) = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.int32)), cparams["mamba"])
    ids = jnp.asarray(_shared_idx(cfg))
    h = layers.apply_norm(cparams["ln_f"], x, "rmsnorm")
    logits = h[:, -1:] @ cparams["head"]["w"].astype(h.dtype)
    new_cache = {"mamba": ssm.Mamba2State(ssm=ssm_s, conv=conv_s),
                 "shared_k": ks[ids], "shared_v": vs[ids]}
    return logits.astype(jnp.float32), new_cache
