"""Unified model API: dispatch per architecture family + input_specs.

``get_model(cfg)`` returns a `Model` bundle of pure functions with a single
signature convention shared by the trainer, the serving engine and the
multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import encdec, lm, xlstm, zamba

# sliding window used for long-context decode of full-attention archs
LONG_WINDOW = 8192
# number of image patches in VLM training batches (frontend stub)
VLM_PATCHES = 256
# whisper target length during training
WHISPER_TGT = 448


class Model(NamedTuple):
    init: Callable
    logical_axes: Callable
    loss_fn: Callable
    init_cache: Callable
    cache_logical_axes: Callable
    prefill: Callable
    decode_step: Callable
    # chunked batched prefill for serving (KV-cache / recurrent-state archs
    # that can ingest a prompt chunk in one launch); None -> the engine
    # falls back to sequential token-by-token prefill
    prefill_chunk: Optional[Callable] = None


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        m = lm
    elif cfg.family == "ssm":
        m = xlstm
    elif cfg.family == "hybrid":
        m = zamba
    elif cfg.family == "audio":
        m = encdec
    else:
        raise ValueError(cfg.family)
    return Model(m.init, m.logical_axes, m.loss_fn, m.init_cache,
                 m.cache_logical_axes, m.prefill, m.decode_step,
                 getattr(m, "prefill_chunk", None))


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape):
    """Batch pytree for loss_fn/train_step (no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return {"audio_emb": _sds((B, S, cfg.d_model), jnp.float32),
                "tokens": _sds((B, WHISPER_TGT), jnp.int32)}
    if cfg.family == "vlm":
        n_tok = S - VLM_PATCHES
        return {"tokens": _sds((B, n_tok), jnp.int32),
                "embeds": _sds((B, VLM_PATCHES, cfg.d_model), jnp.float32),
                "positions": _sds((3, B, S), jnp.int32)}
    return {"tokens": _sds((B, S), jnp.int32)}


def decode_specs(cfg: ArchConfig, shape: InputShape):
    """(tokens1, cache, pos) pytree specs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    window = decode_window(cfg, shape)
    max_len = min(S, window) if window else S
    cache = jax.eval_shape(
        lambda: model.init_cache(cfg, B, max_len, jnp.bfloat16))
    tokens1 = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    extra = {}
    if cfg.mrope:
        extra["positions"] = _sds((3, B, 1), jnp.int32)
    return tokens1, cache, pos, extra


def prefill_specs(cfg: ArchConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return {"audio_emb": _sds((B, S, cfg.d_model), jnp.float32)}
    if cfg.family == "vlm":
        return {"tokens": _sds((B, S), jnp.int32),
                "positions": _sds((3, B, S), jnp.int32)}
    return {"tokens": _sds((B, S), jnp.int32)}


def decode_window(cfg: ArchConfig, shape: InputShape) -> int:
    """Sliding window for long-context decode of full-attention archs.
    0 = no window (full cache)."""
    if shape.name == "long_500k" and not cfg.subquadratic \
            and cfg.family != "audio":
        return LONG_WINDOW
    return 0


def decode_supported(cfg: ArchConfig, shape: InputShape) -> bool:
    """Which (arch, shape) decode pairs exist (DESIGN.md shape notes)."""
    if shape.kind != "decode":
        return True
    if shape.name == "long_500k" and cfg.family == "audio":
        return False            # whisper: no 500k decode (DESIGN.md skip)
    return True
