"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU).

Usage::

    mesh = make_dev_mesh(data=len(jax.devices()))   # tests / this container
    mesh = make_production_mesh()                   # 256-chip pod
    mesh = make_production_mesh(multi_pod=True)     # 512 chips, 2 pods

Axis conventions across the repo: ``pod`` and ``data`` carry the batch
(pure data parallelism — the paper's mirrored strategy, and the axes the
training engine shards over); ``model`` carries tensor/expert parallelism
for the big LM archs.  ``HARDWARE`` holds the per-chip roofline constants
the benchmarks divide by.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, data: int = 16,
                         model: int = 16):
    """Single pod: (data=16, model=16) = 256 chips (default).
    Multi-pod: (pod=2, data, model) = 512 chips; the ``pod`` axis is pure
    data parallelism (the paper's multi-worker mirrored analogue).

    ``data``/``model`` re-factorize the 256 chips per pod — the §Perf
    hillclimb's layout lever (paper Fig. 4): data*model must equal 256."""
    assert data * model == 256, (data, model)
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


HARDWARE = {
    # TPU v5e per-chip constants used by the roofline report
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
    "chips_single_pod": 256,
    "chips_multi_pod": 512,
}
