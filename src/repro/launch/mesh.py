"""Production mesh definitions (TPU v5e target) + cluster topology model.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU).

Usage::

    mesh = make_dev_mesh(data=len(jax.devices()))   # tests / this container
    mesh = make_production_mesh()                   # 256-chip pod
    mesh = make_production_mesh(multi_pod=True)     # 512 chips, 2 pods
    topo = topology("v100", nodes=8)                # 64 GPUs, 8 per node
    mesh = make_node_mesh(nodes=2, devices_per_node=2)   # (node, device)

Axis conventions across the repo: ``pod`` and ``data`` carry the batch
(pure data parallelism — the paper's mirrored strategy, and the axes the
training engine shards over); ``model`` carries tensor/expert parallelism
for the big LM archs; ``node`` × ``device`` is the hierarchical 2-level
layout of a multi-node cluster (paper §5: multi-worker GPU nodes and TPU
pods) — ``device`` peers talk over NVLink/ICI, ``node`` peers over the
node NIC / DCN.  ``HARDWARE`` holds the per-chip roofline constants the
benchmarks divide by; :class:`Topology` carries the per-LINK constants
the cross-node interconnect model (`cloud/interconnect.py`) divides by.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import numpy as np


# ---------------------------------------------------------------------------
# Cluster topology (paper §5: multi-node GPU / multi-pod TPU scale-out)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Link:
    """One interconnect class: sustained bandwidth (B/s, per direction and
    per participant) and per-message latency (s)."""
    bandwidth: float
    latency: float

    def transfer_s(self, nbytes: float) -> float:
        return nbytes / self.bandwidth + self.latency


@dataclasses.dataclass(frozen=True)
class Topology:
    """A 2-level cluster: ``nodes`` × ``devices_per_node`` accelerators.

    ``intra_link`` is the in-node fabric (NVLink for V100 nodes, ICI for
    TPU slices); ``inter_link`` is what crosses node boundaries (the VM
    NIC for GPU nodes; still ICI inside a TPU pod, which is exactly why
    the paper's TPU weak scaling stays linear while GPUs pay a NIC tax).
    ``peak_flops``/``hbm_bw`` are per-device roofline constants for the
    analytic planner.
    """
    name: str
    nodes: int
    devices_per_node: int
    intra_link: Link
    inter_link: Link
    device_kind: str = "v100"
    peak_flops: float = 125e12          # per device
    hbm_bw: float = 900e9               # per device

    @property
    def total_devices(self) -> int:
        return self.nodes * self.devices_per_node

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return (self.nodes, self.devices_per_node)

    axis_names: Tuple[str, str] = ("node", "device")


# Per-link constants (paper-era GCP hardware, see docs/scaling.md):
# V100 NVLink effective all-reduce bandwidth per GPU; the n1 VM NIC is
# shared by the whole 8-GPU node.  TPU ICI links stay on-fabric across
# board boundaries, so inter == intra inside a pod slice.
NVLINK = Link(bandwidth=130e9, latency=5e-6)
GPU_NIC = Link(bandwidth=12.5e9, latency=25e-6)      # 100 Gbit/s VM NIC
TPU_V2_ICI = Link(bandwidth=60e9, latency=2e-6)
TPU_V3_ICI = Link(bandwidth=70e9, latency=2e-6)
V5E_ICI = Link(bandwidth=50e9, latency=2e-6)


def gpu_topology(nodes: int, gpus_per_node: int = 8) -> Topology:
    """The paper's GPU configuration: n1 nodes with 8 V100s each, scaled
    1..16 nodes (8..128 GPUs, Fig. 2 / Fig. 5)."""
    return Topology(f"v100x{nodes * gpus_per_node}", nodes, gpus_per_node,
                    NVLINK, GPU_NIC, device_kind="v100",
                    peak_flops=125e12, hbm_bw=900e9)


def tpu_topology(version: str, cores: int) -> Topology:
    """TPU v2/v3 slices as node×device grids of 8-core boards.  Cross-board
    traffic inside a slice rides the same ICI fabric (inter == intra)."""
    ici = {"v2": TPU_V2_ICI, "v3": TPU_V3_ICI, "v5e": V5E_ICI}[version]
    per_core = {"v2": 23e12, "v3": 61e12, "v5e": 197e12}[version]
    boards = max(cores // 8, 1)
    return Topology(f"tpu_{version}-{cores}", boards, min(cores, 8),
                    ici, ici, device_kind=f"tpu_{version}",
                    peak_flops=per_core, hbm_bw=ici.bandwidth * 14)


def topology(family: str, nodes: int = 1, devices_per_node: int = 8) -> Topology:
    """Factory over the paper's configurations: ``("v100", nodes=1..16)``,
    ``("tpu_v2", cores)``, ``("tpu_v3", cores)``."""
    if family == "v100":
        return gpu_topology(nodes, devices_per_node)
    if family.startswith("tpu_"):
        return tpu_topology(family.split("_", 1)[1],
                            nodes * devices_per_node)
    raise ValueError(f"unknown topology family {family!r}")


# the paper's measured configurations, by name (Fig. 2 / Fig. 5)
TOPOLOGIES = {
    **{f"v100x{8 * n}": gpu_topology(n) for n in (1, 2, 4, 8, 16)},
    "tpu_v2-8": tpu_topology("v2", 8),
    "tpu_v3-8": tpu_topology("v3", 8),
    "tpu_v3-32": tpu_topology("v3", 32),
}


def make_node_mesh(nodes: int = 1, devices_per_node: int = 0,
                   topo: Topology = None):
    """Hierarchical ``(node, device)`` mesh folded onto the host's devices.

    On a real cluster each ``node`` row maps to one machine; on this
    container the host's devices (1 CPU, or N virtual devices under
    ``--xla_force_host_platform_device_count``) are folded into a VIRTUAL
    node×device grid — collectives over ``node`` and ``device`` then
    execute locally, which is how the parity tests pin hierarchical
    reduction numerics without a cluster.  Requires nodes*devices_per_node
    <= len(jax.devices()); sizes are clamped like :func:`make_dev_mesh`
    when ``devices_per_node`` is 0 (auto: fill with what exists).
    """
    if topo is not None:
        nodes, devices_per_node = topo.nodes, topo.devices_per_node
    n_avail = len(jax.devices())
    if devices_per_node <= 0:
        nodes = min(nodes, n_avail)
        devices_per_node = max(n_avail // nodes, 1)
    need = nodes * devices_per_node
    if need > n_avail:
        raise ValueError(
            f"virtual topology {nodes}x{devices_per_node} needs {need} "
            f"devices, host has {n_avail} (set "
            "--xla_force_host_platform_device_count before importing jax)")
    return jax.make_mesh((nodes, devices_per_node), ("node", "device"))


def surviving_devices(mesh, lost_node: int):
    """The device grid of a ``(node, device)`` mesh minus one node row.

    The elastic trainer's view of a preemption: node ``lost_node``'s
    devices are gone, the remaining rows keep their order (surviving
    replicas keep their relative ranks)."""
    grid = np.asarray(mesh.devices)
    if grid.ndim != 2 or mesh.axis_names != ("node", "device"):
        raise ValueError(
            f"expected a (node, device) mesh, got {mesh.axis_names} "
            f"of shape {grid.shape}")
    if not 0 <= lost_node < grid.shape[0]:
        raise ValueError(f"lost_node {lost_node} out of range for "
                         f"{grid.shape[0]} nodes")
    keep = [r for r in range(grid.shape[0]) if r != lost_node]
    return grid[keep]


def shrink_node_mesh(mesh, lost_node: int):
    """Re-mesh after losing a node: the surviving ``(node, device)`` grid.

    Raises ``ValueError`` when the mesh has a single node — with no
    surviving capacity there is nothing to re-mesh onto (the elastic
    trainer treats that preemption as respawn-and-restart instead).
    """
    grid = surviving_devices(mesh, lost_node)
    if grid.shape[0] == 0:
        raise ValueError("cannot shrink a single-node mesh: no survivors")
    return jax.sharding.Mesh(grid, ("node", "device"))


def replica_meshes(mesh):
    """Split a ``(node, device)`` mesh into one 1-D ``device`` mesh per
    node row — the serving runtime's replica layout.

    Training shards ONE step over the whole grid; serving instead runs
    N independent generator replicas (one per node), so a preempted
    node takes out exactly one replica and `serve/replicas.ReplicaGroup`
    fails the in-flight bucket step over to a survivor.  Row order is
    preserved, so replica rank == node row == the ``node`` index a
    `train/faults.FaultPlan` ``preempt`` event targets.
    """
    grid = np.asarray(mesh.devices)
    if grid.ndim != 2 or mesh.axis_names != ("node", "device"):
        raise ValueError(
            f"expected a (node, device) mesh, got {mesh.axis_names} "
            f"of shape {grid.shape}")
    return [jax.sharding.Mesh(grid[r], ("device",))
            for r in range(grid.shape[0])]


def make_production_mesh(*, multi_pod: bool = False, data: int = 16,
                         model: int = 16):
    """Single pod: (data=16, model=16) = 256 chips (default).
    Multi-pod: (pod=2, data, model) = 512 chips; the ``pod`` axis is pure
    data parallelism (the paper's multi-worker mirrored analogue).

    ``data``/``model`` re-factorize the 256 chips per pod — the §Perf
    hillclimb's layout lever (paper Fig. 4): data*model must equal 256."""
    assert data * model == 256, (data, model)
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


HARDWARE = {
    # TPU v5e per-chip constants used by the roofline report
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
    "chips_single_pod": 256,
    "chips_multi_pod": 512,
}
