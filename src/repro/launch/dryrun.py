"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) pair this lowers + compiles the real
train / prefill / serve step on the production mesh — single-pod (16, 16)
= 256 chips and multi-pod (2, 16, 16) = 512 chips — using ShapeDtypeStruct
stand-ins (no allocation).  Failures (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the framework.

Per pair it records: memory_analysis (bytes/device), cost_analysis (FLOPs /
bytes for the §Roofline report) and the collective-traffic breakdown parsed
from the optimized HLO.  Results go to JSON for benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun
  python -m repro.launch.dryrun --arch calo3dgan --multi-pod
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
if not os.environ.get("REPRO_XLA_FULL_OPT"):
    # Reduce LLVM codegen effort for the CPU stand-in backend (8x faster
    # compiles).  GSPMD partitioning, layout & memory assignment — the
    # things the dry-run proves — run identically; cost/memory analysis
    # values were verified unchanged vs. full optimization.
    os.environ["XLA_FLAGS"] += (" --xla_backend_optimization_level=0"
                                " --xla_llvm_disable_expensive_passes=true")
import argparse
import json
import time
import traceback

import jax

from repro.configs import base as config_base
from repro.launch import build as build_lib
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.parallel import collectives, jaxpr_cost


def run_pair(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             rules_name: str = "fsdp_tp", policy_name: str = "bf16",
             save_hlo: str = "", remat: bool = True, data: int = 16,
             model: int = 16, seq_shard: bool = False,
             microbatches: int = 1, train_seq_shard: bool = True,
             verbose: bool = True) -> dict:
    """Lower + compile one (arch, shape, mesh) combination; return metrics."""
    from repro.parallel import sharding as sharding_lib

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod, data=data, model=model)
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": mesh.devices.size, "rules": rules_name,
        "policy": policy_name, "seq_shard": seq_shard,
    }
    _seq_ctx = sharding_lib.seq_sharding(seq_shard)
    _seq_ctx.__enter__()

    if arch_id == "calo3dgan":
        if shape_name != "train_4k":    # GAN has one workload: training
            return {**rec, "status": "skipped",
                    "reason": "GAN: train only (paper's workload)"}
        with mesh:
            built = build_lib.build_gan_train(mesh, policy_name=policy_name)
    else:
        cfg = config_base.get_config(arch_id)
        shape = config_base.INPUT_SHAPES[shape_name]
        if not api.decode_supported(cfg, shape):
            return {**rec, "status": "skipped",
                    "reason": "decode shape unsupported (DESIGN.md notes)"}
        with mesh:
            if shape.kind == "train":
                built = build_lib.build_train(
                    arch_id, shape_name, mesh, rules_name=rules_name,
                    policy_name=policy_name, remat=remat,
                    microbatches=microbatches,
                    seq_shard=train_seq_shard)
            elif shape.kind == "prefill":
                built = build_lib.build_prefill(
                    arch_id, shape_name, mesh, rules_name=rules_name,
                    policy_name=policy_name)
            else:
                built = build_lib.build_serve(
                    arch_id, shape_name, mesh, rules_name=rules_name,
                    policy_name=policy_name)

    try:
        with mesh:
            lowered = built.lower()
    finally:
        _seq_ctx.__exit__(None, None, None)
    t_lower = time.time() - t0
    with mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collectives.collective_stats(hlo)                  # loop-scaled
    coll_raw = collectives.collective_stats(hlo, scale_loops=False)
    # exact structural FLOPs/bytes from the jaxpr (XLA's cost_analysis
    # counts scan bodies once; the jaxpr walk multiplies by trip count)
    jc = jaxpr_cost.cost_of(built.fn, *built.args)

    rec.update({
        "status": "ok",
        "kind": built.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "argument_bytes_per_device": int(getattr(
            mem, "argument_size_in_bytes", 0)),
        "output_bytes_per_device": int(getattr(
            mem, "output_size_in_bytes", 0)),
        "temp_bytes_per_device": int(getattr(
            mem, "temp_size_in_bytes", 0)),
        "peak_bytes_per_device": int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
        "collectives": coll,
        "collective_result_bytes": sum(v["bytes"] for v in coll.values()),
        "collective_result_bytes_unscaled": sum(
            v["bytes"] for v in coll_raw.values()),
        "jaxpr_flops": jc["flops"],
        "jaxpr_bytes": jc["bytes"],
    })
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
        rec["hlo_path"] = save_hlo
    if verbose:
        print(f"[dryrun] {arch_id:16s} {shape_name:12s} mesh={rec['mesh']:9s}"
              f" OK  flops={rec['flops']:.3e}"
              f" bytes={rec['bytes_accessed']:.3e}"
              f" coll={rec['collective_result_bytes']:.3e}"
              f" peakB/dev={rec['peak_bytes_per_device']:.3e}"
              f" (lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    return rec


ALL_ARCHS = config_base.ARCH_IDS          # 10 assigned + calo3dgan
ALL_SHAPES = tuple(config_base.INPUT_SHAPES)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default="fsdp_tp",
                    choices=("dp", "tp", "fsdp_tp"))
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--out", default="")
    ap.add_argument("--data", type=int, default=16,
                    help="data-axis size (data*model must be 256)")
    ap.add_argument("--model", type=int, default=16)
    ap.add_argument("--seq-shard", action="store_true",
                    help="shard the residual seq dim over 'model'")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--no-train-seq-shard", action="store_true",
                    help="disable seq sharding inside train steps")
    args = ap.parse_args()

    archs = ALL_ARCHS if args.all or not args.arch else (args.arch,)
    shapes = ALL_SHAPES if args.all or not args.shape else (args.shape,)
    pods = (False, True) if args.both_meshes else (args.multi_pod,)

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    rec = run_pair(arch, shape, multi_pod=mp,
                                   rules_name=args.rules,
                                   policy_name=args.policy,
                                   save_hlo=args.save_hlo,
                                   remat=not args.no_remat,
                                   data=args.data, model=args.model,
                                   seq_shard=args.seq_shard,
                                   microbatches=args.microbatch,
                                   train_seq_shard=not args.no_train_seq_shard)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failures.append(rec)
                    print(f"[dryrun] {arch} {shape} multi_pod={mp} FAILED:")
                    traceback.print_exc()
                results.append(rec)
                jax.clear_caches()      # bound compile-cache memory

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out if args.out.endswith(".json")
                  else args.out + ".json", "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n[dryrun] {n_ok} ok, {n_skip} skipped, {len(failures)} failed "
          f"of {len(results)}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
