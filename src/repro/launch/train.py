"""Training launcher: GAN (the paper's workload) or any assigned LM arch.

Runs on whatever devices exist (CPU in this container, TPU pod in prod —
the same build path the dry-run compiles for 256/512 chips).

Both workloads route through the unified data-parallel engine
(`train/engine.py`), which implements the paper's two loop strategies:

  --loop builtin   jit + NamedSharding; the compiler places per-device
                   batches (the tf.distribute analogue)
  --loop custom    shard_map; explicit per-device batch assignment,
                   local updates, explicit psum gradient reduction
  --loop naive     (GAN only) the keras.train_on_batch baseline with
                   sequential host-side generator-input init

Usage:
  python -m repro.launch.train --arch calo3dgan --steps 200 --loop custom
  python -m repro.launch.train --arch qwen2-1.5b --reduced --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as config_base
from repro.data.calo import CaloSimulator, CaloSpec
from repro.data.tokens import MarkovTokens
from repro.launch.mesh import make_dev_mesh, make_node_mesh
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.parallel import sharding
from repro.substrate.precision import get_policy
from repro.train import checkpoint as ckpt_lib
from repro.train import engine as engine_lib
from repro.train.metrics import MetricLog


def train_gan(args, mesh, log: MetricLog):
    from repro.configs import calo3dgan
    from repro.core import adversarial, gan, validation

    cfg = calo3dgan.reduced() if args.reduced else calo3dgan.config()
    # --precision beats --policy (the legacy spelling, still honored when
    # given explicitly) beats the config's precision field; the resolved
    # name is recorded in the checkpoint manifest for serving restore
    precision = args.precision or args.policy or cfg.precision
    g_opt = opt_lib.rmsprop(args.lr)
    d_opt = opt_lib.rmsprop(args.lr)

    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=args.seed)
    B = args.batch or cfg.batch_size

    if args.loop == "naive":
        precision = "f32"               # the baseline is measured pure-f32
        state = adversarial.init_state(jax.random.key(args.seed), cfg,
                                       g_opt, d_opt)
        step = adversarial.NaiveStep(cfg, g_opt, d_opt, seed=args.seed)
        for i, batch in zip(range(args.steps), sim.batches(B)):
            state, m = step(state, batch)
            log.log(i, **m)
    else:
        # "fused" is the legacy name for the jit'd single-program loop —
        # that is exactly the engine's builtin mode.
        loop = "builtin" if args.loop == "fused" else args.loop
        task = engine_lib.gan_task(cfg, g_opt, d_opt,
                                   policy=get_policy(precision),
                                   microbatches=args.microbatches)
        # the 3DGAN is PURE data parallelism: every mesh axis is a replica
        eng = engine_lib.Engine(
            mesh, loop, dp_axes=tuple(mesh.axis_names),
            grad_reduce=args.grad_reduce or cfg.grad_reduce,
            bucket_mb=args.bucket_mb or cfg.reduce_bucket_mb)
        state, _ = eng.fit(task, sim.batches(B), args.steps,
                           rng=jax.random.key(args.seed), log=log,
                           log_every=args.log_every,
                           sync_every=args.sync_every or None)

    # physics validation vs fresh Monte Carlo
    mc = next(sim.batches(256))
    noise = jax.random.normal(jax.random.key(7), (256, cfg.latent_dim))
    fake = gan.generate(state.g_params, noise, jnp.asarray(mc["e_p"]),
                        jnp.asarray(mc["theta"]), cfg)
    rep = validation.validation_report(np.asarray(fake), mc["image"],
                                       mc["e_p"], mc["e_p"])
    print("physics validation:", {k: round(v, 4) for k, v in rep.items()})
    if args.ckpt:
        ckpt_lib.save(args.ckpt, state.g_params, step=args.steps,
                      extra={"kind": "gan_generator",
                             "precision": precision})
        print(f"saved generator to {args.ckpt} (precision={precision})")
    return state


def train_lm(args, mesh, log: MetricLog):
    cfg = (config_base.reduced_config(args.arch) if args.reduced
           else config_base.get_config(args.arch))
    from repro.launch.serve import _resolve_pallas_routing
    cfg = _resolve_pallas_routing(cfg, args)
    model = api.get_model(cfg)
    policy = get_policy(args.policy or "f32")
    optimizer = opt_lib.adamw(opt_lib.warmup_cosine(args.lr, 20, args.steps))

    loop = "builtin" if args.loop == "fused" else args.loop
    task = engine_lib.lm_task(model, cfg, optimizer, policy=policy,
                              microbatches=args.microbatches)
    eng = engine_lib.Engine(mesh, loop,
                            grad_reduce=args.grad_reduce or "flat",
                            bucket_mb=args.bucket_mb or 4.0)

    B, S = args.batch or 8, args.seq or 256
    data = MarkovTokens(cfg.vocab, seed=args.seed)

    def gen():
        if cfg.family == "audio":
            while True:
                yield {"audio_emb": np.random.default_rng(0).normal(
                           0, 1, (B, S, cfg.d_model)).astype(np.float32),
                       "tokens": data.sample(B, min(S, cfg.max_target_positions))}
        elif cfg.family == "vlm":
            n_patch = 16
            while True:
                pos = np.broadcast_to(np.arange(S, dtype=np.int32),
                                      (3, B, S)).copy()
                yield {"tokens": data.sample(B, S - n_patch),
                       "embeds": np.zeros((B, n_patch, cfg.d_model), np.float32),
                       "positions": pos}
        else:
            while True:
                yield {"tokens": data.sample(B, S)}

    t0 = time.time()
    state, _ = eng.fit(task, gen(), args.steps,
                       rng=jax.random.key(args.seed), log=log,
                       log_every=args.log_every,
                       sync_every=args.sync_every or None)
    dt = time.time() - t0
    print(f"{args.arch}: {sharding.count_params(state.params):,} params "
          f"({'reduced' if args.reduced else 'full'}), loop={loop}")
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps * B * S / dt:.0f} tok/s)")
    if args.ckpt:
        ckpt_lib.save(args.ckpt, state.params, step=args.steps,
                      extra={"arch": args.arch})
    return state.params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="calo3dgan",
                    choices=config_base.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loop", default="builtin",
                    choices=("builtin", "custom", "fused", "naive"),
                    help="builtin: jit+NamedSharding; custom: shard_map "
                         "with explicit psum; fused: legacy alias of "
                         "builtin; naive: host-orchestrated GAN baseline")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient accumulation inside each step")
    ap.add_argument("--grad-reduce", default="",
                    choices=("", "flat", "hierarchical", "overlap"),
                    help="gradient-reduction strategy: flat psum-mean, "
                         "hierarchical 2-level (intra-node psum + bucketed "
                         "inter-node psums over a (node, device) mesh), or "
                         "overlap (reverse-order buckets issued inside the "
                         "backward pass); empty defers to the config's "
                         "grad_reduce field")
    ap.add_argument("--bucket-mb", type=float, default=0.0,
                    help="inter-node bucket size (MiB) for hierarchical "
                         "grad-reduce (0: config default)")
    ap.add_argument("--nodes", type=int, default=0,
                    help="fold the host devices into a virtual "
                         "(nodes, devices/node) 2-level mesh instead of "
                         "the flat (data, model) dev mesh")
    ap.add_argument("--policy", default="",
                    help="LM mixed-precision policy name (default f32); "
                         "for the GAN arch an explicit value is honored "
                         "as a legacy alias of --precision")
    ap.add_argument("--precision", default="",
                    help="GAN precision policy (f32|bf16|fp16); empty "
                         "defers to --policy, then the config's "
                         "precision field (bf16)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--pallas-attn", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="LM archs: route attention through the Pallas "
                         "kernels (default: on on TPU, off elsewhere; env "
                         "REPRO_PALLAS_ATTN overrides)")
    ap.add_argument("--pallas-ssm", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="LM archs: route SSM scans through the Pallas "
                         "kernels (default: on on TPU, off elsewhere; env "
                         "REPRO_PALLAS_SSM overrides)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log", default="")
    ap.add_argument("--log-every", type=int, default=1,
                    help="steps per metric window; >1 removes the "
                         "per-step device->host sync (async dispatch)")
    ap.add_argument("--sync-every", type=int, default=0,
                    help="force a device sync every N steps to bound "
                         "run-ahead (0: never)")
    args = ap.parse_args()
    if args.loop == "naive" and args.arch != "calo3dgan":
        ap.error("--loop naive is the GAN train_on_batch baseline; "
                 "LM archs support builtin/custom/fused")

    mesh = (make_node_mesh(nodes=args.nodes) if args.nodes
            else make_dev_mesh(data=len(jax.devices())))
    log = MetricLog(args.log or None, print_every=max(args.steps // 20, 1))
    if args.arch == "calo3dgan":
        train_gan(args, mesh, log)
    else:
        train_lm(args, mesh, log)


if __name__ == "__main__":
    main()
