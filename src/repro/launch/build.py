"""Build jitted, mesh-sharded train / prefill / serve steps for any arch.

Shared by the real launchers (train.py / serve.py) and the multi-pod dry-run
(dryrun.py): the SAME code path produces either executable functions (given
real arrays) or AOT ``lowered``/``compiled`` artifacts (given only
ShapeDtypeStructs) — so what the dry-run proves is what training runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as config_base
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.parallel import sharding
from repro.substrate.precision import get_policy
from repro.train import steps as steps_lib


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def param_shapes(model, cfg):
    """ShapeDtypeStruct pytree of the params — no allocation."""
    return jax.eval_shape(lambda: model.init(jax.random.key(0), cfg))


def param_shardings(model, cfg, mesh: Mesh, rules: dict):
    shapes = param_shapes(model, cfg)
    return sharding.tree_shardings(model.logical_axes(cfg), shapes, mesh,
                                   rules), shapes


def opt_state_shardings(optimizer, p_shapes, p_shard, mesh: Mesh):
    """Optimizer-state shardings: moment trees mirror the params; scalars
    (step) are replicated; None slots stay None."""
    o_shapes = jax.eval_shape(optimizer.init, p_shapes)
    rep = NamedSharding(mesh, P())

    def top(entry_shapes):
        if entry_shapes is None:
            return None
        if isinstance(entry_shapes, jax.ShapeDtypeStruct):
            return rep
        return jax.tree.map(lambda _, s: s, entry_shapes, p_shard)

    return {k: top(v) for k, v in o_shapes.items()}, o_shapes


def batch_shardings(batch_shapes, mesh: Mesh, batch_dim_for: Optional[dict] = None):
    """Leading-dim (pod, data) sharding for every batch leaf.  Delegates to
    the engine's single placement rule (``positions`` carries batch on
    dim 1; non-dividing dims stay replicated)."""
    from repro.train import engine as engine_lib
    return engine_lib.Engine(mesh, "builtin").batch_shardings(
        batch_shapes, batch_dim_for)


def cache_shardings(model, cfg, mesh: Mesh, rules: dict, cache_shapes):
    return sharding.tree_shardings(model.cache_logical_axes(cfg),
                                   cache_shapes, mesh, rules)


# ---------------------------------------------------------------------------
# Step builders (train / prefill / serve), AOT-lowerable
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BuiltStep:
    fn: Any                 # the jitted function
    args: tuple             # ShapeDtypeStruct args (for .lower(*args))
    kind: str

    def lower(self):
        return self.fn.lower(*self.args)


def build_train(arch_id: str, shape_name: str, mesh: Mesh, *,
                rules_name: str = "fsdp_tp", policy_name: str = "bf16",
                optimizer_name: str = "adamw", lr: float = 3e-4,
                remat: bool = True, donate: bool = True,
                microbatches: int = 1, seq_shard: bool = True) -> BuiltStep:
    cfg = config_base.get_config(arch_id)
    shape = config_base.INPUT_SHAPES[shape_name]
    model = api.get_model(cfg)
    rules = sharding.RULE_SETS[rules_name]
    policy = get_policy(policy_name)
    optimizer = opt_lib.get_optimizer(optimizer_name, lr)

    p_shard, p_shapes = param_shardings(model, cfg, mesh, rules)
    o_shard, o_shapes = opt_state_shardings(optimizer, p_shapes, p_shard, mesh)
    b_shapes = api.train_batch_specs(cfg, shape)
    b_shard = batch_shardings(b_shapes, mesh)

    step = steps_lib.make_train_step(model, cfg, optimizer, policy, mesh=mesh,
                                     remat=remat, microbatches=microbatches,
                                     seq_shard=seq_shard)
    fn = jax.jit(
        step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return BuiltStep(fn, (p_shapes, o_shapes, b_shapes), "train")


def build_prefill(arch_id: str, shape_name: str, mesh: Mesh, *,
                  rules_name: str = "fsdp_tp",
                  policy_name: str = "bf16") -> BuiltStep:
    cfg = config_base.get_config(arch_id)
    shape = config_base.INPUT_SHAPES[shape_name]
    model = api.get_model(cfg)
    rules = sharding.RULE_SETS[rules_name]
    policy = get_policy(policy_name)
    window = api.decode_window(cfg, shape)

    p_shard, p_shapes = param_shardings(model, cfg, mesh, rules)
    b_shapes = api.prefill_specs(cfg, shape)
    b_shard = batch_shardings(b_shapes, mesh)

    step = steps_lib.make_prefill_step(model, cfg, policy, mesh=mesh,
                                       window=window)
    fn = jax.jit(step, in_shardings=(p_shard, b_shard))
    return BuiltStep(fn, (p_shapes, b_shapes), "prefill")


def build_serve(arch_id: str, shape_name: str, mesh: Mesh, *,
                rules_name: str = "fsdp_tp",
                policy_name: str = "bf16") -> BuiltStep:
    cfg = config_base.get_config(arch_id)
    shape = config_base.INPUT_SHAPES[shape_name]
    model = api.get_model(cfg)
    rules = sharding.RULE_SETS[rules_name]
    policy = get_policy(policy_name)
    window = api.decode_window(cfg, shape)

    p_shard, p_shapes = param_shardings(model, cfg, mesh, rules)
    tokens1, cache_shapes, pos, extra = api.decode_specs(cfg, shape)
    c_shard = cache_shardings(model, cfg, mesh, rules, cache_shapes)
    b = shape.global_batch
    ax = sharding.batch_axes(mesh)
    n_batch = 1
    for a in ax or ():
        n_batch *= mesh.shape[a]
    shard_batch = ax is not None and b % n_batch == 0 and b > 1
    tok_in = NamedSharding(mesh, P(ax, None) if shard_batch else P())
    tok_out = NamedSharding(mesh, P(ax) if shard_batch else P())
    extra_shard = batch_shardings(extra, mesh)

    step = steps_lib.make_serve_step(model, cfg, policy, mesh=mesh,
                                     window=window)
    fn = jax.jit(
        step,
        in_shardings=(p_shard, tok_in, c_shard,
                      NamedSharding(mesh, P()), extra_shard),
        out_shardings=(tok_out, c_shard),
        donate_argnums=(2,),
    )
    return BuiltStep(fn, (p_shapes, tokens1, cache_shapes, pos, extra),
                     "serve")


def gan_batch_shapes(cfg, n_replicas: int) -> dict:
    """ShapeDtypeStruct batch for the 3DGAN at the paper's per-replica
    batch size (global batch = batch_size x replicas, weak scaling)."""
    B = cfg.batch_size * n_replicas
    X, Y, Z = cfg.image_shape
    return {
        "image": jax.ShapeDtypeStruct((B, X, Y, Z, 1), jnp.float32),
        "e_p": jax.ShapeDtypeStruct((B,), jnp.float32),
        "theta": jax.ShapeDtypeStruct((B,), jnp.float32),
        "ecal": jax.ShapeDtypeStruct((B,), jnp.float32),
    }


def build_gan_train(mesh: Mesh, *, policy_name: Optional[str] = None,
                    reduced: bool = False, loop: str = "builtin",
                    grad_reduce: Optional[str] = None,
                    bucket_mb: Optional[float] = None) -> BuiltStep:
    """The paper's own architecture: fused Algorithm-1 step, pure DP
    (mirrored-strategy analogue — params replicated, batch sharded).

    Delegates to the unified engine: ``loop`` selects the paper's
    built-in (jit + NamedSharding) or custom (shard_map + explicit psum)
    strategy, ``grad_reduce`` the reduction schedule (flat | hierarchical
    over a (node, device) mesh).  Every mesh axis carries batch — all
    256/512 chips are replicas, per-replica BS=128 exactly as the paper
    runs it (§4).  ``policy_name``/``grad_reduce``/``bucket_mb`` default
    to the config's ``precision``/``grad_reduce``/``reduce_bucket_mb``."""
    from repro.configs import calo3dgan
    from repro.train import engine as engine_lib

    cfg = calo3dgan.reduced() if reduced else calo3dgan.config()
    task = engine_lib.gan_task(cfg, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4),
                               policy=get_policy(policy_name
                                                 or cfg.precision))
    eng = engine_lib.Engine(mesh, loop, dp_axes=tuple(mesh.axis_names),
                            grad_reduce=grad_reduce or cfg.grad_reduce,
                            bucket_mb=(cfg.reduce_bucket_mb
                                       if bucket_mb is None else bucket_mb))
    built = eng.build(task, gan_batch_shapes(cfg, mesh.devices.size))
    return BuiltStep(built.fn, built.args, built.kind)
