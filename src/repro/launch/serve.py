"""Serving launcher: batched-request demo over any decodable architecture.

Usage:
  python -m repro.launch.serve --arch qwen2-1.5b --reduced --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as config_base
from repro.launch.mesh import make_dev_mesh
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (config_base.reduced_config(args.arch) if args.reduced
           else config_base.get_config(args.arch))
    if not cfg.decode_supported:
        raise SystemExit(f"{args.arch} does not support decode")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(args.seed), cfg)
    mesh = make_dev_mesh(data=len(jax.devices()))

    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      mesh=mesh)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, plen,
                                               dtype=np.int32),
                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.1f}s ({total_new / dt:.1f} tok/s)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {r.tokens[:8]}...")


if __name__ == "__main__":
    main()
