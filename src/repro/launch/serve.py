"""Serving launcher: LM continuous batching OR 3DGAN fast simulation.

Two routes, selected by ``--model``:

- ``--model lm`` (default) — batched-request decode demo over any
  decodable LM architecture (`serve/engine.py` slot pool).
- ``--model gan`` — the paper's deliverable: serve calorimeter showers
  from a trained 3DGAN generator through the bucketed fast-simulation
  engine (`serve/simulate.py`), with the rolling physics gate checking
  every window against fresh Monte Carlo.

Usage:
  python -m repro.launch.serve --arch qwen2-1.5b --reduced --requests 8
  python -m repro.launch.serve --model gan --reduced --requests 16 \
      --ckpt ckpts/gan  # generator saved by launch/train --ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import base as config_base
from repro.launch.mesh import make_dev_mesh
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def _resolve_pallas_routing(cfg, args):
    """TPU-default kernel routing (satellite of the decode-kernel PR):
    --pallas-attn/--pallas-ssm override, else REPRO_PALLAS_ATTN /
    REPRO_PALLAS_SSM, else ON exactly on real TPUs.  Frozen into the
    config here, so the decision is trace-time static."""
    import dataclasses as _dc

    from repro.kernels import autotune as autotune_lib
    attn = (args.pallas_attn if args.pallas_attn is not None
            else autotune_lib.default_use_pallas("REPRO_PALLAS_ATTN"))
    ssm = (args.pallas_ssm if args.pallas_ssm is not None
           else autotune_lib.default_use_pallas("REPRO_PALLAS_SSM"))
    return _dc.replace(cfg, use_pallas_attn=attn, use_pallas_ssm=ssm)


def serve_lm(args):
    cfg = (config_base.reduced_config(args.arch) if args.reduced
           else config_base.get_config(args.arch))
    if not cfg.decode_supported:
        raise SystemExit(f"{args.arch} does not support decode")
    cfg = _resolve_pallas_routing(cfg, args)
    model = api.get_model(cfg)
    params = model.init(jax.random.key(args.seed), cfg)
    mesh = make_dev_mesh(data=len(jax.devices()))

    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      mesh=mesh)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, plen,
                                               dtype=np.int32),
                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.1f}s ({total_new / dt:.1f} tok/s)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {r.tokens[:8]}...")


def serve_gan(args):
    from repro.configs import calo3dgan
    from repro.core import gan, validation
    from repro.data.calo import CaloSimulator, CaloSpec
    from repro.serve.replicas import ReplicaFaultInjector, ReplicaGroup
    from repro.serve.scheduler import SchedulerConfig
    from repro.serve.simulate import PhysicsGate, SimRequest, SimulateEngine
    from repro.train import checkpoint as ckpt_lib
    from repro.train.faults import FaultPlan

    cfg = calo3dgan.reduced() if args.reduced else calo3dgan.config()
    if args.ckpt and os.path.exists(os.path.join(args.ckpt, "arrays.npz")):
        params = ckpt_lib.restore_gan_generator(args.ckpt, cfg)
        policy_name = ckpt_lib.manifest_precision(args.ckpt)
        print(f"restored generator from {args.ckpt} "
              f"(step {ckpt_lib.latest_step(args.ckpt)}, "
              f"precision={policy_name})")
    else:
        params = gan.init_generator(jax.random.key(args.seed), cfg)
        policy_name = "f32"
        print("WARNING: no --ckpt given (or not found) — serving an "
              "UNTRAINED generator; the physics gate will show it")

    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape),
                        seed=args.seed + 1)
    mc = next(sim.batches(max(args.gate_window, 256)))
    gate = PhysicsGate(validation.reference_profiles(mc["image"], mc["e_p"]),
                       window=args.gate_window)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    mesh = make_dev_mesh(data=len(jax.devices()))

    # resilience wiring: SLA-derived admission + replica failover
    sched = None
    if args.sla_s > 0 and args.drain_rate > 0:
        sched = SchedulerConfig.for_sla(args.drain_rate, args.sla_s,
                                        promote_after_steps=args.promote_after)
    elif args.promote_after > 0:
        sched = SchedulerConfig(promote_after_steps=args.promote_after)
    replicas = None
    if args.replicas > 1 or args.chaos_trace:
        injector = (ReplicaFaultInjector(FaultPlan.load(args.chaos_trace))
                    if args.chaos_trace else None)
        replicas = ReplicaGroup(max(args.replicas, 2), injector=injector,
                                hedge_stall_ms=args.hedge_stall_ms)
    eng = SimulateEngine(cfg, params, buckets=buckets, mesh=mesh, gate=gate,
                         policy_name=policy_name, sched=sched,
                         replicas=replicas, max_kl=args.max_kl)
    eng.warmup()

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        eng.submit(SimRequest(
            rid=rid,
            primary_energy=float(rng.uniform(10.0, 500.0)),
            n_events=int(rng.integers(1, args.max_events + 1)),
            seed=int(rng.integers(0, 2**31 - 1)),
            deadline_s=args.sla_s if args.sla_s > 0 else None,
            priority=int(rng.integers(0, args.priorities))))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    gate.flush()
    n_ev = eng.stats["events_generated"]
    lats = sorted(r.latency_s for r in done)

    def pct(q):   # empty-safe percentile (same indexing as the bench)
        return 1e3 * lats[min(len(lats) - 1, int(len(lats) * q))] if lats \
            else 0.0

    print(f"served {len(done)} requests / {n_ev} events in {dt:.2f}s "
          f"({n_ev / dt:.1f} events/s); "
          f"latency p50={pct(0.50):.0f}ms p99={pct(0.99):.0f}ms")
    print(f"  steps={eng.stats['steps']} bucket_steps="
          f"{eng.stats['bucket_steps']} padded={eng.stats['padded_events']} "
          f"transfers={eng.stats['device_transfers']} "
          f"compiles={eng.compile_count}")
    if eng.rejected:
        print(f"  rejected {len(eng.rejected)} requests:")
        for r in eng.rejected[:8]:
            print(f"    req {r.rid}: {r.error['reason']} — "
                  f"{r.error['detail']}")
    if replicas is not None:
        print(f"  replicas: {replicas.health_report()} "
              f"group_stats={replicas.stats}")
    report = eng.degraded_report()
    if report["mode"] != "healthy":
        print(f"  DEGRADED: {report['mode']} shed={report['shed']}")
    for i, rep in enumerate(gate.reports):
        print(f"  gate window {i}: "
              + " ".join(f"{k}={rep[k]:.4f}" for k in
                         ("longitudinal_kl", "transverse_x_kl",
                          "transverse_y_kl", "response_rel_err")))
    if gate.drifted(args.max_kl):
        print(f"  GATE: profile divergence exceeds --max-kl {args.max_kl} "
              "— generator drift (or an untrained generator)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("lm", "gan"), default="lm",
                    help="lm: continuous-batching decode; gan: 3DGAN "
                         "fast-simulation service")
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # lm route
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--pallas-attn", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="route attention through the Pallas kernels "
                         "(default: on on TPU, off elsewhere; env "
                         "REPRO_PALLAS_ATTN overrides)")
    ap.add_argument("--pallas-ssm", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="route SSM scans through the Pallas kernels "
                         "(default: on on TPU, off elsewhere; env "
                         "REPRO_PALLAS_SSM overrides)")
    # gan route
    ap.add_argument("--ckpt", default="",
                    help="generator checkpoint dir (launch/train --ckpt)")
    ap.add_argument("--max-events", type=int, default=64,
                    help="request sizes drawn uniformly from [1, max]")
    ap.add_argument("--buckets", default="8,32,128",
                    help="comma-separated fixed batch buckets")
    ap.add_argument("--gate-window", type=int, default=256,
                    help="events per physics-gate report")
    ap.add_argument("--max-kl", type=float, default=1.0,
                    help="drift threshold on the worst profile KL")
    # gan resilience (serve/scheduler.py + serve/replicas.py)
    ap.add_argument("--sla-s", type=float, default=0.0,
                    help="per-request latency SLA in seconds (0 = no "
                         "deadlines, no admission bound)")
    ap.add_argument("--drain-rate", type=float, default=0.0,
                    help="measured service throughput (events/s) used to "
                         "derive the admission bound from --sla-s")
    ap.add_argument("--promote-after", type=int, default=0,
                    help="age-based promotion after this many passed-over "
                         "bucket steps (0 = off)")
    ap.add_argument("--priorities", type=int, default=1,
                    help="draw request priorities uniformly from "
                         "[0, priorities)")
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1 enables the replica failover group")
    ap.add_argument("--chaos-trace", default="",
                    help="replay a train/faults.FaultPlan JSON against the "
                         "replica group (e.g. results/serve_chaos_trace.json)")
    ap.add_argument("--hedge-stall-ms", type=float, default=200.0,
                    help="hedge scripted stalls at/above this many ms")
    args = ap.parse_args()
    if args.model == "gan":
        serve_gan(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
