"""Split-KV flash-decode: single-query Pallas attention for serving.

Decode attention is one query row against a long KV cache — the shape the
training kernel is worst at: its grid walks kv blocks SEQUENTIALLY per
(batch, kv-head) program, so a 32k cache is one long serial sweep and the
MXU sees a single (G, block_kv) tile at a time.  This kernel splits the
cache into ``num_splits`` independent grid programs per (batch, kv-head):

- each split runs the usual online-softmax sweep over its own kv blocks
  and emits UNNORMALISED partials — the f32 accumulator ``acc``, the
  running row-max ``m`` and the running denominator ``l``;
- a second (pure-JAX) stage, :func:`combine_splits`, merges the partials
  with the standard log-sum-exp algebra: ``o = sum_s acc_s * exp(m_s -
  m*) / sum_s l_s * exp(m_s - m*)``.  The merge is exactly associative
  over splits, so the split count/order is a pure scheduling knob
  (pinned by the parity suite and a hypothesis property).

Ragged continuous batching: every row carries its own valid cache length
``kv_len`` (SMEM scalar per program); blocks entirely past a row's
length are SKIPPED dynamically, so short slots don't pay for the longest
slot's cache.  GQA folds the G query heads of one kv head into the score
matmul rows, like the training kernel.

The (block_kv, num_splits) schedule is the ``DecodeBlocks`` autotune
family (kind ``attn_dec``) on the shared per-device disk cache.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune as autotune_lib
from repro.kernels.autotune import resolve_interpret

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# schedule family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeBlocks:
    """Schedule for the decode kernel: kv tile size and the number of
    independent cache splits (grid parallelism across the cache)."""
    block_kv: int = 128
    num_splits: int = 1


def signature(batch: int, seq_kv: int, heads: int, kv_heads: int,
              d_head: int, window: int, dtype=None):
    """Hashable problem identity for one decode shape.  ``seq_kv`` is the
    CACHE CAPACITY (the static T of the serving cache), not the live
    ragged length — the schedule must be fixed at trace time."""
    base = ("attn_dec", int(batch), int(seq_kv), int(heads), int(kv_heads),
            int(d_head), int(window))
    if dtype is None:
        return base
    return base + (autotune_lib.dtype_name(dtype),)


_SIG_LEN = 7


def default_blocks(sig) -> DecodeBlocks:
    """Decode is bandwidth-bound: small caches stay single-split (the
    combine has a fixed cost), long caches split every ~2k positions up
    to 8 ways so the sweep depth per program stays bounded."""
    T = sig[2]
    return DecodeBlocks(block_kv=128 if T <= 2048 else 256,
                        num_splits=max(1, min(8, T // 2048)))


def candidate_blocks(sig) -> List[DecodeBlocks]:
    """block_kv x num_splits sweep, deduplicated after clamping to the
    cache capacity (a 256-cache measures one split count, not four
    aliases of it)."""
    T = sig[2]
    cands, seen = [], set()
    for bkv in (64, 128, 256, 512):
        for ns in (1, 2, 4, 8):
            eff_b = min(bkv, T)
            n_blocks = -(-T // eff_b)
            eff_s = min(ns, n_blocks)
            if (eff_b, eff_s) in seen:
                continue
            seen.add((eff_b, eff_s))
            cands.append(DecodeBlocks(block_kv=bkv, num_splits=ns))
    return cands


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------


def _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, acc_out, m_out, l_out,
                   acc_scr, m_scr, l_scr, *, scale: float, window: int,
                   block_kv: int, blocks_per_split: int):
    si = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    kv_len = kvlen_ref[0, 0]
    k_start = (si * blocks_per_split + j) * block_kv

    # dynamic block skip: nothing valid in this tile for this row.  The
    # query sits at kv_len - 1, so "causal" is just kpos < kv_len; a
    # sliding window additionally drops blocks entirely below it.
    run = k_start < kv_len
    if window:
        run = jnp.logical_and(run, k_start + block_kv > kv_len - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]                                # (G, D)
        G, D = q.shape
        k = k_ref[0, :, 0, :]                          # (bk, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_kv), 1)
        mask = kpos < kv_len
        if window:
            mask &= kpos > kv_len - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        e = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(e, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            e.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (G, D)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(j == blocks_per_split - 1)
    def _finalize():
        G, D = acc_scr.shape
        # UNNORMALISED partials — the combine owns the normalisation.
        # An entirely-skipped split writes (acc=0, m=NEG_INF, l=0), which
        # the combine weights to exactly zero.
        acc_out[...] = acc_scr[...].reshape(1, 1, 1, G, D)
        m_out[...] = m_scr[:, 0].reshape(1, 1, 1, 1, G)
        l_out[...] = l_scr[:, 0].reshape(1, 1, 1, 1, G)


def combine_splits(acc, m, l):
    """Merge per-split online-softmax partials (second decode stage).

    acc: (..., S, G, D) unnormalised f32 accumulators; m, l: (..., S, G)
    running max / denominator per split.  Returns the normalised
    (..., G, D) attention output.  Pure log-sum-exp algebra — invariant
    to how positions were partitioned into splits (hypothesis-pinned);
    empty splits (l == 0, m == NEG_INF) contribute exactly nothing.
    """
    m_glob = jnp.max(m, axis=-2)                       # (..., G)
    w = jnp.exp(m - m_glob[..., None, :])              # (..., S, G)
    w = jnp.where(l > 0, w, 0.0)
    l_glob = jnp.sum(l * w, axis=-2)                   # (..., G)
    o = jnp.sum(acc * w[..., None], axis=-3)           # (..., G, D)
    return o / jnp.maximum(l_glob, 1e-30)[..., None]


def flash_decode(q, k, v, kv_len, *, window: int = 0,
                 block_kv: Optional[int] = None,
                 num_splits: Optional[int] = None,
                 interpret: Optional[bool] = None):
    """Single-query decode attention against a ragged KV cache.

    q: (B, 1, H, D); k/v: (B, T, KH, D) cache at CAPACITY T; kv_len: (B,)
    per-row valid lengths (the query lives at position kv_len - 1).
    Returns (B, 1, H, D) in q's dtype.  Schedule from the shared autotune
    registry unless (block_kv, num_splits) are forced (the parity suite
    uses that to pin split-count numerics-freedom).
    """
    interpret = resolve_interpret(interpret)
    B, S, H, D = q.shape
    assert S == 1, f"flash_decode is single-query (got S={S})"
    T, KH = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    G = H // KH
    if block_kv is None or num_splits is None:
        sched = autotune_lib.get_schedule(
            signature(B, T, H, KH, D, window, k.dtype))
        block_kv = block_kv or sched.block_kv
        num_splits = num_splits or sched.num_splits
    block_kv = max(1, min(block_kv, T))
    n_blocks = -(-T // block_kv)
    blocks_per_split = -(-n_blocks // max(1, num_splits))
    n_splits = -(-n_blocks // blocks_per_split)
    pad_t = n_splits * blocks_per_split * block_kv - T
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))

    qg = q[:, 0].reshape(B, KH, G, D)
    kvl = jnp.asarray(kv_len, jnp.int32).reshape(B, 1)

    kernel = functools.partial(
        _decode_kernel, scale=1.0 / (D ** 0.5), window=window,
        block_kv=block_kv, blocks_per_split=blocks_per_split)

    acc, m, l = pl.pallas_call(
        kernel,
        grid=(B, KH, n_splits, blocks_per_split),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s, j: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, s, j, bps=blocks_per_split:
                         (b, s * bps + j, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, s, j, bps=blocks_per_split:
                         (b, s * bps + j, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G, D), lambda b, h, s, j: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, G), lambda b, h, s, j: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, G), lambda b, h, s, j: (b, h, s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KH, n_splits, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, KH, n_splits, 1, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KH, n_splits, 1, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kvl, qg, k, v)

    # (B, KH, S, 1, G) -> (B, KH, S, G); acc stays (B, KH, S, G, D)
    m = m[:, :, :, 0, :]
    l = l[:, :, :, 0, :]
    o = combine_splits(acc, m, l)                      # (B, KH, G, D)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# autotune wiring
# ---------------------------------------------------------------------------


def _build_problem(sig):
    """Representative decode step: ragged kv_len staggered across the
    batch (half-full to full cache), forward-only jitted run."""
    import numpy as np

    _, B, T, H, KH, D, window = sig[:_SIG_LEN]
    dtype = jnp.dtype(sig[_SIG_LEN]) if len(sig) > _SIG_LEN else jnp.float32
    kq, kk, kv = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(kq, (B, 1, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, T, KH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, T, KH, D), jnp.float32).astype(dtype)
    kvl = jnp.asarray(np.maximum(
        1, np.linspace(T // 2, T, B).astype(np.int32)))
    interpret = autotune_lib.default_interpret()

    def make(blocks: DecodeBlocks):
        return jax.jit(lambda q_, k_, v_, l_: flash_decode(
            q_, k_, v_, l_, window=window, block_kv=blocks.block_kv,
            num_splits=blocks.num_splits, interpret=interpret))

    args = (q, k, v, kvl)

    def run(blocks: DecodeBlocks, steps: int = 3, repeats: int = 3) -> float:
        return autotune_lib.time_min_of_repeats(make(blocks), args, steps,
                                                repeats)

    return run


def model_signatures(cfg, max_len: int, batch: int = 4, dtype=None) -> list:
    """The decode signature one serving config hits: (slots, cache
    capacity, attention geometry).  Hybrid archs decode through the
    shared block, which runs at 2x width over the shared-attention ring."""
    if cfg.family == "hybrid":
        from repro.models.zamba import _SHARED_WINDOW, _shared_cfg
        scfg = _shared_cfg(cfg)
        cap = min(max_len, _SHARED_WINDOW)
        return [signature(batch, cap, scfg.n_heads, scfg.n_kv_heads,
                          scfg.d_head, 0, dtype)]
    return [signature(batch, max_len, cfg.n_heads, cfg.n_kv_heads,
                      cfg.d_head, 0, dtype)]


autotune_lib.register_kernel(autotune_lib.KernelSpec(
    family="flash_decode",
    kinds=("attn_dec",),
    schedule_cls=DecodeBlocks,
    sig_len=_SIG_LEN,
    default=default_blocks,
    candidates=candidate_blocks,
    build=_build_problem,
))
