"""jit'd public wrapper for the flash-attention kernel.

Forward runs the Pallas kernel; backward differentiates the ref oracle
(numerically identical math), so ``flash_attention`` is safe to use inside
training code while the fused backward kernel is future work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: bool = True):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=interpret)


def _fwd(q, k, v, causal, window, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
