"""jit'd public wrapper for the flash-attention kernel.

Forward AND backward run the Pallas kernels: the forward emits the
per-row log-sum-exp residual, the backward is the flash-2 tiled
recompute (dq over kv blocks, dk/dv over q blocks) — no ref-oracle
fallback, no (S, T) score matrix in HBM in either direction.

``block_q``/``block_kv`` come from the shared autotune registry
(:mod:`repro.kernels.autotune`) by problem signature, so an offline
``tools/autotune_kernels.py`` run retiles both directions here without
touching call sites.  ``interpret=None`` freezes the device-kind default
at trace time — compiled on TPU, interpreter everywhere else.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import autotune as autotune_lib
from repro.kernels.flash_attention import tune as tune_lib
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_bwd, flash_attention_fwd)


def _schedule(q, k, causal, window) -> tune_lib.AttnBlocks:
    sig = tune_lib.signature(q.shape[1], k.shape[1], q.shape[2], k.shape[2],
                             q.shape[3], causal, window, q.dtype)
    return autotune_lib.get_schedule(sig)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: bool | None = None):
    blocks = _schedule(q, k, causal, window)
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=blocks.block_q,
        block_kv=blocks.block_kv,
        interpret=autotune_lib.resolve_interpret(interpret))


def _fwd(q, k, v, causal, window, interpret):
    blocks = _schedule(q, k, causal, window)
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=blocks.block_q,
        block_kv=blocks.block_kv,
        interpret=autotune_lib.resolve_interpret(interpret),
        return_lse=True)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, interpret, res, g):
    q, k, v, out, lse = res
    blocks = _schedule(q, k, causal, window)
    return flash_attention_bwd(
        q, k, v, out, lse, g, causal=causal, window=window,
        block_q=blocks.block_q, block_kv=blocks.block_kv,
        interpret=autotune_lib.resolve_interpret(interpret))


flash_attention.defvjp(_fwd, _bwd)
