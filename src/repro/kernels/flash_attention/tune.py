"""Autotune family registration for the flash-attention Pallas kernels.

Plugs the attention kernels into :mod:`repro.kernels.autotune`: the
signature is the shape that drives tiling — (seq_q, seq_kv, heads,
kv_heads, d_head, causal, window) plus the optional dtype qualifier —
and the schedule is an :class:`AttnBlocks` (block_q, block_kv) pair.
The measurement builder times the full fwd+bwd through the Pallas
kernels, because the winning tile must serve the training step, not
just inference.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.kernels import autotune as autotune_lib


@dataclasses.dataclass(frozen=True)
class AttnBlocks:
    """Schedule for the flash kernels: the q-row and kv-column tile
    sizes of the online-softmax sweep (clamped to the sequence lengths
    at trace time)."""
    block_q: int = 128
    block_kv: int = 128


def signature(seq_q: int, seq_kv: int, heads: int, kv_heads: int,
              d_head: int, causal, window: int, dtype=None):
    """Hashable problem identity for one attention shape.  ``causal``
    is stored as an int so the cache key round-trips through the generic
    ``kind|field|...`` string format."""
    base = ("attn", int(seq_q), int(seq_kv), int(heads), int(kv_heads),
            int(d_head), int(bool(causal)), int(window))
    if dtype is None:
        return base
    return base + (autotune_lib.dtype_name(dtype),)


_SIG_LEN = 8


def default_blocks(sig) -> AttnBlocks:
    """MXU-native 128x128; the wrappers clamp to the actual sequence
    lengths, so short sequences never pay padded tiles."""
    return AttnBlocks()


def candidate_blocks(sig) -> List[AttnBlocks]:
    """The sweep space: the block_q x block_kv grid, deduplicated after
    clamping to (seq_q, seq_kv) so short sequences don't measure
    aliases of the same effective schedule."""
    _, seq_q, seq_kv = sig[:3]
    cands, seen = [], set()
    for bq in (64, 128, 256, 512):
        for bkv in (64, 128, 256, 512):
            eff = (min(bq, seq_q), min(bkv, seq_kv))
            if eff in seen:
                continue
            seen.add(eff)
            cands.append(AttnBlocks(block_q=bq, block_kv=bkv))
    return cands


def _build_problem(sig):
    """Representative arrays + runner: one jitted fwd+bwd through the
    Pallas kernels per candidate (blocks are trace-time static)."""
    import jax
    import jax.numpy as jnp

    import importlib
    fa = importlib.import_module(
        "repro.kernels.flash_attention.flash_attention")

    _, S, T, H, KH, D, causal, window = sig[:_SIG_LEN]
    dtype = jnp.dtype(sig[_SIG_LEN]) if len(sig) > _SIG_LEN else jnp.float32
    kq, kk, kv, kg = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(kq, (1, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (1, T, KH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (1, T, KH, D), jnp.float32).astype(dtype)
    do = jax.random.normal(kg, (1, S, H, D), jnp.float32).astype(dtype)
    interpret = autotune_lib.default_interpret()

    def make(blocks: AttnBlocks):
        def fwd_bwd(q_, k_, v_, do_):
            out, lse = fa.flash_attention_fwd(
                q_, k_, v_, causal=bool(causal), window=window,
                block_q=blocks.block_q, block_kv=blocks.block_kv,
                interpret=interpret, return_lse=True)
            return fa.flash_attention_bwd(
                q_, k_, v_, out, lse, do_, causal=bool(causal),
                window=window, block_q=blocks.block_q,
                block_kv=blocks.block_kv, interpret=interpret)
        return jax.jit(fwd_bwd)

    args = (q, k, v, do)

    def run(blocks: AttnBlocks, steps: int = 3, repeats: int = 3) -> float:
        return autotune_lib.time_min_of_repeats(make(blocks), args, steps,
                                                repeats)

    return run


def model_signatures(cfg, seq_len: int, dtype=None,
                     window: Optional[int] = None) -> list:
    """The attention signatures one LM config hits at a given training
    sequence length (self-attention, causal; the config's sliding
    window unless overridden)."""
    win = cfg.sliding_window if window is None else window
    return [signature(seq_len, seq_len, cfg.n_heads, cfg.n_kv_heads,
                      cfg.d_head, True, win, dtype)]


autotune_lib.register_kernel(autotune_lib.KernelSpec(
    family="flash_attention",
    kinds=("attn",),
    schedule_cls=AttnBlocks,
    sig_len=_SIG_LEN,
    default=default_blocks,
    candidates=candidate_blocks,
    build=_build_problem,
))
