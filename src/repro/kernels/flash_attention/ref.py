"""Pure-jnp oracle for the flash-attention kernel.

Plain materialised-scores attention with causal / sliding-window masking and
GQA head grouping — numerically the ground truth the Pallas kernel must match
(f32 score math, softmax over the full row).
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, S, H, D); k/v: (B, T, KH, D) with H % KH == 0.

    Returns (B, S, H, D) in q.dtype.  Scores and softmax in f32.
    """
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = jnp.where(mask[None, None, None], w, 0.0)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqt,btkd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)
