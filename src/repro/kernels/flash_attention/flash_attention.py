"""Pallas TPU flash attention: tiled online-softmax with GQA folding.

TPU adaptation (vs. the CUDA algorithm):

- tiles live in VMEM via BlockSpec; the kv loop is the innermost grid
  dimension, which TPU executes SEQUENTIALLY per core — the running
  (acc, m, l) online-softmax state is carried in VMEM scratch across kv
  steps (no atomics / shared-memory reductions as on GPU);
- all G query heads of one kv head are FOLDED into the score matmul's row
  dimension: (bq*G, D) @ (D, bk).  For GQA models (G=6..48) this turns many
  skinny matmuls into one MXU-shaped (>=128 rows) matmul per tile;
- score math is f32 (MXU accumulates bf16 inputs into f32).

The backward pass is the flash-2 recompute scheme: the forward also emits
the per-row log-sum-exp, so each backward tile rebuilds its probabilities
as ``p = exp(s - lse)`` from the SAME tiled score matmul (no (S, T) score
matrix ever hits HBM).  Two kernels, because the two accumulation orders
differ: dq sums over kv blocks (kv innermost, like the forward), dk/dv
sum over q blocks (q innermost).  ``delta = rowsum(dO * O)`` — the
softmax-jacobian correction — is a cheap elementwise reduction computed
outside the kernels in f32.

Grid: (B, KH, n_q_blocks, n_kv_blocks), kv innermost (dq / forward);
      (B, KH, n_kv_blocks, n_q_blocks), q innermost (dk/dv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.autotune import resolve_interpret

NEG_INF = -1e30
# padded-row LSE: exp(s - LSE_PAD) underflows to exactly 0, so rows past
# the true sequence end contribute nothing to any backward accumulation
LSE_PAD = 1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                  l_ref, *, scale: float, causal: bool, window: int,
                  block_q: int, block_kv: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # skip kv blocks entirely above the causal diagonal / below the window
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(run, k_start + block_kv > q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                   # (bq, G, D)
        bq, G, D = q.shape
        q2 = q.reshape(bq * G, D)
        k = k_ref[0, :, 0, :]                          # (bk, D)
        v = v_ref[0, :, 0, :]                          # (bk, D)
        s = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq*G, bk)

        mask = _tile_mask(q_start, k_start, bq, G, block_kv, seq_k,
                          causal, window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq*G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        e = jnp.exp(s - m_new)
        e = jnp.where(mask, e, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(e, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            e.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq*G, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        bq, G, D = q_ref[0].shape
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = o.reshape(1, bq, G, D).astype(o_ref.dtype)
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        lse_ref[...] = lse.reshape(1, bq, 1, G)


def _tile_mask(q_start, k_start, bq, G, block_kv, seq_k, causal, window):
    """The (bq*G, block_kv) validity mask of one score tile — the padded
    kv tail plus the causal / sliding-window structure, with the G folded
    query heads sharing each query position."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bq * G, block_kv), 0)
    qpos = q_start + rows // G
    kpos = k_start + jax.lax.broadcasted_iota(
        jnp.int32, (bq * G, block_kv), 1)
    mask = kpos < seq_k                                # guard padded tail
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    return mask


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_kv: int = 128,
                        interpret: bool | None = None, return_lse: bool = False):
    """q: (B, S, H, D); k/v: (B, T, KH, D). Returns (B, S, H, D), and the
    per-row f32 log-sum-exp (B, S, H) when ``return_lse`` (the backward
    residual)."""
    interpret = resolve_interpret(interpret)
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    G = H // KH
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    n_q = -(-S // block_q)
    n_kv = -(-T // block_kv)
    pad_s = n_q * block_q - S
    pad_t = n_kv * block_kv - T
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (D ** 0.5), causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_k=T)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, KH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, G, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, G, D),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_q, 1, G),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_q * block_q, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, n_q * block_q, KH, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q * G, D), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    out = out[:, :S]
    if not return_lse:
        return out
    # (B, Sp, KH, G) -> (B, S, H): head kh*G+g matches q's head layout
    return out, lse.reshape(B, n_q * block_q, H)[:, :S]


# ---------------------------------------------------------------------------
# chunked-prefill forward (serving): per-row query offset + ragged kv_len
# ---------------------------------------------------------------------------


def _flash_chunk_kernel(off_ref, kvl_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *, scale: float, window: int,
                        block_q: int, block_kv: int):
    """The forward online-softmax sweep, with the causal structure shifted
    by a PER-ROW dynamic query offset (the slot's cache position) and the
    kv extent bounded by a per-row dynamic ``kv_len`` instead of the
    static cache capacity.  Forward-only: serving never differentiates
    through the cache, so no LSE output."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_off = off_ref[0, 0]
    kv_len = kvl_ref[0, 0]
    q_start = qi * block_q
    k_start = ki * block_kv

    # dynamic block skip: past the row's live cache, or entirely above the
    # (offset-shifted) causal diagonal, or entirely below the window
    run = jnp.logical_and(k_start < kv_len,
                          k_start <= q_off + q_start + block_q - 1)
    if window:
        run = jnp.logical_and(
            run, k_start + block_kv > q_off + q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                   # (bq, G, D)
        bq, G, D = q.shape
        q2 = q.reshape(bq * G, D)
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq*G, bk)

        rows = jax.lax.broadcasted_iota(jnp.int32, (bq * G, block_kv), 0)
        qpos = q_off + q_start + rows // G
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (bq * G, block_kv), 1)
        mask = jnp.logical_and(kpos < kv_len, qpos >= kpos)
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        e = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(e, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            e.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        bq, G, D = q_ref[0].shape
        # fully-masked rows (inactive slots, padded chunk tail) have l = 0
        # and finalize to exactly 0 — finite, never NaN
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = o.reshape(1, bq, G, D).astype(o_ref.dtype)


def flash_attention_chunk(q, k, v, q_offset, kv_len, *, window: int = 0,
                          block_q: int = 128, block_kv: int = 128,
                          interpret: bool | None = None):
    """Prompt-chunk attention against a ragged cache (serving prefill).

    q: (B, C, H, D) — one chunk of C prompt positions per slot, whose
    row i sits at absolute cache position ``q_offset[b] + i``; k/v:
    (B, T, KH, D) cache at capacity T, already containing this chunk's
    keys; kv_len: (B,) per-row total live length.  Rows past a slot's
    live prompt (and entirely inactive slots, kv_len = 0) yield exact
    zeros.  Returns (B, C, H, D) in q's dtype.
    """
    interpret = resolve_interpret(interpret)
    B, C, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    G = H // KH
    block_q = min(block_q, C)
    block_kv = min(block_kv, T)
    n_q = -(-C // block_q)
    n_kv = -(-T // block_kv)
    pad_c = n_q * block_q - C
    pad_t = n_kv * block_kv - T
    if pad_c:
        q = jnp.pad(q, ((0, 0), (0, pad_c), (0, 0), (0, 0)))
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    off = jnp.asarray(q_offset, jnp.int32).reshape(B, 1)
    kvl = jnp.asarray(kv_len, jnp.int32).reshape(B, 1)

    kernel = functools.partial(
        _flash_chunk_kernel, scale=1.0 / (D ** 0.5), window=window,
        block_q=block_q, block_kv=block_kv)

    smem = pl.BlockSpec((1, 1), lambda b, h, qi, ki: (b, 0),
                        memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        kernel,
        grid=(B, KH, n_q, n_kv),
        in_specs=[
            smem, smem,
            pl.BlockSpec((1, block_q, G, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, G, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_q * block_q, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, D), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(off, kvl, q, k, v)
    return out[:, :C]


# ---------------------------------------------------------------------------
# backward kernels (flash-2 recompute)
# ---------------------------------------------------------------------------


def _recompute_p(q_ref, k_ref, lse_ref, q_start, k_start, block_kv, seq_k,
                 scale, causal, window):
    """Rebuild one tile's probabilities p = exp(s - lse) plus the pieces
    the grads need (q2, k, mask).  Masked entries are exactly 0 — the
    where guards AFTER the exp, because masked scores are finite raw
    dot products, not NEG_INF."""
    q = q_ref[0]                                       # (bq, G, D)
    bq, G, D = q.shape
    q2 = q.reshape(bq * G, D)
    k = k_ref[0, :, 0, :]                              # (bk, D)
    s = jax.lax.dot_general(
        q2, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (bq*G, bk)
    mask = _tile_mask(q_start, k_start, bq, G, block_kv, seq_k,
                      causal, window)
    lse = lse_ref[0, :, 0, :].reshape(bq * G, 1)       # same row folding
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)
    return q2, k, p


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                         dq_ref, acc_ref, *, scale: float, causal: bool,
                         window: int, block_q: int, block_kv: int,
                         seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(run, k_start + block_kv > q_start - window + 1)

    @pl.when(run)
    def _compute():
        q2, k, p = _recompute_p(q_ref, k_ref, lse_ref, q_start, k_start,
                                block_kv, seq_k, scale, causal, window)
        bq, G, D = q_ref[0].shape
        v = v_ref[0, :, 0, :]
        do = do_ref[0].reshape(bq * G, D)
        delta = dl_ref[0, :, 0, :].reshape(bq * G, 1)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq*G, bk)
        ds = p * (dp - delta) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq*G, D)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        bq, G, D = q_ref[0].shape
        dq_ref[...] = acc_ref[...].reshape(1, bq, G, D).astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                          causal: bool, window: int, block_q: int,
                          block_kv: int, seq_k: int):
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    n_q = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = qi * block_q
    k_start = ki * block_kv
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(run, k_start + block_kv > q_start - window + 1)

    @pl.when(run)
    def _compute():
        q2, k, p = _recompute_p(q_ref, k_ref, lse_ref, q_start, k_start,
                                block_kv, seq_k, scale, causal, window)
        bq, G, D = q_ref[0].shape
        v = v_ref[0, :, 0, :]
        do = do_ref[0].reshape(bq * G, D)
        delta = dl_ref[0, :, 0, :].reshape(bq * G, 1)
        # padded q rows carry do = 0 and delta = 0, so both accumulations
        # receive exactly zero from them — no qpos < seq_q mask needed
        dv_acc[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bk, D)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds.astype(q2.dtype), q2, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bk, D)

    @pl.when(qi == n_q - 1)
    def _finalize():
        bk, D = dk_acc.shape
        dk_ref[...] = dk_acc[...].reshape(1, bk, 1, D).astype(dk_ref.dtype)
        dv_ref[...] = dv_acc[...].reshape(1, bk, 1, D).astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool = True,
                        window: int = 0, block_q: int = 128,
                        block_kv: int = 128, interpret: bool | None = None):
    """Tiled recompute backward.  ``o``/``lse`` are the forward outputs
    (lse in f32, (B, S, H)); returns (dq, dk, dv) in the operand dtypes."""
    interpret = resolve_interpret(interpret)
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    n_q = -(-S // block_q)
    n_kv = -(-T // block_kv)
    pad_s = n_q * block_q - S
    pad_t = n_kv * block_kv - T

    # the softmax-jacobian row correction, in f32 regardless of operand
    # dtype — it divides grads that were accumulated in f32
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                            # (B, S, H)
    if pad_s:
        qpad = ((0, 0), (0, pad_s), (0, 0), (0, 0))
        q = jnp.pad(q, qpad)
        do = jnp.pad(do, qpad)
        lse = jnp.pad(lse, ((0, 0), (0, pad_s), (0, 0)),
                      constant_values=LSE_PAD)
        delta = jnp.pad(delta, ((0, 0), (0, pad_s), (0, 0)))
    if pad_t:
        kpad = ((0, 0), (0, pad_t), (0, 0), (0, 0))
        k = jnp.pad(k, kpad)
        v = jnp.pad(v, kpad)
    Sp = n_q * block_q
    lse = lse.reshape(B, Sp, KH, G)
    delta = delta.reshape(B, Sp, KH, G)

    opts = dict(scale=1.0 / (D ** 0.5), causal=causal, window=window,
                block_q=block_q, block_kv=block_kv, seq_k=T)
    q_spec = pl.BlockSpec((1, block_q, G, D),
                          lambda b, h, qi, ki: (b, qi, h, 0))
    row_spec = pl.BlockSpec((1, block_q, 1, G),
                            lambda b, h, qi, ki: (b, qi, h, 0))
    kv_spec = pl.BlockSpec((1, block_kv, 1, D),
                           lambda b, h, qi, ki: (b, ki, h, 0))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, **opts),
        grid=(B, KH, n_q, n_kv),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q * G, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv accumulate over q blocks — q is the innermost (sequential)
    # grid dimension here, so the index maps swap their last two args
    q_spec_t = pl.BlockSpec((1, block_q, G, D),
                            lambda b, h, ki, qi: (b, qi, h, 0))
    row_spec_t = pl.BlockSpec((1, block_q, 1, G),
                              lambda b, h, ki, qi: (b, qi, h, 0))
    kv_spec_t = pl.BlockSpec((1, block_kv, 1, D),
                             lambda b, h, ki, qi: (b, ki, h, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, **opts),
        grid=(B, KH, n_kv, n_q),
        in_specs=[q_spec_t, kv_spec_t, kv_spec_t, q_spec_t, row_spec_t,
                  row_spec_t],
        out_specs=[kv_spec_t, kv_spec_t],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv * block_kv, KH, D), k.dtype),
            jax.ShapeDtypeStruct((B, n_kv * block_kv, KH, D), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_kv, D), jnp.float32),
                        pltpu.VMEM((block_kv, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    return dq[:, :S], dk[:, :T], dv[:, :T]
