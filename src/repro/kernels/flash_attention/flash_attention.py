"""Pallas TPU flash attention: tiled online-softmax with GQA folding.

TPU adaptation (vs. the CUDA algorithm):

- tiles live in VMEM via BlockSpec; the kv loop is the innermost grid
  dimension, which TPU executes SEQUENTIALLY per core — the running
  (acc, m, l) online-softmax state is carried in VMEM scratch across kv
  steps (no atomics / shared-memory reductions as on GPU);
- all G query heads of one kv head are FOLDED into the score matmul's row
  dimension: (bq*G, D) @ (D, bk).  For GQA models (G=6..48) this turns many
  skinny matmuls into one MXU-shaped (>=128 rows) matmul per tile;
- score math is f32 (MXU accumulates bf16 inputs into f32).

Grid: (B, KH, n_q_blocks, n_kv_blocks), kv innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_kv: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # skip kv blocks entirely above the causal diagonal / below the window
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(run, k_start + block_kv > q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                   # (bq, G, D)
        bq, G, D = q.shape
        q2 = q.reshape(bq * G, D)
        k = k_ref[0, :, 0, :]                          # (bk, D)
        v = v_ref[0, :, 0, :]                          # (bk, D)
        s = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq*G, bk)

        rows = jax.lax.broadcasted_iota(jnp.int32, (bq * G, block_kv), 0)
        qpos = q_start + rows // G
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (bq * G, block_kv), 1)
        mask = kpos < seq_k                            # guard padded tail
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq*G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        e = jnp.exp(s - m_new)
        e = jnp.where(mask, e, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(e, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            e.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq*G, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        bq, G, D = q_ref[0].shape
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = o.reshape(1, bq, G, D).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_kv: int = 128,
                        interpret: bool = True):
    """q: (B, S, H, D); k/v: (B, T, KH, D). Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    G = H // KH
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    n_q = -(-S // block_q)
    n_kv = -(-T // block_kv)
    pad_s = n_q * block_q - S
    pad_t = n_kv * block_kv - T
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (D ** 0.5), causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_k=T)

    out = pl.pallas_call(
        kernel,
        grid=(B, KH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, G, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, G, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_q * block_q, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, D), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
