"""Pallas TPU kernels for the perf-critical compute layers.

- flash_attention: tiled online-softmax attention (GQA-folded MXU matmuls)
- conv3d:          the 3DGAN hot-spot as implicit GEMM
- ssm_scan:        Mamba2/SSD chunked scan with VMEM state carry

All validated against pure-jnp oracles (ref.py) with interpret=True on CPU.
"""
