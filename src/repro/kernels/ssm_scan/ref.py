"""Pure-jnp oracle for the Mamba2 (SSD) scan kernel.

Naive SEQUENTIAL recurrence — one timestep at a time — which is the
definition of the selective-state-space update:

    s_t = exp(dt_t * A_h) * s_{t-1} + dt_t * x_t B_t^T      (per head h)
    y_t = C_t . s_t

Deliberately independent of the chunked formulations in substrate/ssm.py
and in the Pallas kernel, so it validates BOTH.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, B, C, dt, A, init_state=None):
    """x: (Bt, S, H, P); B/C: (Bt, S, N); dt: (Bt, S, H); A: (H,) negative.

    Returns (y (Bt, S, H, P), final_state (Bt, H, P, N)).  All math f32.
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    x = x.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bt, H, P, N), jnp.float32))

    def step(s, inp):
        xt, Bt_, Ct_, dtt = inp                        # (B,H,P),(B,N),(B,N),(B,H)
        decay = jnp.exp(dtt * A)                       # (B,H)
        s = s * decay[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, Bt_)
        y = jnp.einsum("bn,bhpn->bhp", Ct_, s)
        return s, y

    inputs = (x.transpose(1, 0, 2, 3), B.transpose(1, 0, 2),
              C.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    s, ys = jax.lax.scan(step, s0, inputs)
    return ys.transpose(1, 0, 2, 3), s
