"""Pallas TPU Mamba2 (SSD) chunked scan kernel.

TPU adaptation of the Mamba2 CUDA kernel's split into "intra-chunk" and
"inter-chunk" work:

- the sequence is blocked into chunks of length L; within a chunk the
  recurrence unrolls into three DENSE matmuls (MXU work):
      cb       = C @ B^T                  (L, L)
      y_intra  = (cb * decay * dt) @ x    (L, L) @ (L, P)
      dstate   = (w * x)^T @ B            (P, L) @ (L, N)
- the inter-chunk state (P, N) is carried in VMEM scratch across the
  SEQUENTIAL innermost grid dimension (TPU grid order replaces the GPU
  kernel's block-level carry),
- decay factors are computed from the in-chunk cumsum of log-decay; all
  state math is f32.

Grid: (B, H, n_chunks) — chunks innermost (sequential carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, s0_ref,
                y_ref, sf_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)            # (L, P)
    B = b_ref[0, 0].astype(jnp.float32)               # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)               # (L, N)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)    # (L,)
    A = a_ref[0, 0]                                   # scalar (negative)

    la = dt * A                                       # (L,) log-decay
    F = jnp.cumsum(la)                                # inclusive cumsum
    Ftot = F[-1]
    state = state_ref[...]                            # (P, N)

    # ---- inter-chunk: y_t += exp(F_t) * C_t . state
    y_inter = jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(F)[:, None]  # (L, P)

    # ---- intra-chunk: M[t, s] = (C_t.B_s) exp(F_t - F_s) dt_s,  s <= t
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = F[:, None] - F[None, :]
    M = jnp.where(rows >= cols, cb * jnp.exp(dec) * dt[None, :], 0.0)
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # ---- state update: s' = exp(Ftot) s + sum_t exp(Ftot - F_t) dt_t x_t B_t^T
    wgt = jnp.exp(Ftot - F) * dt                      # (L,)
    dstate = jax.lax.dot_general(
        x * wgt[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (P, N)
    state_ref[...] = state * jnp.exp(Ftot) + dstate

    @pl.when(ci == pl.num_programs(2) - 1)
    def _final():
        sf_ref[0, 0] = state_ref[...]


def ssm_scan(x, B, C, dt, A, init_state=None, *, chunk: int = 128,
             interpret: bool = True):
    """Chunked SSD scan.  x: (Bt,S,H,P); B/C: (Bt,S,N); dt: (Bt,S,H);
    A: (H,).  Returns (y (Bt,S,H,P) f32, final_state (Bt,H,P,N) f32)."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nC = S // L

    xc = x.reshape(Bt, nC, L, H, P).transpose(0, 3, 1, 2, 4)   # (B,H,nC,L,P)
    dtc = dt.reshape(Bt, nC, L, H).transpose(0, 3, 1, 2)[..., None]
    bc = B.reshape(Bt, nC, L, N)
    cc = C.reshape(Bt, nC, L, N)
    a2 = jnp.broadcast_to(A.astype(jnp.float32)[None], (Bt, H))
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bt, H, P, N), jnp.float32))

    y, sf = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=L),
        grid=(Bt, H, nC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, H, nC, L, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xc, bc, cc, dtc, a2, s0)
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bt, S, H, P)
    return y, sf
