"""Pallas TPU Mamba2 (SSD) chunked scan kernel.

TPU adaptation of the Mamba2 CUDA kernel's split into "intra-chunk" and
"inter-chunk" work:

- the sequence is blocked into chunks of length L; within a chunk the
  recurrence unrolls into three DENSE matmuls (MXU work):
      cb       = C @ B^T                  (L, L)
      y_intra  = (cb * decay * dt) @ x    (L, L) @ (L, P)
      dstate   = (w * x)^T @ B            (P, L) @ (L, N)
- the inter-chunk state (P, N) is carried in VMEM scratch across the
  SEQUENTIAL innermost grid dimension (TPU grid order replaces the GPU
  kernel's block-level carry),
- decay factors are computed from the in-chunk cumsum of log-decay; all
  state math is f32.

Sequences that don't divide the chunk length are zero-padded: a padded
step has dt = 0 (decay exp(0) = 1, zero state injection) and x = B = C
= 0, so the carried state and every valid output row are untouched.

The backward pass is a REVERSE chunk scan through the same dense-matmul
structure: the forward also records each chunk's ENTRY state, and the
backward grid walks chunks last-to-first carrying dL/d(chunk-end state)
in VMEM scratch, emitting dx/dB/dC/ddt (and the log-decay cotangent that
reduces to dA) per chunk.

Grid: (B, H, n_chunks) — chunks innermost (sequential carry), reversed
via the block index maps for the backward kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.autotune import resolve_interpret


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, s0_ref,
                y_ref, sf_ref, si_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)            # (L, P)
    B = b_ref[0, 0].astype(jnp.float32)               # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)               # (L, N)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)    # (L,)
    A = a_ref[0, 0]                                   # scalar (negative)

    la = dt * A                                       # (L,) log-decay
    F = jnp.cumsum(la)                                # inclusive cumsum
    Ftot = F[-1]
    state = state_ref[...]                            # (P, N)
    si_ref[0, 0, 0] = state                           # backward residual

    # ---- inter-chunk: y_t += exp(F_t) * C_t . state
    y_inter = jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(F)[:, None]  # (L, P)

    # ---- intra-chunk: M[t, s] = (C_t.B_s) exp(F_t - F_s) dt_s,  s <= t
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = F[:, None] - F[None, :]
    M = jnp.where(rows >= cols, cb * jnp.exp(dec) * dt[None, :], 0.0)
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # ---- state update: s' = exp(Ftot) s + sum_t exp(Ftot - F_t) dt_t x_t B_t^T
    wgt = jnp.exp(Ftot - F) * dt                      # (L,)
    dstate = jax.lax.dot_general(
        x * wgt[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (P, N)
    state_ref[...] = state * jnp.exp(Ftot) + dstate

    @pl.when(ci == pl.num_programs(2) - 1)
    def _final():
        sf_ref[0, 0] = state_ref[...]


def _chunk_layout(x, B, C, dt, chunk):
    """Clamp + zero-pad to a whole number of chunks and reshape into the
    kernel's (B, H, nC, L, ...) block layout."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    L = min(chunk, S)
    nC = -(-S // L)
    pad = nC * L - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(Bt, nC, L, H, P).transpose(0, 3, 1, 2, 4)   # (B,H,nC,L,P)
    dtc = dt.reshape(Bt, nC, L, H).transpose(0, 3, 1, 2)[..., None]
    bc = B.reshape(Bt, nC, L, N)
    cc = C.reshape(Bt, nC, L, N)
    return xc, bc, cc, dtc, L, nC


def ssm_scan(x, B, C, dt, A, init_state=None, *, chunk: int = 128,
             interpret: bool | None = None, return_chunk_states: bool = False):
    """Chunked SSD scan.  x: (Bt,S,H,P); B/C: (Bt,S,N); dt: (Bt,S,H);
    A: (H,).  Returns (y (Bt,S,H,P) f32, final_state (Bt,H,P,N) f32),
    plus the per-chunk ENTRY states (Bt,H,nC,P,N) — the backward
    residual — when ``return_chunk_states``."""
    interpret = resolve_interpret(interpret)
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    xc, bc, cc, dtc, L, nC = _chunk_layout(x, B, C, dt, chunk)
    a2 = jnp.broadcast_to(A.astype(jnp.float32)[None], (Bt, H))
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bt, H, P, N), jnp.float32))

    y, sf, si = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=L),
        grid=(Bt, H, nC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, H, nC, L, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, nC, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xc, bc, cc, dtc, a2, s0)
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bt, nC * L, H, P)[:, :S]
    if return_chunk_states:
        return y, sf, si
    return y, sf


# ---------------------------------------------------------------------------
# backward kernel: reverse chunk scan carrying dL/d(state)
# ---------------------------------------------------------------------------


def _ssd_bwd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, si_ref, dy_ref,
                    dx_ref, db_ref, dc_ref, ddt_ref, dla_ref, g_ref, *,
                    chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        # last chunk first: nothing downstream consumes its end state
        g_ref[...] = jnp.zeros_like(g_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)            # (L, P)
    B = b_ref[0, 0].astype(jnp.float32)               # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)               # (L, N)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)    # (L,)
    A = a_ref[0, 0]
    s0 = si_ref[0, 0, 0]                              # chunk ENTRY state
    dy = dy_ref[0, 0, 0]                              # (L, P) f32
    G = g_ref[...]                                    # dL/d(chunk-end state)

    la = dt * A
    F = jnp.cumsum(la)
    Ftot = F[-1]
    eF = jnp.exp(F)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

    # ---- recompute the forward chunk pieces
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, L)
    edec = jnp.where(rows >= cols, jnp.exp(F[:, None] - F[None, :]), 0.0)
    Mnodt = cb * edec                                 # M without the dt col
    y_inter = jax.lax.dot_general(
        C, s0, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * eF[:, None]          # (L, P)
    w_exp = jnp.exp(Ftot - F)                         # (L,)
    w = w_exp * dt
    dstate = jax.lax.dot_general(
        x * w[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s1 = s0 * jnp.exp(Ftot) + dstate                  # chunk-end state

    # ---- shared intermediates
    dyx = jax.lax.dot_general(dy, x, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (L, L)
    DM = dyx * Mnodt                                  # d(dec) seed, masked
    T1 = dyx * edec                                   # d(cb) seed / dt
    BG = jax.lax.dot_general(B, G, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, P)
    xG = jax.lax.dot_general(x, G, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, N)
    xBG = jnp.sum(x * BG, axis=1)                     # (L,)

    # ---- operand grads
    M = Mnodt * dt[None, :]
    dx = jax.lax.dot_general(M, dy, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        + w[:, None] * BG                                          # (L, P)
    dB = dt[:, None] * jax.lax.dot_general(
        T1, C, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + w[:, None] * xG      # (L, N)
    dC = jax.lax.dot_general(
        T1 * dt[None, :], B, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) \
        + eF[:, None] * jax.lax.dot_general(
            dy, s0, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                    # (L, N)

    # ---- log-decay cotangent: every F_t dependence, incl. the chunk-end
    # state's Ftot (a <G, s1> bump on the last row), then the reverse
    # cumsum dla_t = sum_{u >= t} dF_u  (written cumsum-only: TPU-safe)
    DMdt = DM * dt[None, :]
    dF = (jnp.sum(dy * y_inter, axis=1) + jnp.sum(DMdt, axis=1)
          - jnp.sum(DMdt, axis=0) - w * xBG)                       # (L,)
    gs1 = jnp.sum(G * s1)
    ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)[:, 0]
    dF = dF + jnp.where(ids == chunk - 1, gs1, 0.0)
    dla = jnp.sum(dF) - jnp.cumsum(dF) + dF
    ddt = A * dla + jnp.sum(DM, axis=0) + w_exp * xBG

    # ---- carry to the PREVIOUS chunk: dL/d(its end state) = dL/d(s0)
    g_ref[...] = G * jnp.exp(Ftot) + jax.lax.dot_general(
        dy * eF[:, None], C, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    dx_ref[0, 0, 0] = dx
    db_ref[0, 0, 0] = dB
    dc_ref[0, 0, 0] = dC
    ddt_ref[0, 0, 0] = ddt[:, None]
    dla_ref[0, 0, 0] = dla[:, None]


def ssm_scan_bwd(x, B, C, dt, A, chunk_states, dy, *, chunk: int = 128,
                 interpret: bool | None = None):
    """Reverse chunk scan.  ``chunk_states`` is the forward's per-chunk
    entry-state residual; ``dy`` the y cotangent.  Returns
    (dx, dB, dC, ddt, dA) in the operand dtypes (dB/dC summed over
    heads, matching the broadcast forward)."""
    interpret = resolve_interpret(interpret)
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    xc, bc, cc, dtc, L, nC = _chunk_layout(x, B, C, dt, chunk)
    dyc = _chunk_layout(dy.astype(jnp.float32), B, C, dt, chunk)[0]
    a2 = jnp.broadcast_to(A.astype(jnp.float32)[None], (Bt, H))

    # chunks walk last-to-first: grid step c reads/writes block nC-1-c
    def rev5(b, h, c):
        return (b, h, nC - 1 - c, 0, 0)

    def rev4(b, h, c):
        return (b, nC - 1 - c, 0, 0)

    dxc, dbc, dcc, ddtc, dlac = pl.pallas_call(
        functools.partial(_ssd_bwd_kernel, chunk=L),
        grid=(Bt, H, nC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), rev5),
            pl.BlockSpec((1, 1, L, N), rev4),
            pl.BlockSpec((1, 1, L, N), rev4),
            pl.BlockSpec((1, 1, 1, L, 1), rev5),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1, 1, P, N), rev5),
            pl.BlockSpec((1, 1, 1, L, P), rev5),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), rev5),
            pl.BlockSpec((1, 1, 1, L, N), rev5),
            pl.BlockSpec((1, 1, 1, L, N), rev5),
            pl.BlockSpec((1, 1, 1, L, 1), rev5),
            pl.BlockSpec((1, 1, 1, L, 1), rev5),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, H, nC, L, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, nC, L, N), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, nC, L, N), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, nC, L, 1), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, nC, L, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xc, bc, cc, dtc, a2, chunk_states, dyc)

    Sp = nC * L
    dx = dxc.transpose(0, 2, 3, 1, 4).reshape(Bt, Sp, H, P)[:, :S]
    # B and C are broadcast across heads in the forward -> sum head grads
    dB = jnp.sum(dbc, axis=1).reshape(Bt, Sp, N)[:, :S]
    dC = jnp.sum(dcc, axis=1).reshape(Bt, Sp, N)[:, :S]
    ddt = ddtc[..., 0].transpose(0, 2, 3, 1).reshape(Bt, Sp, H)[:, :S]
    dla = dlac[..., 0].transpose(0, 2, 3, 1).reshape(Bt, Sp, H)[:, :S]
    dA = jnp.einsum("bsh,bsh->h", dt.astype(jnp.float32), dla)
    return (dx.astype(x.dtype), dB.astype(B.dtype), dC.astype(C.dtype),
            ddt.astype(dt.dtype), dA.astype(A.dtype))
