"""Associative SSM-scan Pallas kernel and its reference path."""
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

__all__ = ["ssm_scan", "ssm_scan_ref"]
