"""Autotune family registration for the SSD scan Pallas kernels.

Plugs the chunked Mamba2/SSD scan into :mod:`repro.kernels.autotune`.
The signature is (seq, heads, head_dim, state_dim) plus the optional
dtype qualifier, and the schedule is a :class:`ScanChunks` — the chunk
length of the intra/inter-chunk decomposition.  Chunk length trades the
O(L^2) intra-chunk matmul against the number of sequential carry steps,
so the winner is shape- and device-dependent; the measurement builder
times the full fwd+bwd because the backward sweeps the same chunk grid
in reverse.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.kernels import autotune as autotune_lib


@dataclasses.dataclass(frozen=True)
class ScanChunks:
    """Schedule for the SSD scan: the chunk (intra-chunk block) length.
    Clamped to the sequence length at trace time."""
    chunk: int = 128


def signature(seq: int, heads: int, head_dim: int, state_dim: int,
              dtype=None):
    """Hashable problem identity for one scan shape."""
    base = ("ssm", int(seq), int(heads), int(head_dim), int(state_dim))
    if dtype is None:
        return base
    return base + (autotune_lib.dtype_name(dtype),)


_SIG_LEN = 5


def default_chunks(sig) -> ScanChunks:
    """128 balances the L^2 intra-chunk work against carry steps on
    every shape the models hit; the wrapper clamps to the sequence."""
    return ScanChunks()


def candidate_chunks(sig) -> List[ScanChunks]:
    """The sweep space: power-of-two chunk lengths, deduplicated after
    clamping to the sequence length."""
    seq = sig[1]
    cands, seen = [], set()
    for chunk in (32, 64, 128, 256):
        eff = min(chunk, seq)
        if eff in seen:
            continue
        seen.add(eff)
        cands.append(ScanChunks(chunk=chunk))
    return cands


def _build_problem(sig):
    """Representative arrays + runner: one jitted fwd+bwd through the
    Pallas kernels per candidate (chunk is trace-time static)."""
    import jax
    import jax.numpy as jnp

    import importlib
    scan_mod = importlib.import_module("repro.kernels.ssm_scan.ssm_scan")

    _, S, H, P, N = sig[:_SIG_LEN]
    dtype = jnp.dtype(sig[_SIG_LEN]) if len(sig) > _SIG_LEN else jnp.float32
    keys = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(keys[0], (1, S, H, P), jnp.float32).astype(dtype)
    Bm = jax.random.normal(keys[1], (1, S, N), jnp.float32).astype(dtype)
    Cm = jax.random.normal(keys[2], (1, S, N), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(keys[3], (1, S, H), jnp.float32)).astype(dtype)
    A = -jnp.exp(jax.random.normal(keys[4], (H,), jnp.float32))
    dy = jax.random.normal(keys[0], (1, S, H, P), jnp.float32)
    interpret = autotune_lib.default_interpret()

    def make(chunks: ScanChunks):
        def fwd_bwd(x_, b_, c_, dt_, a_, dy_):
            y, _, si = scan_mod.ssm_scan(
                x_, b_, c_, dt_, a_, chunk=chunks.chunk,
                interpret=interpret, return_chunk_states=True)
            grads = scan_mod.ssm_scan_bwd(
                x_, b_, c_, dt_, a_, si, dy_, chunk=chunks.chunk,
                interpret=interpret)
            return y, grads
        return jax.jit(fwd_bwd)

    args = (x, Bm, Cm, dt, A, dy)

    def run(chunks: ScanChunks, steps: int = 3, repeats: int = 3) -> float:
        return autotune_lib.time_min_of_repeats(make(chunks), args, steps,
                                                repeats)

    return run


def model_signatures(cfg, seq_len: int, dtype=None) -> list:
    """The scan signatures one LM config hits at a given training
    sequence length (empty for configs without an SSM block)."""
    ssm = getattr(cfg, "ssm", None)
    if ssm is None:
        return []
    d_in = ssm.expand * cfg.d_model
    heads = d_in // ssm.head_dim
    return [signature(seq_len, heads, ssm.head_dim, ssm.state_dim, dtype)]


autotune_lib.register_kernel(autotune_lib.KernelSpec(
    family="ssm_scan",
    kinds=("ssm",),
    schedule_cls=ScanChunks,
    sig_len=_SIG_LEN,
    default=default_chunks,
    candidates=candidate_chunks,
    build=_build_problem,
))
