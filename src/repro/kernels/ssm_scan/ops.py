"""jit'd public wrapper for the SSD scan kernel.

Forward AND backward run the Pallas kernels: the forward saves the
per-chunk entry states as the recompute anchor, the backward sweeps the
chunk grid in reverse with a VMEM gradient-state carry — no ref-oracle
``jax.vjp`` detour, no materialised (S, S) attention-like matrix.

``chunk`` comes from the shared autotune registry
(:mod:`repro.kernels.autotune`) by problem signature when left ``None``,
so an offline ``tools/autotune_kernels.py`` run re-chunks both
directions here without touching call sites.  ``interpret=None``
freezes the device-kind default at trace time — compiled on TPU,
interpreter everywhere else.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import autotune as autotune_lib
from repro.kernels.ssm_scan import tune as tune_lib
from repro.kernels.ssm_scan.ssm_scan import (ssm_scan as _ssm_scan_fwd,
                                             ssm_scan_bwd as _ssm_scan_bwd)


def _chunk_for(x, B, chunk: Optional[int]) -> int:
    if chunk is not None:
        return chunk
    sig = tune_lib.signature(x.shape[1], x.shape[2], x.shape[3], B.shape[-1],
                             x.dtype)
    return autotune_lib.get_schedule(sig).chunk


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssm_scan(x, B, C, dt, A, chunk: Optional[int] = None,
             interpret: Optional[bool] = None):
    y, _ = _ssm_scan_fwd(x, B, C, dt, A, chunk=_chunk_for(x, B, chunk),
                         interpret=autotune_lib.resolve_interpret(interpret))
    return y


def _fwd(x, B, C, dt, A, chunk, interpret):
    y, _, si = _ssm_scan_fwd(
        x, B, C, dt, A, chunk=_chunk_for(x, B, chunk),
        interpret=autotune_lib.resolve_interpret(interpret),
        return_chunk_states=True)
    return y, (x, B, C, dt, A, si)


def _bwd(chunk, interpret, res, g):
    x, B, C, dt, A, si = res
    return _ssm_scan_bwd(
        x, B, C, dt, A, si, g, chunk=_chunk_for(x, B, chunk),
        interpret=autotune_lib.resolve_interpret(interpret))


ssm_scan.defvjp(_fwd, _bwd)
