"""jit'd public wrapper for the SSD scan kernel (ref-backed backward)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan as _ssm_scan_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssm_scan(x, B, C, dt, A, chunk: int = 128, interpret: bool = True):
    y, _ = _ssm_scan_fwd(x, B, C, dt, A, chunk=chunk, interpret=interpret)
    return y


def _fwd(x, B, C, dt, A, chunk, interpret):
    y, _ = _ssm_scan_fwd(x, B, C, dt, A, chunk=chunk, interpret=interpret)
    return y, (x, B, C, dt, A)


def _bwd(chunk, interpret, res, g):
    x, B, C, dt, A = res
    _, vjp = jax.vjp(
        lambda x_, B_, C_, dt_, A_: ssm_scan_ref(x_, B_, C_, dt_, A_)[0],
        x, B, C, dt, A)
    return vjp(g)


ssm_scan.defvjp(_fwd, _bwd)
