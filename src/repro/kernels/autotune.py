"""Kernel-agnostic autotune substrate shared by every Pallas kernel family.

``kernels/conv3d/tiles.py`` grew the full treatment — measured candidate
sweeps, an in-memory registry, a persistent on-disk cache keyed by
(signature, dtype, device kind) — but all of it was welded to conv tile
configs.  This module is that machinery with the conv specifics factored
out, so flash-attention block sizes and SSD scan chunk lengths tune
through the SAME registry, cache files, and measurement clock.

A kernel family plugs in by registering a :class:`KernelSpec`:

- ``kinds`` — the signature kind-tags the family owns (conv3d owns
  ``conv``/``conv_t``/``dw``/``dw_t``; attention owns ``attn``; the SSD
  scan owns ``ssm``).
- ``schedule_cls`` — a frozen dataclass of schedule parameters
  (``ConvTiles``, ``AttnBlocks``, ``ScanChunks``); its fields are what
  the JSON cache stores.
- ``default`` / ``candidates`` — the shape heuristic and the sweep space.
- ``build`` — constructs representative arrays + a timed runner for a
  signature, used by :func:`autotune_signature`.

Resolution order everywhere: exact in-memory registration, then the
dtype-free base signature, then the on-disk cache for the current device
(warm-loaded once per process), then the family's heuristic default.
The cache file format is unchanged from the conv3d-only era — existing
``results/autotune/<device_kind>.json`` entries keep loading bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

_HERE = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_CACHE_DIR = os.path.join(_HERE, "results", "autotune")

Signature = Tuple  # (kind, *shape-fields[, dtype-name])


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """How one kernel family participates in the shared autotuner.

    ``sig_len`` counts the signature fields BEFORE the optional trailing
    dtype name, so dtype-qualified lookups can fall back to their base.
    ``build(sig)`` returns ``run(schedule, steps=, repeats=) -> seconds``
    over representative arrays; it is only called by the measurement
    driver, never on the inference path.  ``parse`` may override the
    generic string→signature decoder for exotic key layouts.
    """
    family: str
    kinds: Tuple[str, ...]
    schedule_cls: type
    sig_len: int
    default: Callable[[Signature], object]
    candidates: Callable[[Signature], List[object]]
    build: Optional[Callable[[Signature], Callable]] = None
    parse: Optional[Callable[[List[str]], Optional[Signature]]] = None


_FAMILIES: Dict[str, KernelSpec] = {}
_KIND_TO_FAMILY: Dict[str, str] = {}
_REGISTRY: Dict[Signature, object] = {}
_CACHE_LOADED: set = set()      # device kinds whose disk cache was merged


def register_kernel(spec: KernelSpec) -> None:
    """Idempotently install a family's spec (latest registration wins)."""
    _FAMILIES[spec.family] = spec
    for kind in spec.kinds:
        _KIND_TO_FAMILY[kind] = spec.family


def _ensure_families() -> None:
    """Import every in-tree kernel family's tune module.

    Cache loading parses keys by their kind tag, and the warm-load flag is
    per-device-kind, not per-family — if only one family were imported
    when the cache loads, the other families' entries would be silently
    dropped for the rest of the process.  Lazy (and import-error-tolerant:
    a family with a missing optional dep just doesn't join the registry).
    """
    import importlib
    for mod in ("repro.kernels.conv3d.tiles",
                "repro.kernels.flash_attention.tune",
                "repro.kernels.flash_attention.decode",
                "repro.kernels.ssm_scan.tune"):
        try:
            importlib.import_module(mod)
        except ImportError:
            pass


def spec_for(sig: Signature) -> KernelSpec:
    _ensure_families()
    family = _KIND_TO_FAMILY.get(sig[0])
    if family is None:
        raise KeyError(f"no kernel family registered for kind {sig[0]!r} "
                       f"(known: {sorted(_KIND_TO_FAMILY)})")
    return _FAMILIES[family]


def dtype_name(dtype) -> str:
    return getattr(dtype, "name", None) or getattr(dtype, "__name__", None) \
        or str(dtype)


def register_schedule(sig: Signature, schedule) -> None:
    _REGISTRY[sig] = schedule


def clear_registry() -> None:
    _REGISTRY.clear()
    _CACHE_LOADED.clear()


def _base_sig(sig: Signature, spec: KernelSpec) -> Optional[Signature]:
    return sig[:spec.sig_len] if len(sig) == spec.sig_len + 1 else None


def get_schedule(sig: Signature):
    """Registered schedule if present, else the family heuristic.

    Resolution: exact in-memory registration (a dtype-qualified signature
    falls back to its dtype-free base, so hand-registered entries keep
    working), then the on-disk autotune cache for the current device
    (warm-loaded once per process), then the family's ``default``.
    """
    hit = _REGISTRY.get(sig)
    if hit is not None:
        return hit
    spec = spec_for(sig)
    base = _base_sig(sig, spec)
    if base is not None:
        hit = _REGISTRY.get(base)
        if hit is not None:
            return hit
    kind = _device_kind()
    if kind not in _CACHE_LOADED:
        load_cache(kind=kind)
        hit = _REGISTRY.get(sig) or (
            _REGISTRY.get(base) if base is not None else None)
        if hit is not None:
            return hit
    return spec.default(sig)


def default_schedule(sig: Signature):
    return spec_for(sig).default(sig)


def candidate_schedules(sig: Signature) -> List:
    return spec_for(sig).candidates(sig)


def autotune(sig: Signature, measure: Callable[[object], float],
             candidates: Optional[Iterable] = None):
    """Measure ``candidates`` (seconds, lower is better), register the best.

    ``measure`` runs the kernel with a given schedule and returns its
    cost; the driver below passes timed executions, tests pass analytic
    stand-ins.
    """
    if candidates is None:
        candidates = candidate_schedules(sig)
    best, best_cost = None, float("inf")
    for cand in candidates:
        cost = measure(cand)
        if cost < best_cost:
            best, best_cost = cand, cost
    assert best is not None, "autotune needs at least one candidate"
    register_schedule(sig, best)
    return best


# ---------------------------------------------------------------------------
# measurement driver: time candidates on the live device
# ---------------------------------------------------------------------------


def time_min_of_repeats(fn, args, steps: int = 3, repeats: int = 3) -> float:
    """Seconds per execution of ``fn(*args)``: warmup + min over
    ``repeats`` timed batches of ``steps`` calls.  The min is the
    least-contended execution — robust to scheduler noise on shared
    hosts.  Shared by the autotune driver and the kernel benchmarks so
    winners and recorded numbers come from the same clock."""
    import jax
    out = fn(*args)                       # compile + warmup
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(steps):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / steps)
    return best


def _device_kind() -> str:
    import jax
    try:
        return jax.devices()[0].device_kind.replace(" ", "_")
    except Exception:                     # no backend yet — be permissive
        return "unknown"


def autotune_signature(sig: Signature, *, steps: int = 3,
                       cache_dir: Optional[str] = None,
                       use_cache: bool = True) -> Tuple[object, int]:
    """Tune one signature on the live device.

    Returns ``(best, n_measured)`` — ``n_measured == 0`` when the on-disk
    cache already held an entry (the warm-start the CLI asserts on).
    Winners are registered in-memory AND persisted.
    """
    spec = spec_for(sig)
    if use_cache:
        load_cache(cache_dir=cache_dir)
        if sig in _REGISTRY:
            return _REGISTRY[sig], 0
    if spec.build is None:
        raise ValueError(f"family {spec.family!r} has no measurement "
                         "builder; pass schedules via register_schedule")
    run = spec.build(sig)
    measured = [0]

    def measure(schedule) -> float:
        measured[0] += 1
        return run(schedule, steps=steps)

    best = autotune(sig, measure)
    save_cache(cache_dir=cache_dir)
    return best, measured[0]


# ---------------------------------------------------------------------------
# trace-time interpret default (shared by every kernel's public wrapper)
# ---------------------------------------------------------------------------


def default_interpret() -> bool:
    """Pallas ``interpret`` default: emulate everywhere except real TPUs.

    ``REPRO_PALLAS_INTERPRET`` overrides (unset/empty = auto; ``0`` /
    ``false`` / ``no`` force compiled, anything else forces interpret).
    Resolved at trace time, so a wrapper default of ``None`` freezes the
    decision into the jaxpr exactly once per trace.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
    if env:
        return env.lower() not in ("0", "false", "no")
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def default_use_pallas(env_var: str) -> bool:
    """Launcher-level kernel-routing default: ON on real TPUs, OFF
    elsewhere, overridable per flag family via its env var (``1`` /
    ``true`` / ``yes`` / ``on`` force on; ``0`` / ``false`` / ``no`` /
    ``off`` force off).  Resolved once at launcher startup and frozen
    into the ArchConfig, so the routing decision is trace-time static
    like every other config field.
    """
    env = os.environ.get(env_var, "").lower()
    if env in ("1", "true", "yes", "on"):
        return True
    if env in ("0", "false", "no", "off"):
        return False
    import jax
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# on-disk persistence (results/autotune/<device_kind>.json)
# ---------------------------------------------------------------------------


def cache_path(kind: Optional[str] = None,
               cache_dir: Optional[str] = None) -> str:
    env_dir = os.environ.get("REPRO_AUTOTUNE_DIR", "")
    base = cache_dir or env_dir or DEFAULT_CACHE_DIR
    return os.path.join(base, f"{kind or _device_kind()}.json")


def _sig_to_str(sig: Signature) -> str:
    parts = []
    for field in sig:
        if isinstance(field, tuple):
            parts.append("x".join(str(int(d)) for d in field))
        else:
            parts.append(str(field))
    return "|".join(parts)


def _generic_parse(spec: KernelSpec, parts: List[str]) -> Optional[Signature]:
    """Decode ``kind|field|...[|dtype]``: ints stay ints, ``x``-joined
    runs become tuples, a trailing non-numeric field is the dtype name."""
    if len(parts) not in (spec.sig_len, spec.sig_len + 1):
        return None
    sig: list = [parts[0]]
    try:
        for p in parts[1:spec.sig_len]:
            if "x" in p:
                sig.append(tuple(int(d) for d in p.split("x")))
            else:
                sig.append(int(p))
    except ValueError:                    # hand-edited/truncated key
        return None
    if len(parts) == spec.sig_len + 1:
        sig.append(parts[-1])
    return tuple(sig)


def _sig_from_str(s: str) -> Optional[Signature]:
    parts = s.split("|")
    if not parts:
        return None
    _ensure_families()
    family = _KIND_TO_FAMILY.get(parts[0])
    if family is None:
        return None
    spec = _FAMILIES[family]
    if spec.parse is not None:
        return spec.parse(parts)
    return _generic_parse(spec, parts)


def save_cache(kind: Optional[str] = None,
               cache_dir: Optional[str] = None) -> str:
    """Persist the in-memory registry for this device kind (merging over
    whatever the file already holds, so concurrent tuners compose)."""
    path = cache_path(kind, cache_dir)
    entries = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                entries = json.load(f).get("tiles", {})
        except (json.JSONDecodeError, OSError,
                AttributeError, TypeError):
            entries = {}                  # corrupt cache: overwrite
        if not isinstance(entries, dict):
            entries = {}                  # e.g. {"tiles": 0}
    for sig, schedule in _REGISTRY.items():
        entries[_sig_to_str(sig)] = dataclasses.asdict(schedule)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {"device_kind": kind or _device_kind(),
               "version": 1, "tiles": entries}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def load_cache(kind: Optional[str] = None,
               cache_dir: Optional[str] = None) -> int:
    """Merge the on-disk cache into the registry (in-memory entries win).

    A missing, corrupt, or shape-mismatched file is NOT an error — the
    kernels must never fail because a cache went stale; they fall back to
    the family default.  Keys whose kind tag no family claims are skipped
    (a cache written by a newer tree stays loadable).  Returns the number
    of entries merged.
    """
    _ensure_families()
    kind = kind or _device_kind()
    if cache_dir is None:
        # only a DEFAULT-location load satisfies get_schedule's warm-load;
        # an explicit scratch cache_dir must not suppress it
        _CACHE_LOADED.add(kind)
    path = cache_path(kind, cache_dir)
    if not os.path.exists(path):
        return 0
    try:
        with open(path) as f:
            payload = json.load(f)
        entries = payload["tiles"]
        assert isinstance(entries, dict)
    except (json.JSONDecodeError, OSError, KeyError,
            AssertionError, TypeError):
        return 0                          # corrupt cache -> heuristic
    n = 0
    for key, val in entries.items():
        sig = _sig_from_str(key)
        if sig is None or not isinstance(val, dict):
            continue
        spec = _FAMILIES[_KIND_TO_FAMILY[sig[0]]]
        known = {f.name for f in dataclasses.fields(spec.schedule_cls)}
        try:
            schedule = spec.schedule_cls(
                **{k: v for k, v in val.items() if k in known})
        except TypeError:
            continue
        if sig not in _REGISTRY:          # in-memory registrations win
            _REGISTRY[sig] = schedule
            n += 1
    return n


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
