"""Tile-size selection + autotuner for the conv3d Pallas kernels.

The fused implicit-GEMM kernels tile the output-channel (N of the GEMM)
dimension and choose a tap schedule; the standalone ``gemm`` tiles all
three of (bm, bk, bn).  Which config wins depends on the problem shape:
the 3DGAN layers range from Ci=1 (discriminator input) to Ci=Co=128
(MXU-native), and the spatial row length OH*OW ranges from 25 to 2601 —
a single hard-coded 128 is right for the big layers and wasteful for the
small ones, and for tiny Ci the per-tap (P, Ci) x (Ci, bn) contractions
are so thin that gathering ALL taps into one wide GEMM
(``fuse_taps=True``) wins outright.

The generic registry/cache/measurement machinery that used to live here
moved to :mod:`repro.kernels.autotune` so flash-attention and the SSD
scan tune through the same substrate; this module keeps the conv
specifics (signature layout, ``ConvTiles``, the candidate sweep, the
problem builder, the GAN-config signature enumerator) and re-exports the
shared API under its historical names:

- :func:`get_tiles` / :func:`register_tiles` — registry lookup / pin.
- :func:`autotune` / :func:`autotune_signature` / :func:`autotune_config`
  — the measurement drivers.
- :func:`load_cache` / :func:`save_cache` — on-disk JSON persistence
  under ``results/autotune/``, keyed by (signature, dtype, device kind).
  ``get_tiles`` warm-loads the cache for the current device on first use,
  so an offline ``tools/autotune_conv3d.py`` run changes kernel behaviour
  in every later process without touching call sites.

Registered entries take priority over the heuristic, and in-memory
registrations take priority over the disk cache.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.kernels import autotune as autotune_lib
from repro.kernels.autotune import (   # noqa: F401  (historical API)
    DEFAULT_CACHE_DIR, Signature, _device_kind, _round_up, _sig_from_str,
    _sig_to_str, cache_path, clear_registry, dtype_name as _dtype_name,
    load_cache, save_cache, time_min_of_repeats,
)

# the registry and warm-load set are the SAME objects as the shared
# substrate's — conv, attention, and ssm schedules live in one table
_REGISTRY = autotune_lib._REGISTRY
_CACHE_LOADED = autotune_lib._CACHE_LOADED


@dataclasses.dataclass(frozen=True)
class ConvTiles:
    """Tile config for the fused conv kernels.

    ``bn``   — output-channel (GEMM N) tile, MXU lane dimension.
    ``bm``/``bk`` — row/contraction tiles; used by the standalone
    :func:`repro.kernels.conv3d.conv3d.gemm`.  The fused conv kernels tile
    rows structurally (one padded-input slab per (n, od) grid row), so for
    them ``bn`` and ``fuse_taps`` are the load-bearing fields.
    ``fuse_taps`` — gather every (kh, kw) tap into one
    (OH*OW, KH*KW*Ci) matrix per kd step and contract it in a SINGLE
    wide GEMM instead of KH*KW thin ones.  Wins when Ci is small (the
    thin contractions waste the MXU's K dimension); loses when the
    concatenated patch matrix outgrows VMEM-friendly sizes.
    """
    bn: int = 128
    bm: int = 128
    bk: int = 128
    fuse_taps: bool = False


def signature(kind: str, spatial: Sequence[int], ci: int, co: int,
              k: int, stride: int, dtype=None) -> Signature:
    """Hashable problem identity: kernel kind + the shape that drives
    tiling.  ``dtype`` (e.g. ``jnp.bfloat16`` or ``"bfloat16"``) joins the
    key when given — bf16 and f32 tune independently."""
    base = (kind, tuple(int(s) for s in spatial), int(ci), int(co),
            int(k), int(stride))
    if dtype is None:
        return base
    return base + (_dtype_name(dtype),)


def register_tiles(sig: Signature, tiles: ConvTiles) -> None:
    autotune_lib.register_schedule(sig, tiles)


def default_tiles(sig: Signature) -> ConvTiles:
    """Shape heuristic: MXU-native 128, shrunk when the problem is smaller.

    Tiles never exceed the (padded) problem extent — a 128-lane tile over
    Co=8 would spend 94% of the MXU on padding.
    """
    co = sig[3]
    bn = min(128, _round_up(co, 8))
    return ConvTiles(bn=bn)


def get_tiles(sig: Signature) -> ConvTiles:
    """Registered config if present, else the heuristic default.

    Resolution order: exact in-memory registration (a dtype-qualified
    signature falls back to its dtype-free base, so hand-registered
    entries keep working), then the on-disk autotune cache for the
    current device (warm-loaded once per process), then the heuristic.
    """
    return autotune_lib.get_schedule(sig)


def autotune(sig: Signature, measure: Callable[[ConvTiles], float],
             candidates: Optional[Iterable[ConvTiles]] = None) -> ConvTiles:
    """Measure ``candidates`` (seconds, lower is better), register the best.

    ``measure`` runs the kernel with a given config and returns its cost;
    the driver passes timed executions, tests pass analytic stand-ins.
    """
    return autotune_lib.autotune(sig, measure, candidates)


def candidate_tiles(sig: Signature) -> List[ConvTiles]:
    """The sweep space for one signature: the heuristic default plus
    bn variants and the fused-tap schedule (deduplicated after clamping
    bn to the problem's Co, so tiny layers don't measure aliases)."""
    co = sig[3]
    cands, seen = [], set()
    for fuse in (False, True):
        # max(co, 1) = exact-Co tile (zero weight padding): usually wrong
        # for the 128-lane MXU, sometimes right for narrow layers — the
        # measurement decides, not the heuristic
        for bn in (default_tiles(sig).bn, max(co, 1), 32, 64, 128, 256):
            eff = (min(bn, max(co, 1)), fuse)
            if eff in seen:
                continue
            seen.add(eff)
            cands.append(ConvTiles(bn=bn, fuse_taps=fuse))
    return cands


# ---------------------------------------------------------------------------
# measurement problem builder (the conv half of the shared driver)
# ---------------------------------------------------------------------------


def _build_problem(sig: Signature):
    """Representative arrays + runner for the conv problem ``sig`` names.

    Handles all four kernel kinds — ``conv`` / ``conv_t`` (the forward
    family, which the dx routes also reduce to) and ``dw`` / ``dw_t``
    (the patches^T @ grad backward kernel).  Returns
    ``run(tiles) -> float`` timing one jitted execution (a fresh jit per
    tile config — the config is trace-time static).
    """
    import importlib

    import jax
    import jax.numpy as jnp

    # the package __init__ re-exports a FUNCTION named conv3d, which
    # shadows the submodule in a from-import — resolve the module itself
    conv3d_lib = importlib.import_module("repro.kernels.conv3d.conv3d")

    kind, spatial, ci, co, k, stride = sig[:6]
    dtype = jnp.dtype(sig[6]) if len(sig) == 7 else jnp.float32
    key = jax.random.key(0)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (2, *spatial, ci), jnp.float32).astype(dtype)

    if kind in ("dw", "dw_t"):
        if kind == "dw":
            pads = tuple(conv3d_lib._same_pads(L, k, stride)[:2]
                         for L in spatial)
            out_dims = tuple(-(-L // stride) for L in spatial)
            core_stride, dil = stride, 1
        else:
            pads = tuple(conv3d_lib._transpose_pads(k, stride)
                         for _ in spatial)
            out_dims = tuple(L * stride for L in spatial)
            core_stride, dil = 1, stride
        g = jax.random.normal(kw, (2, *out_dims, co), jnp.float32) \
            .astype(dtype)

        def make(tiles: ConvTiles):
            return jax.jit(lambda x_, g_: conv3d_lib._conv_dw_core(
                x_, g_, (k, k, k), stride=core_stride, pads=pads,
                in_dilation=dil, tile_cfg=tiles))

        args = (x, g)
    else:
        w = (jax.random.normal(kw, (k, k, k, ci, co), jnp.float32) * 0.1) \
            .astype(dtype)
        b = jnp.zeros((co,), dtype)

        def make(tiles: ConvTiles):
            if kind == "conv_t":
                pads = tuple(conv3d_lib._transpose_pads(kk, stride)
                             for kk in w.shape[:3])
                return jax.jit(lambda x_, w_, b_: conv3d_lib._conv_core(
                    x_, w_, b_, stride=1, pads=pads, in_dilation=stride,
                    tile_cfg=tiles))
            pads = tuple(conv3d_lib._same_pads(L, kk, stride)[:2]
                         for L, kk in zip(spatial, w.shape[:3]))
            return jax.jit(lambda x_, w_, b_: conv3d_lib._conv_core(
                x_, w_, b_, stride=stride, pads=pads, tile_cfg=tiles))

        args = (x, w, b)

    def run(tiles: ConvTiles, steps: int = 3, repeats: int = 3) -> float:
        return time_min_of_repeats(make(tiles), args, steps, repeats)

    return run


def autotune_signature(sig: Signature, *, steps: int = 3,
                       cache_dir: Optional[str] = None,
                       use_cache: bool = True) -> Tuple[ConvTiles, int]:
    """Tune one signature on the live device.

    Returns ``(best, n_measured)`` — ``n_measured == 0`` when the on-disk
    cache already held an entry (the warm-start the CLI asserts on).
    Winners are registered in-memory AND persisted.
    """
    return autotune_lib.autotune_signature(sig, steps=steps,
                                           cache_dir=cache_dir,
                                           use_cache=use_cache)


def _bwd_signatures(kind: str, spatial, ci: int, co: int, k: int,
                    stride: int, dtype) -> List[Signature]:
    """The dx/dw signatures one forward layer's backward pass hits, as
    the kernel drivers will look them up at trace time."""
    if kind == "conv_t":
        # dx of a transposed conv = a stride-s conv of the cotangent
        out = tuple(d * stride for d in spatial)
        return [signature("conv", out, co, ci, k, stride, dtype),
                signature("dw_t", spatial, ci, co, k, stride, dtype)]
    out = tuple(-(-d // stride) for d in spatial)
    dx_kind = "conv" if stride == 1 else "conv_t"
    return [signature(dx_kind, out, co, ci, k, stride if stride > 1 else 1,
                      dtype),
            signature("dw", spatial, ci, co, k, stride, dtype)]


def gan_signatures(cfg, dtype=None, train: bool = False) -> List[Signature]:
    """Every conv signature the 3DGAN hot path hits for ``cfg`` — the
    generator's transposed convs + output conv and the discriminator's
    strided convs (matching `core/gan` layer geometry).  ``train=True``
    appends each layer's backward (dx / dw) signatures, so the tuned
    tiles cover the full fwd+bwd adversarial step."""
    fwd: List[tuple] = []
    ups = len(cfg.gen_channels) - 1
    dims = tuple(-(-d // 2 ** ups) for d in cfg.image_shape)
    for i in range(ups):
        fwd.append(("conv_t", dims, cfg.gen_channels[i],
                    cfg.gen_channels[i + 1], 3, 2))
        dims = tuple(d * 2 for d in dims)
    fwd.append(("conv", cfg.image_shape, cfg.gen_channels[-1], 1, 3, 1))
    dims, ci = cfg.image_shape, 1
    for c in cfg.disc_channels:
        fwd.append(("conv", dims, ci, c, 3, 2))
        dims = tuple(-(-d // 2) for d in dims)
        ci = c
    sigs = [signature(*spec, dtype) for spec in fwd]
    if train:
        for spec in fwd:
            sigs += _bwd_signatures(*spec, dtype)
    seen, uniq = set(), []
    for s in sigs:
        if s not in seen:
            seen.add(s)
            uniq.append(s)
    return uniq


def autotune_config(cfg, dtype=None, *, steps: int = 3,
                    cache_dir: Optional[str] = None,
                    use_cache: bool = True, train: bool = False) -> dict:
    """Tune every GAN layer signature for ``cfg`` (``train=True`` adds the
    backward dx/dw signatures); returns a report dict with per-signature
    winners and the measurement count (zero on a fully warm cache — the
    CLI's second-run assertion)."""
    report = {"device_kind": _device_kind(), "measured": 0, "cached": 0,
              "entries": []}
    for sig in gan_signatures(cfg, dtype, train=train):
        best, n = autotune_signature(sig, steps=steps, cache_dir=cache_dir,
                                     use_cache=use_cache)
        report["measured"] += n
        report["cached"] += int(n == 0)
        report["entries"].append({"signature": _sig_to_str(sig),
                                  "tiles": dataclasses.asdict(best),
                                  "measurements": n})
    return report


autotune_lib.register_kernel(autotune_lib.KernelSpec(
    family="conv3d",
    kinds=("conv", "conv_t", "dw", "dw_t"),
    schedule_cls=ConvTiles,
    sig_len=6,
    default=default_tiles,
    candidates=candidate_tiles,
    build=_build_problem,
))
