"""Tile-size selection for the conv3d Pallas kernels.

The fused implicit-GEMM kernels tile the output-channel (N of the GEMM)
dimension and, for the standalone ``gemm``, all three of (bm, bk, bn).
Which tile wins depends on the problem shape: the 3DGAN layers range from
Ci=1 (discriminator input) to Ci=Co=128 (MXU-native), and the spatial row
length OH*OW ranges from 25 to 2601 — a single hard-coded 128 is right for
the big layers and wasteful for the small ones.

This module is the one place that decision lives:

- :func:`get_tiles` — registry lookup by problem signature, falling back
  to a shape heuristic (MXU-native 128 lanes, shrunk to the padded problem).
- :func:`register_tiles` — pin a tile config for a signature (what a
  sweep on the real TPU target would persist).
- :func:`autotune` — the hook such a sweep plugs into: measure a callable
  over candidate configs and register the argmin.

Registered entries take priority, so an offline autotune run changes
kernel behaviour without touching call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ConvTiles:
    """Tile config for the fused conv kernels.

    ``bn``   — output-channel (GEMM N) tile, MXU lane dimension.
    ``bm``/``bk`` — row/contraction tiles; used by the standalone
    :func:`repro.kernels.conv3d.conv3d.gemm`.  The fused conv kernels tile
    rows structurally (one padded-input slab per (n, od) grid row), so for
    them only ``bn`` is load-bearing.
    """
    bn: int = 128
    bm: int = 128
    bk: int = 128


Signature = Tuple  # (kind, spatial..., Ci, Co, K, stride) — see signature()

_REGISTRY: Dict[Signature, ConvTiles] = {}


def signature(kind: str, spatial: Sequence[int], ci: int, co: int,
              k: int, stride: int) -> Signature:
    """Hashable problem identity: kernel kind + the shape that drives tiling."""
    return (kind, tuple(int(s) for s in spatial), int(ci), int(co),
            int(k), int(stride))


def register_tiles(sig: Signature, tiles: ConvTiles) -> None:
    _REGISTRY[sig] = tiles


def clear_registry() -> None:
    _REGISTRY.clear()


def default_tiles(sig: Signature) -> ConvTiles:
    """Shape heuristic: MXU-native 128, shrunk when the problem is smaller.

    Tiles never exceed the (padded) problem extent — a 128-lane tile over
    Co=8 would spend 94% of the MXU on padding.
    """
    _kind, _spatial, _ci, co, _k, _stride = sig
    bn = min(128, _round_up(co, 8))
    return ConvTiles(bn=bn)


def get_tiles(sig: Signature) -> ConvTiles:
    """Registered config if present, else the heuristic default."""
    return _REGISTRY.get(sig, default_tiles(sig))


def autotune(sig: Signature, measure: Callable[[ConvTiles], float],
             candidates: Optional[Iterable[ConvTiles]] = None) -> ConvTiles:
    """Measure ``candidates`` (seconds, lower is better), register the best.

    ``measure`` runs the kernel with a given config and returns its cost;
    a TPU sweep passes timed executions, tests pass analytic stand-ins.
    """
    if candidates is None:
        candidates = [ConvTiles(bn=bn) for bn in (32, 64, 128, 256)]
    best, best_cost = None, float("inf")
    for cand in candidates:
        cost = measure(cand)
        if cost < best_cost:
            best, best_cost = cand, cost
    assert best is not None, "autotune needs at least one candidate"
    register_tiles(sig, best)
    return best


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
