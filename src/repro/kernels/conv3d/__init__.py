from repro.kernels.conv3d.ops import conv3d, conv3d_transpose
from repro.kernels.conv3d.ref import conv3d_ref, conv3d_transpose_ref
from repro.kernels.conv3d.conv3d import gemm

__all__ = ["conv3d", "conv3d_transpose", "conv3d_ref", "conv3d_transpose_ref", "gemm"]
