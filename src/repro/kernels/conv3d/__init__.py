"""Fused implicit-GEMM Pallas conv3d family (fwd + bwd) — the 3DGAN hot
path — with the `lax.conv` reference implementations and tile registry."""
from repro.kernels.conv3d.conv3d import default_interpret, gemm
from repro.kernels.conv3d.ops import (ACTIVATIONS, conv3d, conv3d_bias_act,
                                      conv3d_transpose,
                                      conv3d_transpose_bias_act)
from repro.kernels.conv3d.ref import (conv3d_bias_act_ref, conv3d_ref,
                                      conv3d_transpose_bias_act_ref,
                                      conv3d_transpose_ref)
from repro.kernels.conv3d.tiles import (ConvTiles, autotune,
                                        autotune_config, autotune_signature,
                                        get_tiles, load_cache,
                                        register_tiles, save_cache,
                                        signature)

__all__ = [
    "ACTIVATIONS", "ConvTiles", "autotune", "autotune_config",
    "autotune_signature", "conv3d", "conv3d_bias_act",
    "conv3d_bias_act_ref", "conv3d_ref", "conv3d_transpose",
    "conv3d_transpose_bias_act", "conv3d_transpose_bias_act_ref",
    "conv3d_transpose_ref", "default_interpret", "gemm", "get_tiles",
    "load_cache", "register_tiles", "save_cache", "signature",
]
