"""Pure-jnp oracle for the conv3d kernel: lax.conv in NDHWC/DHWIO layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

DN = ("NDHWC", "DHWIO", "NDHWC")


def conv3d_ref(x, w, stride: int = 1, padding: str = "SAME"):
    """x: (N, D, H, W, Ci); w: (KD, KH, KW, Ci, Co)."""
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride,) * 3, padding, dimension_numbers=DN)


def conv3d_transpose_ref(x, w, stride: int = 2):
    """SAME-padded stride-s transposed conv (the 3DGAN generator op)."""
    return jax.lax.conv_transpose(
        x, w.astype(x.dtype), (stride,) * 3, "SAME", dimension_numbers=DN)
