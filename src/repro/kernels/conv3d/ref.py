"""Pure-jnp oracle for the conv3d kernels: lax.conv in NDHWC/DHWIO layout,
plus unfused bias/activation compositions mirroring the fused epilogue."""
from __future__ import annotations

import jax
import jax.numpy as jnp

DN = ("NDHWC", "DHWIO", "NDHWC")


def conv3d_ref(x, w, stride: int = 1, padding: str = "SAME"):
    """x: (N, D, H, W, Ci); w: (KD, KH, KW, Ci, Co)."""
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride,) * 3, padding, dimension_numbers=DN)


def conv3d_transpose_ref(x, w, stride: int = 2):
    """SAME-padded stride-s transposed conv (the 3DGAN generator op)."""
    return jax.lax.conv_transpose(
        x, w.astype(x.dtype), (stride,) * 3, "SAME", dimension_numbers=DN)


def _act_ref(y, activation: str, slope: float):
    if activation == "leaky_relu":
        return jax.nn.leaky_relu(y, slope)
    if activation == "softplus":
        return jax.nn.softplus(y)
    assert activation == "none", activation
    return y


def conv3d_bias_act_ref(x, w, b, stride: int = 1, activation: str = "none",
                        slope: float = 0.2):
    """Unfused conv + bias + activation — oracle for the fused epilogue."""
    return _act_ref(conv3d_ref(x, w, stride) + b.astype(x.dtype),
                    activation, slope)


def conv3d_transpose_bias_act_ref(x, w, b, stride: int = 2,
                                  activation: str = "none",
                                  slope: float = 0.2):
    return _act_ref(conv3d_transpose_ref(x, w, stride) + b.astype(x.dtype),
                    activation, slope)
