"""Pallas TPU conv3d as implicit GEMM — the 3DGAN hot-spot.

TPU adaptation of the paper's 3-D convolutions (the GAN's compute bottleneck
on V100s):  a CUDA direct conv relies on per-thread scalar accumulation;
the TPU version reformulates each conv as a GEMM over gathered patches so
the MXU's 128x128 systolic array does the work:

    out[p, co] = sum_k patches[p, k] * w2[k, co]
    p = (n, od, oh, ow) output position,  k = (kd, kh, kw, ci) tap

- Patch gathering (the "im2col" staging) happens in jnp at trace time by
  stacking KD*KH*KW shifted, stride-sampled views of the padded input —
  XLA fuses those slices; the GEMM itself is the Pallas kernel below with
  (bm, bk, bn) VMEM tiles and an f32 accumulator carried across the
  sequential k grid dimension.
- Transposed conv (generator upsampling) = input dilation + spatially
  flipped weights + the same stride-1 path, so BOTH GAN networks hit the
  same GEMM kernel.
- Tile sizes default to the MXU-native 128; m/k/n are padded up to tile
  multiples (the roofline counts real FLOPs; padding waste shows up in the
  MODEL_FLOPS / HLO_FLOPs ratio tracked in EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm(x, w, *, bm: int = 128, bk: int = 128, bn: int = 128,
         interpret: bool = True, out_dtype=None):
    """Tiled MXU matmul: (M, K) @ (K, N) with f32 accumulation."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    out_dtype = out_dtype or x.dtype
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    gm, gk, gn = -(-M // bm), -(-K // bk), -(-N // bn)
    xp = jnp.pad(x, ((0, gm * bm - M), (0, gk * bk - K)))
    wp = jnp.pad(w, ((0, gk * bk - K), (0, gn * bn - N)))
    out = pl.pallas_call(
        _gemm_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:M, :N]


def _same_pads(size: int, k: int, stride: int):
    """TF-style SAME padding for one spatial dim."""
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return pad // 2, pad - pad // 2, out


def conv3d_gemm(x, w, stride: int = 1, *, interpret: bool = True,
                bm: int = 128, bn: int = 128):
    """SAME conv via implicit GEMM.  x: (N,D,H,W,Ci); w: (KD,KH,KW,Ci,Co)."""
    N, D, H, W, Ci = x.shape
    KD, KH, KW, _, Co = w.shape
    (pd0, pd1, OD) = _same_pads(D, KD, stride)
    (ph0, ph1, OH) = _same_pads(H, KH, stride)
    (pw0, pw1, OW) = _same_pads(W, KW, stride)
    xp = jnp.pad(x, ((0, 0), (pd0, pd1), (ph0, ph1), (pw0, pw1), (0, 0)))

    # implicit-GEMM patch matrix: KD*KH*KW stride-sampled shifted views
    cols = []
    for kd in range(KD):
        for kh in range(KH):
            for kw in range(KW):
                sl = xp[:, kd:kd + (OD - 1) * stride + 1:stride,
                        kh:kh + (OH - 1) * stride + 1:stride,
                        kw:kw + (OW - 1) * stride + 1:stride, :]
                cols.append(sl.reshape(N * OD * OH * OW, Ci))
    patches = jnp.concatenate(cols, axis=-1)          # (P, KD*KH*KW*Ci)
    w2 = w.reshape(KD * KH * KW * Ci, Co)
    out = gemm(patches, w2.astype(patches.dtype), bm=bm, bn=bn,
               interpret=interpret)
    return out.reshape(N, OD, OH, OW, Co)


def conv3d_transpose_gemm(x, w, stride: int = 2, *, interpret: bool = True):
    """SAME transposed conv = input dilation + stride-1 implicit GEMM.

    Matches jax.lax.conv_transpose(..., 'SAME') exactly: the kernel is used
    UNFLIPPED (conv_transpose's transpose_kernel=False default) and the
    fractionally-strided input is padded with lax's SAME-transpose rule
    (pad_a = k-1 if s > k-1 else ceil((k+s-2)/2)); output = input * stride.
    """
    N, D, H, W, Ci = x.shape
    KD, KH, KW, _, Co = w.shape
    s = stride
    # dilate input with (s-1) zeros between elements
    xd = jnp.zeros((N, (D - 1) * s + 1, (H - 1) * s + 1, (W - 1) * s + 1, Ci),
                   x.dtype)
    xd = xd.at[:, ::s, ::s, ::s].set(x)
    outs = (D * s, H * s, W * s)
    pads = []
    for k in (KD, KH, KW):
        pad_len = k + s - 2
        pad_a = k - 1 if s > k - 1 else -(-pad_len // 2)
        pads.append((pad_a, pad_len - pad_a))
    xp = jnp.pad(xd, ((0, 0), pads[0], pads[1], pads[2], (0, 0)))

    cols = []
    for kd in range(KD):
        for kh in range(KH):
            for kw in range(KW):
                sl = xp[:, kd:kd + outs[0], kh:kh + outs[1], kw:kw + outs[2], :]
                cols.append(sl.reshape(N * outs[0] * outs[1] * outs[2], Ci))
    patches = jnp.concatenate(cols, axis=-1)
    w2 = w.reshape(KD * KH * KW * Ci, Co)
    out = gemm(patches, w2.astype(patches.dtype), interpret=interpret)
    return out.reshape(N, *outs, Co)
