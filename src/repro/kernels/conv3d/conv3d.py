"""Pallas TPU conv3d as a *fused* implicit GEMM — the 3DGAN hot path.

TPU adaptation of the paper's 3-D convolutions (the GAN's compute
bottleneck on V100s): a CUDA direct conv relies on per-thread scalar
accumulation; the TPU version reformulates each conv as a GEMM over
gathered patches so the MXU's 128x128 systolic array does the work:

    out[p, co] = sum_k patches[p, k] * w2[k, co]
    p = (n, od, oh, ow) output position,  k = (kd, kh, kw, ci) tap

Unlike a classical im2col lowering there is NO materialized
(P, KD*KH*KW*Ci) patches matrix in HBM (27x the input for 3^3 kernels).
Patch gathering happens *inside* the kernel:

- the grid walks (n*od rows, co tiles, kd taps); the BLOCK INDEX MAP over
  the padded input selects the (n, od*stride + kd) slab for each step, so
  the only HBM-resident staging is the SAME-padded input itself;
- the (kh, kw) taps are gathered in-kernel as static strided views of the
  VMEM slab, each feeding a (OH*OW, Ci) x (Ci, bn) MXU contraction into an
  f32 VMEM accumulator carried across the sequential kd grid dimension;
- the epilogue (bias add + LeakyReLU / softplus) is fused into the final
  kd step, so conv+bias+activation is one kernel launch.

Transposed conv (generator upsampling) = input dilation + the same
stride-1 path, so BOTH GAN networks hit the same kernel.  The backward
pass also routes through this file: dx is a transposed conv through the
same fused GEMM (spatially flipped, ci/co-swapped weights), dw is a
patches^T @ grad GEMM with the identical in-kernel gather (`_dw_kernel`).

Tile sizes come from `kernels/conv3d/tiles.py` (registry + autotune
hook); Co is padded up to the bn tile (weights only — cheap), m/k stay
structural.  Ci is deliberately NOT padded to the 128-lane width: for the
discriminator's Ci=1 input layer that padding would inflate HBM traffic
128x, and the MXU cost of a ragged K is already counted by the roofline's
MODEL_FLOPS / HLO_FLOPs ratio.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.autotune import (default_interpret,
                                    resolve_interpret as _resolve_interpret)
from repro.kernels.conv3d import tiles as tiles_lib


# ---------------------------------------------------------------------------
# standalone tiled GEMM (kept for the roofline + gemm-level tests)
# ---------------------------------------------------------------------------


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm(x, w, *, bm: int = 128, bk: int = 128, bn: int = 128,
         interpret: bool = True, out_dtype=None):
    """Tiled MXU matmul: (M, K) @ (K, N) with f32 accumulation."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    out_dtype = out_dtype or x.dtype
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    gm, gk, gn = -(-M // bm), -(-K // bk), -(-N // bn)
    Mp, Kp, Np = gm * bm, gk * bk, gn * bn
    # skip no-op pads: when M/K/N already land on tile multiples the pad
    # (and the trailing slice) would be a pure HBM copy
    if (Mp, Kp) != (M, K):
        x = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
    out = pl.pallas_call(
        _gemm_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:M, :N] if (Mp, Np) != (M, N) else out


# ---------------------------------------------------------------------------
# padding geometry
# ---------------------------------------------------------------------------


def _same_pads(size: int, k: int, stride: int):
    """TF-style SAME padding for one spatial dim -> (lo, hi, out)."""
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return pad // 2, pad - pad // 2, out


def _transpose_pads(k: int, stride: int):
    """lax.conv_transpose 'SAME' rule for the dilated-input stride-1 conv."""
    pad_len = k + stride - 2
    pad_a = k - 1 if stride > k - 1 else -(-pad_len // 2)
    return pad_a, pad_len - pad_a


def _prepare_input(x, kdims, *, stride: int, pads, in_dilation: int):
    """Dilate + pad (negative pads crop) -> (xp, out_dims).

    ``pads`` is ((lo, hi),)*3 over (D, H, W); ``out_dims`` are the conv
    output sizes (Lp - K)//stride + 1 of the prepared input.
    """
    N, D, H, W, Ci = x.shape
    if in_dilation > 1:
        s = in_dilation
        dil = ((D - 1) * s + 1, (H - 1) * s + 1, (W - 1) * s + 1)
        xd = jnp.zeros((N, *dil, Ci), x.dtype)
        x = xd.at[:, ::s, ::s, ::s].set(x)
    # crop any negative pad amounts before jnp.pad (which requires >= 0)
    starts = [max(-lo, 0) for (lo, _hi) in pads]
    stops = [x.shape[1 + i] - max(-hi, 0) for i, (_lo, hi) in enumerate(pads)]
    if any(s != 0 for s in starts) or \
            any(stops[i] != x.shape[1 + i] for i in range(3)):
        x = x[:, starts[0]:stops[0], starts[1]:stops[1], starts[2]:stops[2]]
    pos = [(max(lo, 0), max(hi, 0)) for (lo, hi) in pads]
    if any(p != (0, 0) for p in pos):
        x = jnp.pad(x, ((0, 0), pos[0], pos[1], pos[2], (0, 0)))
    outs = tuple((x.shape[1 + i] - kdims[i]) // stride + 1 for i in range(3))
    return x, outs


# ---------------------------------------------------------------------------
# fused forward kernel: in-kernel patch gather + GEMM + bias/activation
# ---------------------------------------------------------------------------


def _apply_act(y, activation: str, slope: float):
    if activation == "leaky_relu":
        return jnp.where(y >= 0, y, y * slope)
    if activation == "softplus":
        return jax.nn.softplus(y)
    assert activation == "none", activation
    return y


def _gather_taps(x, KH, KW, OH, OW, stride):
    """All (kh, kw) tap columns of the VMEM slab as static strided views."""
    ci = x.shape[-1]
    cols = []
    for kh in range(KH):
        for kw in range(KW):
            # static strided view of the slab == this tap's patch column
            patch = x[kh:kh + (OH - 1) * stride + 1:stride,
                      kw:kw + (OW - 1) * stride + 1:stride, :]
            cols.append(patch.reshape(OH * OW, ci))
    return cols


def _fused_conv_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, KH, KW, OH, OW,
                       stride, activation, slope, n_kd, fuse_taps):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0, 0]                      # (Hp, Wp, Ci) VMEM slab
    w_all = w_ref[...]
    ci = x.shape[-1]
    cols = _gather_taps(x, KH, KW, OH, OW, stride)
    if fuse_taps:
        # one wide (OH*OW, KH*KW*Ci) x (KH*KW*Ci, bn) MXU contraction —
        # wins when Ci is small and per-tap GEMMs would be K-starved
        patches = jnp.concatenate(cols, axis=1)
        w = w_all[0].reshape(KH * KW * ci, -1)
        acc_ref[...] += jax.lax.dot_general(
            patches, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        for t, patch in enumerate(cols):
            acc_ref[...] += jax.lax.dot_general(
                patch, w_all[0, t], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

    @pl.when(kd == n_kd - 1)
    def _():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[0] = _apply_act(y, activation, slope).astype(o_ref.dtype)


def _conv_core(x, w, b=None, *, stride: int, pads, in_dilation: int = 1,
               activation: str = "none", slope: float = 0.2,
               interpret=None, tile_cfg: tiles_lib.ConvTiles | None = None):
    """Driver for the fused kernel; returns (N, OD, OH, OW, Co).

    All conv3d entry points (fwd, transpose fwd, dx of both) reduce to
    this one routine with different (stride, pads, in_dilation, weights).
    """
    interpret = _resolve_interpret(interpret)
    out_dtype = x.dtype
    low_precision_emulation = interpret and x.dtype != jnp.float32
    if low_precision_emulation:
        # Interpret-mode stand-in for the MXU's native low-precision
        # multiply with f32 accumulate: upcast ONCE before staging (and
        # downcast the result once after the call), so the dilate/pad
        # data movement and the grid loop's block reads/writes skip
        # XLA-CPU's per-op emulation casts.  Bit-identical — the kernel
        # dots force preferred_element_type=f32 and the f32->bf16
        # rounding of the final cast matches the per-block epilogue cast
        # — and a no-op on real TPU, where bf16 feeds the MXU natively.
        x = x.astype(jnp.float32)
    N, _, _, _, Ci = x.shape
    KD, KH, KW, Ci2, Co = w.shape
    assert Ci == Ci2, (x.shape, w.shape)
    xp, (OD, OH, OW) = _prepare_input(x, (KD, KH, KW), stride=stride,
                                      pads=pads, in_dilation=in_dilation)
    if tile_cfg is None:
        # dtype joins the key: bf16 and f32 tune independently, and the
        # stride slot records the dilation for the transposed routes so
        # distinct problems never alias
        tile_cfg = tiles_lib.get_tiles(tiles_lib.signature(
            "conv" if in_dilation == 1 else "conv_t",
            x.shape[1:4], Ci, Co, KD,
            stride if in_dilation == 1 else in_dilation, out_dtype))
    bn = min(tile_cfg.bn, max(Co, 1))
    gn = -(-Co // bn)
    Cop = gn * bn
    w4 = w.reshape(KD, KH * KW, Ci, Co).astype(x.dtype)
    if Cop != Co:
        w4 = jnp.pad(w4, ((0, 0), (0, 0), (0, 0), (0, Cop - Co)))
    if b is None:
        b2 = jnp.zeros((1, Cop), x.dtype)
    else:
        b2 = b.reshape(1, Co).astype(x.dtype)
        if Cop != Co:
            b2 = jnp.pad(b2, ((0, 0), (0, Cop - Co)))
    M = N * OD
    Hp, Wp = xp.shape[2], xp.shape[3]
    kernel = functools.partial(
        _fused_conv_kernel, KH=KH, KW=KW, OH=OH, OW=OW, stride=stride,
        activation=activation, slope=slope, n_kd=KD,
        fuse_taps=tile_cfg.fuse_taps)
    out = pl.pallas_call(
        kernel,
        grid=(M, gn, KD),
        in_specs=[
            # the implicit-GEMM gather: dims 0/1 have block size 1, so the
            # index map picks the (n, od*stride + kd) slab of the padded
            # input for each grid step — no patches matrix is ever formed
            pl.BlockSpec((1, 1, Hp, Wp, Ci),
                         lambda m, j, kd, OD=OD, s=stride:
                         (m // OD, (m % OD) * s + kd, 0, 0, 0)),
            pl.BlockSpec((1, KH * KW, Ci, bn),
                         lambda m, j, kd: (kd, 0, 0, j)),
            pl.BlockSpec((1, bn), lambda m, j, kd: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, OH * OW, bn),
                               lambda m, j, kd: (m, 0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (M, OH * OW, Cop),
            jnp.float32 if low_precision_emulation else out_dtype),
        scratch_shapes=[pltpu.VMEM((OH * OW, bn), jnp.float32)],
        interpret=interpret,
    )(xp, w4, b2)
    if low_precision_emulation:
        out = out.astype(out_dtype)
    if Cop != Co:
        out = out[..., :Co]
    return out.reshape(N, OD, OH, OW, Co)


# ---------------------------------------------------------------------------
# dw kernel: patches^T @ grad, same in-kernel gather
# ---------------------------------------------------------------------------


def _dw_kernel(x_ref, g_ref, o_ref, acc_ref, *, KH, KW, OH, OW, stride, n_m,
               fuse_taps):
    m = pl.program_id(2)

    @pl.when(m == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0, 0]                      # (Hp, Wp, Ci)
    g = g_ref[0]                         # (OH*OW, bn)
    ci = x.shape[-1]
    cols = _gather_taps(x, KH, KW, OH, OW, stride)
    if fuse_taps:
        # one (KH*KW*Ci, OH*OW) x (OH*OW, bn) contraction instead of
        # KH*KW thin ones — same win as the forward fused-tap schedule
        patches = jnp.concatenate(cols, axis=1)
        acc_ref[...] += jax.lax.dot_general(
            patches, g, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(acc_ref.shape)
    else:
        for t, patch in enumerate(cols):
            # patches^T @ grad: contract the P row dimension
            acc_ref[t] += jax.lax.dot_general(
                patch, g, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

    @pl.when(m == n_m - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _conv_dw_core(x, g, kdims, *, stride: int, pads, in_dilation: int = 1,
                  interpret=None, tile_cfg: tiles_lib.ConvTiles | None = None):
    """dw[kd,kh,kw,ci,co] = sum_p patches[p, (kd,kh,kw,ci)] * g[p, co].

    ``g`` is the conv output cotangent (N, OD, OH, OW, Co); the input is
    prepared exactly as in the forward pass so the in-kernel gather sees
    the same patch geometry.  The Co (GEMM N) dimension is tiled by the
    signature's ``bn`` — the same registry/autotune machinery as the
    forward kernels (signature kind ``dw`` / ``dw_t``).
    """
    interpret = _resolve_interpret(interpret)
    sig_dtype = x.dtype
    if interpret and x.dtype != jnp.float32:
        # one upcast before staging — see _conv_core
        x, g = x.astype(jnp.float32), g.astype(jnp.float32)
    KD, KH, KW = kdims
    N, _, _, _, Ci = x.shape
    Co = g.shape[-1]
    xp, (OD, OH, OW) = _prepare_input(x, kdims, stride=stride, pads=pads,
                                      in_dilation=in_dilation)
    assert g.shape[1:4] == (OD, OH, OW), (g.shape, (OD, OH, OW))
    if tile_cfg is None:
        tile_cfg = tiles_lib.get_tiles(tiles_lib.signature(
            "dw" if in_dilation == 1 else "dw_t",
            x.shape[1:4], Ci, Co, KD,
            stride if in_dilation == 1 else in_dilation, sig_dtype))
    bn = min(tile_cfg.bn, max(Co, 1))
    gn = -(-Co // bn)
    Cop = gn * bn
    M = N * OD
    Hp, Wp = xp.shape[2], xp.shape[3]
    g3 = g.reshape(M, OH * OW, Co).astype(x.dtype)
    if Cop != Co:
        g3 = jnp.pad(g3, ((0, 0), (0, 0), (0, Cop - Co)))
    kernel = functools.partial(_dw_kernel, KH=KH, KW=KW, OH=OH, OW=OW,
                               stride=stride, n_m=M,
                               fuse_taps=tile_cfg.fuse_taps)
    dw = pl.pallas_call(
        kernel,
        grid=(KD, gn, M),
        in_specs=[
            pl.BlockSpec((1, 1, Hp, Wp, Ci),
                         lambda kd, j, m, OD=OD, s=stride:
                         (m // OD, (m % OD) * s + kd, 0, 0, 0)),
            pl.BlockSpec((1, OH * OW, bn), lambda kd, j, m: (m, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, KH * KW, Ci, bn),
                               lambda kd, j, m: (kd, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((KD, KH * KW, Ci, Cop), jnp.float32),
        scratch_shapes=[pltpu.VMEM((KH * KW, Ci, bn), jnp.float32)],
        interpret=interpret,
    )(xp, g3)
    if Cop != Co:
        dw = dw[..., :Co]
    return dw.reshape(KD, KH, KW, Ci, Co)


# ---------------------------------------------------------------------------
# public trace-time entry points
# ---------------------------------------------------------------------------


def conv3d_fwd(x, w, b=None, stride: int = 1, *, activation: str = "none",
               slope: float = 0.2, interpret=None):
    """SAME conv via the fused implicit-GEMM kernel.

    x: (N, D, H, W, Ci); w: (KD, KH, KW, Ci, Co); optional bias (Co,) and
    activation are fused into the kernel epilogue.
    """
    _, D, H, W, _ = x.shape
    KD, KH, KW = w.shape[:3]
    pads = (_same_pads(D, KD, stride)[:2], _same_pads(H, KH, stride)[:2],
            _same_pads(W, KW, stride)[:2])
    return _conv_core(x, w, b, stride=stride, pads=pads,
                      activation=activation, slope=slope, interpret=interpret)


def conv3d_transpose_fwd(x, w, b=None, stride: int = 2, *,
                         activation: str = "none", slope: float = 0.2,
                         interpret=None):
    """SAME transposed conv = input dilation + stride-1 fused GEMM.

    Matches jax.lax.conv_transpose(..., 'SAME') exactly: the kernel is
    used UNFLIPPED (conv_transpose's transpose_kernel=False default) and
    the fractionally-strided input is padded with lax's SAME-transpose
    rule; output spatial dims = input * stride.
    """
    pads = tuple(_transpose_pads(k, stride) for k in w.shape[:3])
    return _conv_core(x, w, b, stride=1, pads=pads, in_dilation=stride,
                      activation=activation, slope=slope, interpret=interpret)


def _flip_t(w):
    """Spatially flipped, ci/co-swapped weights for the dx routes."""
    return w[::-1, ::-1, ::-1].swapaxes(3, 4)


def conv3d_dx(g, w, stride: int, in_spatial, *, interpret=None):
    """dx of the SAME stride-s conv: a transposed conv routed through the
    same fused GEMM (dilate g by s, flipped/swapped weights, stride 1)."""
    KD, KH, KW = w.shape[:3]
    pads = []
    for L, k in zip(in_spatial, (KD, KH, KW)):
        lo, _hi, O = _same_pads(L, k, stride)
        pads.append((k - 1 - lo, L + lo - 1 - (O - 1) * stride))
    return _conv_core(g, _flip_t(w), None, stride=1, pads=tuple(pads),
                      in_dilation=stride, interpret=interpret)


def conv3d_dw(x, g, kdims, stride: int, *, interpret=None):
    """dw of the SAME stride-s conv: patches^T @ grad GEMM."""
    pads = tuple(_same_pads(L, k, stride)[:2]
                 for L, k in zip(x.shape[1:4], kdims))
    return _conv_dw_core(x, g, kdims, stride=stride, pads=pads,
                         interpret=interpret)


def conv3d_transpose_dx(g, w, stride: int, *, interpret=None):
    """dx of the SAME transposed conv: a stride-s conv of the cotangent
    with flipped/swapped weights through the same fused GEMM."""
    pads = []
    for k in w.shape[:3]:
        pa, _pb = _transpose_pads(k, stride)
        pads.append((k - 1 - pa, pa + 1 - stride))
    return _conv_core(g, _flip_t(w), None, stride=stride, pads=tuple(pads),
                      interpret=interpret)


def conv3d_transpose_dw(x, g, kdims, stride: int, *, interpret=None):
    """dw of the SAME transposed conv: the same patches^T @ grad GEMM over
    the dilated input."""
    pads = tuple(_transpose_pads(k, stride) for k in kdims)
    return _conv_dw_core(x, g, kdims, stride=1, pads=pads,
                         in_dilation=stride, interpret=interpret)


# -- backward-compat aliases (pre-fusion API) --------------------------------


def conv3d_gemm(x, w, stride: int = 1, *, interpret=None, bm=None, bn=None):
    del bm, bn  # tile selection moved to tiles.py
    return conv3d_fwd(x, w, None, stride, interpret=interpret)


def conv3d_transpose_gemm(x, w, stride: int = 2, *, interpret=None):
    return conv3d_transpose_fwd(x, w, None, stride, interpret=interpret)
