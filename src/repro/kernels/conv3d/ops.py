"""jit'd public wrappers for the conv3d implicit-GEMM kernel.

Forward = Pallas kernel; backward differentiates the ref oracle (identical
math) so the ops are usable inside the adversarial training step.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.conv3d.conv3d import conv3d_gemm, conv3d_transpose_gemm
from repro.kernels.conv3d.ref import conv3d_ref, conv3d_transpose_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def conv3d(x, w, stride: int = 1, interpret: bool = True):
    return conv3d_gemm(x, w, stride, interpret=interpret)


def _c_fwd(x, w, stride, interpret):
    return conv3d_gemm(x, w, stride, interpret=interpret), (x, w)


def _c_bwd(stride, interpret, res, g):
    x, w = res
    _, vjp = jax.vjp(lambda x_, w_: conv3d_ref(x_, w_, stride), x, w)
    return vjp(g)


conv3d.defvjp(_c_fwd, _c_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def conv3d_transpose(x, w, stride: int = 2, interpret: bool = True):
    return conv3d_transpose_gemm(x, w, stride, interpret=interpret)


def _t_fwd(x, w, stride, interpret):
    return conv3d_transpose_gemm(x, w, stride, interpret=interpret), (x, w)


def _t_bwd(stride, interpret, res, g):
    x, w = res
    _, vjp = jax.vjp(lambda x_, w_: conv3d_transpose_ref(x_, w_, stride), x, w)
    return vjp(g)


conv3d_transpose.defvjp(_t_fwd, _t_bwd)
