"""jit'd public wrappers for the fused conv3d implicit-GEMM kernels.

Forward AND backward are Pallas kernels: the `custom_vjp` no longer
detours through the `lax.conv` reference —

- dx is a transposed conv routed through the same fused GEMM kernel
  (spatially flipped, ci/co-swapped weights);
- dw is a patches^T @ grad GEMM with the identical in-kernel patch gather;
- db is a plain reduction of the epilogue cotangent (XLA handles it).

The bias+activation epilogue is fused into the forward kernel; its
backward needs only the activation OUTPUT (saved as a residual — it is
the op's result anyway):

    leaky_relu:  d/dz = where(y >= 0, 1, slope)        (y >= 0 <=> z >= 0)
    softplus:    d/dz = sigmoid(z) = 1 - exp(-y)       (y = log(1+e^z))

so no pre-activation buffer is kept and nothing is recomputed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv3d.conv3d import (
    conv3d_dw, conv3d_dx, conv3d_fwd, conv3d_transpose_dw,
    conv3d_transpose_dx, conv3d_transpose_fwd)

ACTIVATIONS = ("none", "leaky_relu", "softplus")


def _act_grad_from_y(y, activation: str, slope: float):
    """d activation / d preactivation, recovered from the OUTPUT y."""
    if activation == "leaky_relu":
        return jnp.where(y >= 0, jnp.ones_like(y), jnp.full_like(y, slope))
    if activation == "softplus":
        return 1.0 - jnp.exp(-y)          # = sigmoid(z); y >= 0 so stable
    raise AssertionError(activation)


def _epilogue_cotangent(g, y, activation, slope):
    if activation == "none":
        return g
    return g * _act_grad_from_y(y, activation, slope).astype(g.dtype)


# ---------------------------------------------------------------------------
# conv3d (+ fused bias/activation)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def conv3d_bias_act(x, w, b, stride: int = 1, activation: str = "none",
                    slope: float = 0.2, interpret=None):
    """Fused SAME conv + bias + activation; one Pallas kernel launch."""
    assert activation in ACTIVATIONS, activation
    return conv3d_fwd(x, w, b, stride, activation=activation, slope=slope,
                      interpret=interpret)


def _cba_fwd(x, w, b, stride, activation, slope, interpret):
    y = conv3d_fwd(x, w, b, stride, activation=activation, slope=slope,
                   interpret=interpret)
    return y, (x, w, b, y if activation != "none" else None)


def _cba_bwd(stride, activation, slope, interpret, res, g):
    x, w, b, y = res
    dz = _epilogue_cotangent(g, y, activation, slope)
    dx = conv3d_dx(dz, w, stride, x.shape[1:4],
                   interpret=interpret).astype(x.dtype)
    dw = conv3d_dw(x, dz, w.shape[:3], stride,
                   interpret=interpret).astype(w.dtype)
    # f32 accumulation for the bias grad (a quarter-million-element sum
    # of bf16 terms drifts in bf16), mirroring the kernels' f32 VMEM
    db = jnp.sum(dz, axis=(0, 1, 2, 3), dtype=jnp.float32).astype(b.dtype)
    return dx, dw, db


conv3d_bias_act.defvjp(_cba_fwd, _cba_bwd)


def conv3d(x, w, stride: int = 1, interpret=None):
    """SAME conv via the fused kernel (no bias/activation epilogue)."""
    b = jnp.zeros((w.shape[-1],), x.dtype)
    return conv3d_bias_act(x, w, b, stride, "none", 0.2, interpret)


# ---------------------------------------------------------------------------
# conv3d_transpose (+ fused bias/activation)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def conv3d_transpose_bias_act(x, w, b, stride: int = 2,
                              activation: str = "none", slope: float = 0.2,
                              interpret=None):
    """Fused SAME transposed conv + bias + activation."""
    assert activation in ACTIVATIONS, activation
    return conv3d_transpose_fwd(x, w, b, stride, activation=activation,
                                slope=slope, interpret=interpret)


def _tba_fwd(x, w, b, stride, activation, slope, interpret):
    y = conv3d_transpose_fwd(x, w, b, stride, activation=activation,
                             slope=slope, interpret=interpret)
    return y, (x, w, b, y if activation != "none" else None)


def _tba_bwd(stride, activation, slope, interpret, res, g):
    x, w, b, y = res
    dz = _epilogue_cotangent(g, y, activation, slope)
    dx = conv3d_transpose_dx(dz, w, stride,
                             interpret=interpret).astype(x.dtype)
    dw = conv3d_transpose_dw(x, dz, w.shape[:3], stride,
                             interpret=interpret).astype(w.dtype)
    db = jnp.sum(dz, axis=(0, 1, 2, 3), dtype=jnp.float32).astype(b.dtype)
    return dx, dw, db


conv3d_transpose_bias_act.defvjp(_tba_fwd, _tba_bwd)


def conv3d_transpose(x, w, stride: int = 2, interpret=None):
    """SAME transposed conv via the fused kernel (no epilogue)."""
    b = jnp.zeros((w.shape[-1],), x.dtype)
    return conv3d_transpose_bias_act(x, w, b, stride, "none", 0.2, interpret)
