"""Mixture-of-Experts substrate: top-k router + grouped capacity dispatch.

GShard/Mesh-TF formulation, adapted for TPU + GSPMD:

- tokens are split into GROUPS along the (data-sharded) token axis; each
  group computes its own capacity-bounded dispatch one-hot, keeping dispatch
  memory O(group * E * cap) instead of O(T * E * cap_global);
- per-expert buffers are built with einsums (lowering to all-to-all across
  the ``expert``->``model`` mesh axis under GSPMD);
- experts run as one (G, E)-batched matmul, sharded over groups (data) and
  experts (model) simultaneously.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.substrate import layers


def init_moe(key, cfg):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, dff, E = cfg.d_model, m.d_ff_expert, m.n_experts
    p = {
        "router": layers.normal_init(ks[0], (d, E), 0.02),
        "w_in": layers.normal_init(ks[1], (E, d, dff)),
        "w_out": layers.normal_init(ks[2], (E, dff, d)),
    }
    if cfg.ffn_type == "swiglu":
        p["w_gate"] = layers.normal_init(ks[3], (E, d, dff))
    return p


def moe_axes(cfg):
    p = {
        "router": ("embed", None),
        "w_in": ("expert", "embed", "mlp"),
        "w_out": ("expert", "mlp", "embed"),
    }
    if cfg.ffn_type == "swiglu":
        p["w_gate"] = ("expert", "embed", "mlp")
    return p


def _pick_groups(T: int, target: int = 1024) -> int:
    """Largest group count G dividing T with group size <= target."""
    G = max(1, T // target)
    while T % G:
        G += 1
    return G


def apply_moe(p, x, cfg, group_target: int = 1024):
    """x: (B, S, d) -> (y, aux_loss, stats)."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    T = B * S
    G = _pick_groups(T, group_target)
    gs = T // G
    xg = x.reshape(G, gs, d)

    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)   # (G,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                   # (G,gs,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    cap = max(int(m.capacity_factor * gs * K / E), K)

    # position of each (token, k) slot inside its expert buffer (per group)
    onehot_e = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)         # (G,gs,K,E)
    flat = onehot_e.reshape(G, gs * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, gs, K, E)
    pos = jnp.sum(pos * onehot_e, axis=-1)                            # (G,gs,K)
    keep = (pos < cap).astype(jnp.float32)

    onehot_c = jax.nn.one_hot(pos, cap, dtype=jnp.float32)            # (G,gs,K,cap)
    oe = onehot_e.astype(jnp.float32)

    # dispatch: (G, gs, E, cap)
    disp = jnp.einsum("gske,gskc->gsec", oe, onehot_c * keep[..., None])
    buf = jnp.einsum("gsec,gsd->gecd", disp.astype(x.dtype), xg)      # (G,E,cap,d)

    # expert computation — batched over (G, E)
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", buf, p["w_in"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, p["w_in"].astype(x.dtype)))
    out = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(x.dtype))

    # combine with gate weights: (G, gs, E, cap) weighted
    wdisp = jnp.einsum("gske,gskc,gsk->gsec", oe, onehot_c,
                       gate_vals * keep)
    y = jnp.einsum("gsec,gecd->gsd", wdisp.astype(x.dtype), out)
    y = y.reshape(B, S, d)

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=1)                                      # (G,E)
    frac = jnp.mean(oe, axis=(1, 2))                                  # (G,E)
    load_balance = E * jnp.mean(jnp.sum(frac * me, axis=-1))
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = m.load_balance_loss * load_balance + m.router_z_loss * z_loss
    stats = {"moe_load_balance": load_balance, "moe_z": z_loss,
             "moe_drop_frac": 1.0 - jnp.mean(keep)}
    return y.astype(x.dtype), aux, stats
