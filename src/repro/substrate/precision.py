"""Mixed-precision policy (paper §4: bf16 on the MXU, f32 master weights).

bf16 shares the f32 exponent range, so no loss SCALING is required
(unlike fp16) — matching how TPUs train in practice and what the paper
relies on.  The adversarial step still runs the dynamic-loss-scale state
machine under bf16 with ``loss_scale=1``: the scale never needs to grow,
but the skip-on-nonfinite guard keeps a diverging GAN step from ever
writing NaNs into the master weights.  The fp16 policy (GPU tensor-core
mode) uses the full dynamic range: scale up, halve on overflow, grow back
after ``growth_interval`` clean steps.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    output_dtype: jnp.dtype = jnp.float32
    # dynamic loss scaling: 0 disables the state machine entirely; 1 runs
    # skip-on-nonfinite without amplification (bf16); >1 is the fp16 mode
    loss_scale: float = 0.0
    # clean steps between scale doublings (0: never grow — bf16 mode)
    growth_interval: int = 0

    def cast_to_compute(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def cast_to_param(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.param_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def cast_to_output(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.output_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


DEFAULT = Policy(loss_scale=1.0)                      # bf16 (paper's TPU mode)
FULL = Policy(compute_dtype=jnp.float32)              # f32 everywhere
FP16 = Policy(compute_dtype=jnp.float16,              # GPU tensor-core mode
              loss_scale=2.0 ** 15, growth_interval=200)


def get_policy(name: str) -> Policy:
    return {"bf16": DEFAULT, "mixed": DEFAULT, "f32": FULL, "full": FULL,
            "fp16": FP16}[name]


def policy_name(policy: Policy) -> str:
    """Canonical name for a policy (the inverse of :func:`get_policy`) —
    what checkpoints record so serving can restore the right one."""
    return {jnp.dtype(jnp.bfloat16): "bf16", jnp.dtype(jnp.float32): "f32",
            jnp.dtype(jnp.float16): "fp16"}[jnp.dtype(policy.compute_dtype)]


# ---------------------------------------------------------------------------
# dynamic loss scaling with skip-on-nonfinite
# ---------------------------------------------------------------------------


class LossScaleState(NamedTuple):
    """Device-resident dynamic-loss-scale state, carried in the train
    state (so it checkpoints and donates with everything else)."""
    scale: jax.Array        # f32 scalar, multiplies the loss
    good_steps: jax.Array   # int32: consecutive finite phases since a skip


def init_loss_scale(policy: Optional[Policy]) -> Optional[LossScaleState]:
    """The initial state, or None when the policy disables scaling."""
    if policy is None or not policy.loss_scale:
        return None
    return LossScaleState(jnp.float32(policy.loss_scale),
                          jnp.zeros((), jnp.int32))


def all_finite(tree) -> jax.Array:
    """Scalar bool: every leaf of ``tree`` is finite (the overflow check
    run on the UNSCALED gradients of each phase)."""
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)]
    if not leaves:
        return jnp.bool_(True)
    return jnp.all(jnp.stack(leaves))


def unscale(state: LossScaleState, tree):
    inv = 1.0 / state.scale
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * inv), tree)


def next_loss_scale(state: LossScaleState, finite: jax.Array,
                    growth_interval: int) -> LossScaleState:
    """Halve on overflow; after ``growth_interval`` consecutive clean
    phases, double (never below 1, never grown when the interval is 0)."""
    good = jnp.where(finite, state.good_steps + 1, 0)
    if growth_interval > 0:
        grow = good >= growth_interval
        scale = jnp.where(grow, state.scale * 2.0, state.scale)
        good = jnp.where(grow, 0, good)
    else:
        scale = state.scale
    scale = jnp.where(finite, scale, jnp.maximum(state.scale * 0.5, 1.0))
    return LossScaleState(scale, good)


def select_finite(finite: jax.Array, new_tree, old_tree):
    """``new_tree`` where the phase was finite, else the untouched
    ``old_tree`` — the skip that keeps nonfinite updates out of the
    master params and optimizer state."""
    return jax.tree.map(lambda n, o: jnp.where(finite, n, o),
                        new_tree, old_tree)
