"""Mixed-precision policy (paper §4: bf16 on the MXU, f32 master weights).

bf16 shares the f32 exponent range, so no loss scaling is required (unlike
fp16) — matching how TPUs train in practice and what the paper relies on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    output_dtype: jnp.dtype = jnp.float32

    def cast_to_compute(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def cast_to_param(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.param_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def cast_to_output(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.output_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


DEFAULT = Policy()                                   # bf16 compute (paper's TPU mode)
FULL = Policy(compute_dtype=jnp.float32)             # f32 everywhere (GPU baseline)


def get_policy(name: str) -> Policy:
    return {"bf16": DEFAULT, "mixed": DEFAULT, "f32": FULL, "full": FULL}[name]
