"""Attention substrate: GQA projections, RoPE / M-RoPE, blockwise
(flash-style) attention in pure JAX, sliding-window variant, decode step.

The blockwise path is the memory-safe reference used inside jitted train /
prefill steps; kernels/flash_attention provides the Pallas TPU version with
the same semantics (validated against this module's math via ref.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.substrate import layers

# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_cos_sin(positions, d_head: int, theta: float, dtype=jnp.float32):
    """positions: (..., S) int -> cos,sin (..., S, d_head//2)."""
    half = d_head // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def mrope_cos_sin(positions, d_head: int, theta: float, sections, dtype=jnp.float32):
    """M-RoPE (qwen2-vl): positions (3, B, S) for (t, h, w) axes.

    The rotary half-dim is partitioned into ``sections``; frequencies in
    section j rotate by the j-th position axis.
    """
    half = d_head // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # section id per frequency index
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    # choose position row per frequency: (B, S, half)
    pos = positions.astype(jnp.float32)[sec_id, :, :].transpose(1, 2, 0)
    ang = pos * inv_freq
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) -> rotated x (half-split)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def init_attn(key, cfg, cross: bool = False):
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": layers.init_dense(ks[0], d, qd, bias=cfg.qkv_bias),
        "wk": layers.init_dense(ks[1], d, kvd, bias=cfg.qkv_bias),
        "wv": layers.init_dense(ks[2], d, kvd, bias=cfg.qkv_bias),
        "wo": layers.init_dense(ks[3], qd, d, bias=False,
                                scale=0.02 / max(cfg.n_layers, 1) ** 0.5),
    }
    return p


def attn_axes(cfg):
    b = cfg.qkv_bias
    return {
        "wq": layers.dense_axes("embed", "heads", bias=b),
        "wk": layers.dense_axes("embed", "kv_heads", bias=b),
        "wv": layers.dense_axes("embed", "kv_heads", bias=b),
        "wo": layers.dense_axes("heads", "embed"),
    }


def project_qkv(p, x, cfg):
    B, S, _ = x.shape
    q = layers.apply_dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = layers.apply_dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = layers.apply_dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure JAX
# ---------------------------------------------------------------------------


def _attend_block(q, k, v, q_off, k_off, causal, window, scale):
    """One (q-block, kv-block) tile with f32 score math.

    q: (B, qc, KH, G, D)  k/v: (B, kc, KH, D) -> out (unnormalised), m, l.
    """
    s = jnp.einsum("bqkgd,bckd->bkgqc", q, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = q_off + jnp.arange(q.shape[1])
    kpos = k_off + jnp.arange(k.shape[1])
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # (B,KH,G,qc)
    # guard fully-masked rows
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(s - m_safe[..., None])
    e = jnp.where(mask[None, None, None], e, 0.0)
    l = jnp.sum(e, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", e.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m_safe, l


def blockwise_attention(q, k, v, *, causal=True, window=0,
                        q_chunk=512, kv_chunk=512, q_offset=0):
    """Memory-bounded attention with online softmax.

    q: (B, S, H, D); k/v: (B, T, KH, D). GQA via head grouping.
    Python loop over q blocks (static causal kv extent -> exact FLOPs),
    lax.scan over kv blocks (O(1) HLO in T).
    """
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / (D ** 0.5)
    q = q.reshape(B, S, KH, G, D)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    # pad kv to a block multiple so dynamic_slice never clamps (the valid
    # mask below zeroes the padded tail)
    t_pad = (-T) % kv_chunk
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    n_q = -(-S // q_chunk)
    outs = []
    for qi in range(n_q):
        q_off = q_offset + qi * q_chunk
        qlen = min(q_chunk, S - qi * q_chunk)
        qb = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, qlen, axis=1)
        # causal: kv blocks beyond the end of this q block contribute nothing
        if causal:
            k_hi = min(T, q_off + qlen)
        else:
            k_hi = T
        if window and causal:
            k_lo = max(0, (q_off - window + 1) // kv_chunk * kv_chunk)
        else:
            k_lo = 0
        n_kv = max(1, -(-(k_hi - k_lo) // kv_chunk))

        def body(carry, ki, qb=qb, q_off=q_off, k_lo=k_lo):
            acc, m, l = carry
            k_off = k_lo + ki * kv_chunk
            kb = jax.lax.dynamic_slice_in_dim(k, k_off, kv_chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, k_off, kv_chunk, axis=1)
            # mask out positions past T (dynamic_slice clamps, so re-mask)
            kpos = k_off + jnp.arange(kv_chunk)
            valid = kpos < T
            o_b, m_b, l_b = _attend_block(
                qb, jnp.where(valid[None, :, None, None], kb, 0),
                jnp.where(valid[None, :, None, None], vb, 0),
                q_off, k_off, causal, window, scale)
            l_b = jnp.where(valid.any(), l_b, 0.0)
            m_new = jnp.maximum(m, m_b)
            a1 = jnp.exp(m - m_new)
            a2 = jnp.exp(m_b - m_new)
            acc = acc * a1[..., None] + o_b.transpose(0, 2, 3, 1, 4) * a2[..., None]
            l = l * a1 + l_b * a2
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, KH, G, qlen, D), jnp.float32)
        # m must start finite for exp(m - m_new); use large negative, not -inf
        m0 = jnp.full((B, KH, G, qlen), -1e30)
        l0 = jnp.zeros((B, KH, G, qlen), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(n_kv))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, qlen, H, D))
    return jnp.concatenate(outs, axis=1).astype(v.dtype) if len(outs) > 1 \
        else outs[0].astype(v.dtype)


def attend(q, k, v, *, causal=True, window=0, use_pallas=False,
           seq_len=None, kv_len=None, q_offset=None):
    """Training/prefill/decode attention router shared by the model zoo.

    ``use_pallas=True`` routes to the flash-attention Pallas kernels
    (forward AND backward; block sizes from the shared autotune
    registry).  The pure-JAX fallback picks ``dot_attention`` for short
    sequences and ``blockwise_attention`` beyond 1k, as before.

    A non-None ``kv_len`` (per-row (B,) live cache lengths) selects the
    SERVING branch: single-query calls (S=1, no ``q_offset``) hit the
    split-KV flash-decode kernel; chunked prefill calls pass ``q_offset``
    (per-row (B,) absolute position of the chunk's first query) and hit
    the offset-aware chunk kernel.  The pure-JAX serving fallback is
    ``dot_attention`` with the matching ragged masks.
    """
    if kv_len is not None:
        if use_pallas:
            if q.shape[1] == 1 and q_offset is None:
                from repro.kernels.flash_attention.decode import flash_decode
                return flash_decode(q, k, v, kv_len, window=window)
            from repro.kernels.flash_attention.flash_attention import (
                flash_attention_chunk)
            off = q_offset if q_offset is not None \
                else jnp.maximum(kv_len - 1, 0)
            return flash_attention_chunk(q, k, v, off, kv_len, window=window)
        if q_offset is None:
            return dot_attention(q, k, v, causal=False, window=window,
                                 kv_len=kv_len)
        qpos = q_offset[:, None] + jnp.arange(q.shape[1])[None]
        return dot_attention(q, k, v, causal=causal, window=window,
                             kv_len=kv_len, q_positions=qpos)
    S = q.shape[1] if seq_len is None else seq_len
    if use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal, window)
    if S <= 1024:
        return dot_attention(q, k, v, causal=causal, window=window)
    return blockwise_attention(q, k, v, causal=causal, window=window)


def dot_attention(q, k, v, *, causal=True, window=0, kv_len=None, q_positions=None):
    """Plain O(S*T)-memory attention for short sequences / decode.

    kv_len: (B,) valid cache lengths (decode); q_positions: (B,S) absolute
    positions of queries (for causal masking against a cache).
    """
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k,
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    kpos = jnp.arange(T)
    mask = jnp.ones((B, S, T), bool)
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if causal:
        mask &= q_positions[:, :, None] >= kpos[None, None, :]
    if window:
        mask &= kpos[None, None, :] > q_positions[:, :, None] - window
    if kv_len is not None:
        mask &= kpos[None, None, :] < kv_len[:, None, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqt,btkd->bqkgd", w, v, preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, D).astype(v.dtype)
