"""State-space / recurrent substrate.

Three block families, each with a chunked/scan training form and an O(1)
recurrent decode step (the reason zamba2/xlstm can serve long_500k):

- **Mamba2 (SSD)**: scalar-per-head decay A, chunked algorithm — intra-chunk
  quadratic matmuls (MXU-friendly) + inter-chunk state carry via lax.scan.
- **mLSTM** (xLSTM): matrix memory C with exponential input gate / sigmoid
  forget gate, computed chunkwise with running-max stabilisation.
- **sLSTM** (xLSTM): strictly sequential stabilised scalar-memory LSTM with
  block-diagonal recurrent weights, via lax.scan over time.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.substrate import layers

# ===========================================================================
# Mamba2 / SSD
# ===========================================================================


def init_mamba2(key, d_model: int, ssm):
    di = ssm.expand * d_model
    H = di // ssm.head_dim
    N = ssm.state_dim
    ks = jax.random.split(key, 8)
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": layers.normal_init(ks[0], (d_model, 2 * di + 2 * N + H)),
        "conv_w": layers.normal_init(ks[1], (ssm.conv_width, di + 2 * N), 0.2),
        "conv_b": jnp.zeros((di + 2 * N,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,),
                    minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))),
        "norm": layers.init_norm(di, "rmsnorm"),
        "out_proj": layers.normal_init(ks[3], (di, d_model)),
    }


def mamba2_axes():
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "A_log": ("inner",),
        "D": ("inner",),
        "dt_bias": ("inner",),
        "norm": {"scale": ("inner",)},
        "out_proj": ("inner", "embed"),
    }


class Mamba2State(NamedTuple):
    ssm: jax.Array      # (B, H, P, N)
    conv: jax.Array     # (B, conv_width-1, di + 2N) rolling conv buffer


def _mamba2_split(p, x, d_model, ssm):
    di = ssm.expand * d_model
    H = di // ssm.head_dim
    N = ssm.state_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    return z, xbc, dt, di, H, N


def _causal_conv(xbc, w, b, pad_left=None):
    """xbc: (B,S,C); depthwise causal conv, width W."""
    W = w.shape[0]
    if pad_left is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = pad_left.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(W))
    return jax.nn.silu(out + b.astype(xbc.dtype))


def apply_mamba2(p, x, d_model, ssm, init_state=None, return_state=False,
                 use_pallas=False):
    """Chunked SSD forward. x: (B, S, d_model) -> (B, S, d_model).

    ``use_pallas=True`` routes the scan core through the Pallas SSD
    kernels (forward AND backward; chunk length from the shared autotune
    registry) on the stateless training path; the stateful prefill /
    resume paths keep the lax.scan form, which carries conv and ssm
    state explicitly.
    """
    B, S, _ = x.shape
    z, xbc, dt_raw, di, H, N = _mamba2_split(p, x, d_model, ssm)
    P = ssm.head_dim
    conv_pad = init_state.conv if init_state is not None else None
    if return_state:
        # capture the conv tail BEFORE the conv consumes xbc (recomputing
        # x @ in_proj here kept a 0.5 GB/layer buffer alive per layer in
        # 32k prefill — §Perf zamba hillclimb)
        W = p["conv_w"].shape[0]
        if S >= W - 1:
            conv_tail = xbc[:, S - (W - 1):, :]
        else:
            conv_tail = jnp.pad(xbc, ((0, 0), (W - 1 - S, 0), (0, 0)))
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_pad)
    xs, Bmat, Cmat = jnp.split(xbc, [di, di + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                      # (B,S,H)
    A = -jnp.exp(p["A_log"])                                  # (H,) negative

    if use_pallas and init_state is None and not return_state:
        from repro.kernels.ssm_scan.ops import ssm_scan as ssm_scan_kernel
        y = ssm_scan_kernel(xs.astype(jnp.float32),
                            Bmat.astype(jnp.float32),
                            Cmat.astype(jnp.float32), dt, A)
        y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(B, S, di).astype(x.dtype)
        y = layers.apply_norm(p["norm"], y) * jax.nn.silu(z)
        return y @ p["out_proj"].astype(x.dtype)

    la = dt * A                                               # log-decay (B,S,H)

    L = min(ssm.chunk, S)
    assert S % L == 0, (S, L)
    nC = S // L
    # reshape into chunks
    xc = xs.reshape(B, nC, L, H, P)
    bc = Bmat.reshape(B, nC, L, N).astype(jnp.float32)
    cc = Cmat.reshape(B, nC, L, N).astype(jnp.float32)
    dtc = dt.reshape(B, nC, L, H)
    lac = la.reshape(B, nC, L, H)

    s0 = (init_state.ssm.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32))

    def chunk_body(state, inp):
        xci, bci, cci, dti, lai = inp                 # (B,L,H,P),(B,L,N),...
        F = jnp.cumsum(lai, axis=1)                   # (B,L,H) inclusive
        Ftot = F[:, -1]                               # (B,H)
        # ----- inter: y_t += exp(F_t) * C_t . state
        y_inter = jnp.einsum("bln,bhpn->blhp", cci, state) \
            * jnp.exp(F).transpose(0, 1, 2)[..., None]
        # ----- intra: scores[t,s] = (C_t.B_s) exp(F_t - F_s) dt_s, s<=t
        dec = F[:, :, None, :] - F[:, None, :, :]     # (B,L,L,H)
        tri = jnp.tril(jnp.ones((L, L), bool))
        dec = jnp.where(tri[None, :, :, None], dec, -jnp.inf)
        cb = jnp.einsum("bln,bsn->bls", cci, bci)     # (B,L,L)
        M = cb[..., None] * jnp.exp(dec) * dti[:, None, :, :]
        y_intra = jnp.einsum("blsh,bshp->blhp", M, xci.astype(jnp.float32))
        # ----- state update
        wgt = jnp.exp(Ftot[:, None] - F) * dti        # (B,L,H)
        dstate = jnp.einsum("blh,blhp,bln->bhpn",
                            wgt, xci.astype(jnp.float32), bci)
        state = state * jnp.exp(Ftot)[:, :, None, None] + dstate
        return state, (y_inter + y_intra)

    inputs = (xc.transpose(1, 0, 2, 3, 4), bc.transpose(1, 0, 2, 3),
              cc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
              lac.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(chunk_body, s0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = layers.apply_norm(p["norm"], y) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, Mamba2State(ssm=state, conv=conv_tail)
    return out


def mamba2_init_state(cfg_d_model, ssm, batch, dtype=jnp.float32):
    di = ssm.expand * cfg_d_model
    H = di // ssm.head_dim
    return Mamba2State(
        ssm=jnp.zeros((batch, H, ssm.head_dim, ssm.state_dim), jnp.float32),
        conv=jnp.zeros((batch, ssm.conv_width - 1, di + 2 * ssm.state_dim),
                       dtype))


def mamba2_step(p, x1, state: Mamba2State, d_model, ssm):
    """Single decode step. x1: (B, 1, d_model) -> (y1, new_state)."""
    B = x1.shape[0]
    z, xbc, dt_raw, di, H, N = _mamba2_split(p, x1, d_model, ssm)
    P = ssm.head_dim
    # rolling conv buffer
    buf = jnp.concatenate([state.conv.astype(x1.dtype), xbc], axis=1)
    W = p["conv_w"].shape[0]
    conv_out = jnp.einsum("bwc,wc->bc", buf[:, -W:], p["conv_w"].astype(x1.dtype))
    xbc1 = jax.nn.silu(conv_out + p["conv_b"].astype(x1.dtype))[:, None]
    xs, Bmat, Cmat = jnp.split(xbc1, [di, di + N], axis=-1)
    xs = xs.reshape(B, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                   # (B,H)
    Bv = Bmat[:, 0].astype(jnp.float32)                       # (B,N)
    Cv = Cmat[:, 0].astype(jnp.float32)
    new_s = (state.ssm * decay[:, :, None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dt, xs, Bv))
    y = jnp.einsum("bn,bhpn->bhp", Cv, new_s) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, di).astype(x1.dtype)
    y = layers.apply_norm(p["norm"], y) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x1.dtype)
    return out, Mamba2State(ssm=new_s, conv=buf[:, -(W - 1):])


# ===========================================================================
# mLSTM (xLSTM) — chunkwise with running-max stabilisation
# ===========================================================================


def init_mlstm(key, d_model: int, n_heads: int, expand: int = 2):
    di = expand * d_model
    ks = jax.random.split(key, 6)
    return {
        "up": layers.normal_init(ks[0], (d_model, 2 * di)),    # [mlstm in, gate]
        "qkv": layers.normal_init(ks[1], (di, 3 * di)),
        "gates": layers.normal_init(ks[2], (di, 3 * n_heads), 0.02),  # i,f,o~
        "gates_b": jnp.concatenate([
            jnp.zeros((n_heads,)), 3.0 * jnp.ones((n_heads,)),
            jnp.zeros((n_heads,))]),
        "norm": layers.init_norm(di, "rmsnorm"),
        "down": layers.normal_init(ks[3], (di, d_model)),
    }


def mlstm_axes():
    return {
        "up": ("embed", "inner"), "qkv": ("inner", "inner"),
        "gates": ("inner", None), "gates_b": (None,),
        "norm": {"scale": ("inner",)}, "down": ("inner", "embed"),
    }


class MLSTMState(NamedTuple):
    C: jax.Array        # (B, H, Dk, Dv)
    n: jax.Array        # (B, H, Dk)
    m: jax.Array        # (B, H)


def mlstm_init_state(batch, n_heads, dh):
    return MLSTMState(C=jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, n_heads, dh), jnp.float32),
                      m=jnp.full((batch, n_heads), -1e30, jnp.float32))


def _mlstm_qkvg(p, x, n_heads):
    di = p["down"].shape[0]
    up = x @ p["up"].astype(x.dtype)
    inner, gate = jnp.split(up, 2, axis=-1)
    qkv = inner @ p["qkv"].astype(x.dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    B, S = x.shape[:2]
    dh = di // n_heads
    q = q.reshape(B, S, n_heads, dh)
    k = k.reshape(B, S, n_heads, dh) / (dh ** 0.5)
    v = v.reshape(B, S, n_heads, dh)
    g = (inner @ p["gates"].astype(x.dtype)).astype(jnp.float32) \
        + p["gates_b"]
    ig, fg, og = jnp.split(g, 3, axis=-1)                     # (B,S,H)
    return q, k, v, ig, fg, og, gate, di, dh


def apply_mlstm(p, x, n_heads, chunk=256, init_state=None, return_state=False):
    B, S, _ = x.shape
    q, k, v, ig, fg, og, gate, di, dh = _mlstm_qkvg(p, x, n_heads)
    L = min(chunk, S)
    assert S % L == 0
    nC = S // L
    lf = jax.nn.log_sigmoid(fg)                               # (B,S,H)

    st = init_state if init_state is not None else mlstm_init_state(B, n_heads, dh)

    def rs(t, *shape):
        return t.reshape(B, nC, L, *shape).transpose(1, 0, 2, *range(3, 3 + len(shape)))

    qc, kc, vc = (rs(t, n_heads, dh).astype(jnp.float32) for t in (q, k, v))
    lfc, igc, = rs(lf, n_heads), rs(ig, n_heads)

    def body(carry, inp):
        C, n, m = carry
        qi, ki, vi, lfi, igi = inp                            # (B,L,H,*)
        F = jnp.cumsum(lfi, axis=1)                           # (B,L,H)
        Ftot = F[:, -1]
        # row stabiliser
        dec = F[:, :, None, :] - F[:, None, :, :] + igi[:, None, :, :]
        tri = jnp.tril(jnp.ones((qi.shape[1], qi.shape[1]), bool))
        dec = jnp.where(tri[None, :, :, None], dec, -jnp.inf)
        row_intra = jnp.max(dec, axis=2)                      # (B,L,H)
        row_inter = m[:, None, :] + F
        m_row = jnp.maximum(row_inter, row_intra)             # (B,L,H)
        m_row = jnp.maximum(m_row, -1e30)
        # intra scores
        sc = jnp.einsum("blhd,bshd->blsh", qi, ki) * jnp.exp(
            dec - m_row[:, :, None, :])
        y = jnp.einsum("blsh,bshd->blhd", sc, vi)
        den = jnp.sum(sc, axis=2)                             # (B,L,H)
        # inter
        w_inter = jnp.exp(row_inter - m_row)                  # (B,L,H)
        y = y + jnp.einsum("blhd,bhdv->blhv", qi, C) * w_inter[..., None]
        den = den + jnp.einsum("blhd,bhd->blh", qi, n) * w_inter
        h = y / jnp.maximum(jnp.abs(den), jnp.exp(-m_row))[..., None]
        # carry update
        m_new = jnp.maximum(m + Ftot, jnp.max(Ftot[:, None] - F + igi, axis=1))
        C = C * jnp.exp(m + Ftot - m_new)[..., None, None] + jnp.einsum(
            "blh,blhd,blhv->bhdv",
            jnp.exp(Ftot[:, None] - F + igi - m_new[:, None]), ki, vi)
        n = n * jnp.exp(m + Ftot - m_new)[..., None] + jnp.einsum(
            "blh,blhd->bhd",
            jnp.exp(Ftot[:, None] - F + igi - m_new[:, None]), ki)
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(body, tuple(st), (qc, kc, vc, lfc, igc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, n_heads, dh)
    h = h * jax.nn.sigmoid(og).reshape(B, S, n_heads, 1)
    h = h.reshape(B, S, di).astype(x.dtype)
    h = layers.apply_norm(p["norm"], h) * jax.nn.silu(gate)
    out = h @ p["down"].astype(x.dtype)
    if return_state:
        return out, MLSTMState(C=C, n=n, m=m)
    return out


def mlstm_step(p, x1, state: MLSTMState, n_heads):
    """Single decode step. x1: (B, 1, d)."""
    B = x1.shape[0]
    q, k, v, ig, fg, og, gate, di, dh = _mlstm_qkvg(p, x1, n_heads)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))   # (B,H,dh)
    ig, fg, og = ig[:, 0], fg[:, 0], og[:, 0]                    # (B,H)
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(state.m + lf, ig)
    a = jnp.exp(state.m + lf - m_new)
    b = jnp.exp(ig - m_new)
    C = state.C * a[..., None, None] + jnp.einsum("bhd,bhv->bhdv", k, v) * b[..., None, None]
    n = state.n * a[..., None] + k * b[..., None]
    num = jnp.einsum("bhd,bhdv->bhv", q, C)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = (h * jax.nn.sigmoid(og)[..., None]).reshape(B, 1, di).astype(x1.dtype)
    h = layers.apply_norm(p["norm"], h) * jax.nn.silu(gate)
    return h @ p["down"].astype(x1.dtype), MLSTMState(C=C, n=n, m=m_new)


# ===========================================================================
# sLSTM (xLSTM) — sequential stabilised scan
# ===========================================================================


def init_slstm(key, d_model: int, n_heads: int):
    ks = jax.random.split(key, 5)
    dh = d_model // n_heads
    d_ff = int(d_model * 4 / 3)
    return {
        "w": layers.normal_init(ks[0], (d_model, 4 * d_model)),    # z,i,f,o
        "r": layers.normal_init(ks[1], (n_heads, dh, 4 * dh), 0.02),
        "b": jnp.concatenate([jnp.zeros((2 * d_model,)),
                              3.0 * jnp.ones((d_model,)),
                              jnp.zeros((d_model,))]),
        # post-block gated FFN (pf = 4/3)
        "ffn_in": layers.normal_init(ks[2], (d_model, 2 * d_ff)),
        "ffn_out": layers.normal_init(ks[3], (d_ff, d_model)),
    }


def slstm_axes():
    return {"w": ("embed", "inner"), "r": ("heads", None, None), "b": (None,),
            "ffn_in": ("embed", "mlp"), "ffn_out": ("mlp", "embed")}


class SLSTMState(NamedTuple):
    c: jax.Array    # (B, d)
    n: jax.Array    # (B, d)
    h: jax.Array    # (B, d)
    m: jax.Array    # (B, d)


def slstm_init_state(batch, d_model):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))


def _slstm_cell(p, wx, state: SLSTMState, n_heads, d_model):
    """wx: (B, 4d) precomputed input contribution."""
    dh = d_model // n_heads
    B = wx.shape[0]
    hh = state.h.reshape(B, n_heads, dh)
    rh = jnp.einsum("bhd,hde->bhe", hh, p["r"])                # (B,H,4dh)
    rh = rh.reshape(B, n_heads, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d_model)
    g = (wx + rh + p["b"]).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    zt = jnp.tanh(zt)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + state.m, it)
    a = jnp.exp(lf + state.m - m_new)
    b = jnp.exp(it - m_new)
    c = a * state.c + b * zt
    n = a * state.n + b
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def apply_slstm(p, x, n_heads, init_state=None, return_state=False):
    B, S, d = x.shape
    wx = (x @ p["w"].astype(x.dtype)).astype(jnp.float32)     # (B,S,4d)
    # gate layout: r output is per-head [z,i,f,o] chunks; reorder w to match
    st = init_state if init_state is not None else slstm_init_state(B, d)

    def body(state, wxt):
        new = _slstm_cell(p, wxt, state, n_heads, d)
        return new, new.h

    st, hs = jax.lax.scan(body, st, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)                 # (B,S,d)
    # gated FFN
    u = h @ p["ffn_in"].astype(x.dtype)
    a, bgate = jnp.split(u, 2, axis=-1)
    out = (jax.nn.gelu(a) * bgate) @ p["ffn_out"].astype(x.dtype)
    if return_state:
        return out, st
    return out


def slstm_step(p, x1, state: SLSTMState, n_heads):
    B, _, d = x1.shape
    wx = (x1[:, 0] @ p["w"].astype(x1.dtype)).astype(jnp.float32)
    new = _slstm_cell(p, wx, state, n_heads, d)
    h = new.h[:, None].astype(x1.dtype)
    u = h @ p["ffn_in"].astype(x1.dtype)
    a, bgate = jnp.split(u, 2, axis=-1)
    out = (jax.nn.gelu(a) * bgate) @ p["ffn_out"].astype(x1.dtype)
    return out, new
