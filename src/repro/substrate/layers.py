"""Shared numeric substrate: initializers, norms, dense layers, activations.

Parameters are plain nested dicts of jnp arrays.  Every init_* function has a
matching *_axes function returning the logical-axis names for each leaf, used
by repro.parallel.sharding to build PartitionSpecs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


def lecun_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return jax.random.normal(key, shape, dtype) / jnp.sqrt(jnp.maximum(fan_in, 1.0))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(d: int, norm_type: str = "rmsnorm"):
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def norm_axes(norm_type: str = "rmsnorm"):
    if norm_type == "rmsnorm":
        return {"scale": ("embed",)}
    return {"scale": ("embed",), "bias": ("embed",)}


def apply_norm(p, x, norm_type: str = "rmsnorm", eps: float = 1e-5):
    # norm statistics in f32 regardless of compute dtype
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"]).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / FFN
# ---------------------------------------------------------------------------


def init_dense(key, d_in, d_out, bias=False, scale=0.02):
    p = {"w": normal_init(key, (d_in, d_out), scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_axes(ax_in, ax_out, bias=False):
    p = {"w": (ax_in, ax_out)}
    if bias:
        p["b"] = (ax_out,)
    return p


def apply_dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def act(name: str, x):
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu2":                       # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def init_ffn(key, d_model, d_ff, ffn_type: str):
    ks = jax.random.split(key, 3)
    if ffn_type == "swiglu":
        return {
            "w_gate": normal_init(ks[0], (d_model, d_ff)),
            "w_in": normal_init(ks[1], (d_model, d_ff)),
            "w_out": normal_init(ks[2], (d_ff, d_model)),
        }
    return {
        "w_in": normal_init(ks[0], (d_model, d_ff)),
        "w_out": normal_init(ks[1], (d_ff, d_model)),
    }


def ffn_axes(ffn_type: str):
    if ffn_type == "swiglu":
        return {"w_gate": ("embed", "mlp"), "w_in": ("embed", "mlp"),
                "w_out": ("mlp", "embed")}
    return {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}


def apply_ffn(p, x, ffn_type: str):
    if ffn_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_in"].astype(x.dtype))
    elif ffn_type == "relu2":
        h = act("relu2", x @ p["w_in"].astype(x.dtype))
    else:
        h = act("gelu", x @ p["w_in"].astype(x.dtype))
    return h @ p["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def init_embed(key, vocab, d_model):
    return {"emb": normal_init(key, (vocab, d_model), 0.02)}


def embed_axes():
    return {"emb": ("vocab", "embed")}


def apply_embed(p, tokens, dtype=jnp.float32):
    return p["emb"].astype(dtype)[tokens]
