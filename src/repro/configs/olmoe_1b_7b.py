"""olmoe-1b-7b [moe]: 64 experts top-8, fine-grained. [arXiv:2409.02060]"""
from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,                  # per-expert width (fine-grained)
        vocab=50_304,
        source="arXiv:2409.02060",
        ffn_type="swiglu",
        qkv_bias=False,
        rope_theta=10_000.0,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    )
