"""whisper-base [audio]: enc-dec, conv frontend stubbed. [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="whisper-base",
        family="audio",
        n_layers=6,                 # decoder layers
        n_encoder_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51_865,
        source="arXiv:2212.04356",
        ffn_type="gelu",
        norm_type="layernorm",
        qkv_bias=True,              # whisper uses bias on q/v
        rope_theta=0.0,             # learned absolute positions, not rope
        is_encoder_decoder=True,
        max_source_positions=1500,
        max_target_positions=448,
        tie_embeddings=True,
        subquadratic=False,
    )
