"""nemotron-4-15b [dense]: GQA, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="nemotron-4-15b",
        family="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=24_576,
        vocab=256_000,
        source="arXiv:2402.16819",
        ffn_type="relu2",           # squared ReLU, no gating
        norm_type="layernorm",
        rope_theta=10_000.0,
    )
