"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="zamba2-1.2b",
        family="hybrid",
        n_layers=38,                # mamba2 blocks
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,                  # shared-block MLP width
        vocab=32_000,
        source="arXiv:2411.15242",
        ffn_type="gelu",
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
        shared_attn_every=6,        # shared attn block applied every 6 layers
        subquadratic=True,          # mamba2 state decode; shared attn cached
    )
