"""calo3dgan: the paper's own architecture — 3-D convolutional ACGAN for
electromagnetic-calorimeter shower simulation (3DGAN, Khattak et al. 2019,
as trained in this paper). [paper §2-§4]"""
import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class GANConfig:
    arch_id: str = "calo3dgan"
    family: str = "gan"
    source: str = "18th IEEE ICMLA (2019); this paper"
    # calorimeter image: 51 x 51 x 25 cells (x, y, z=depth)
    image_shape: Tuple[int, int, int] = (51, 51, 25)
    latent_dim: int = 254          # + 2 conditioning scalars (E_p, theta)
    gen_channels: Tuple[int, ...] = (64, 32, 16, 8)
    disc_channels: Tuple[int, ...] = (16, 32, 64, 128)
    gen_steps_per_disc: int = 2    # Algorithm 1: train G twice per D step
    # ACGAN auxiliary targets: primary energy E_p, angle theta, total E_CAL
    aux_ecal_weight: float = 0.1
    aux_energy_weight: float = 10.0
    aux_angle_weight: float = 0.1
    batch_size: int = 128          # paper: BS=128 matches the 128x128 MXU
    decode_supported: bool = False
    # Pallas fused-conv hot path: None defers to the process/env toggle
    # (core/gan.py pallas_conv_enabled); True/False pins it per config.
    # Train steps freeze the resolved value at trace time.
    use_pallas_conv: Optional[bool] = None
    # Mixed-precision policy name (substrate/precision.get_policy): the
    # paper's TPU runs train bf16-compute / f32-master.  launch/train.py
    # --precision and launch/build.build_gan_train(policy_name=...)
    # override per run; checkpoints record the resolved value so serving
    # restores showers at the precision the generator trained in.
    precision: str = "bf16"
    # Gradient-reduction strategy over the data axes ("flat" |
    # "hierarchical"): hierarchical = intra-node psum over `device`, then
    # bucketed psums over `node` (collectives.make_grad_reduce) — the
    # cross-node schedule the custom loop runs on multi-node clusters.
    # Numerically interchangeable with flat; launch/train.py --grad-reduce
    # and build_gan_train(grad_reduce=...) override per run.
    grad_reduce: str = "flat"
    # Inter-node bucket size (MiB) for the hierarchical strategy.
    reduce_bucket_mb: float = 4.0


def config() -> GANConfig:
    return GANConfig()


def reduced() -> GANConfig:
    return GANConfig(
        image_shape=(13, 13, 13),
        latent_dim=62,
        gen_channels=(16, 8),
        disc_channels=(8, 16),
        batch_size=8,
    )


def bench() -> GANConfig:
    """Minimal variant for CPU wall-clock benchmarks (fast compiles)."""
    return GANConfig(
        image_shape=(9, 9, 9),
        latent_dim=30,
        gen_channels=(12, 6),
        disc_channels=(6, 12),
        batch_size=8,
    )
