"""Architecture configuration system.

Every assigned architecture (plus the paper's own 3DGAN) is described by a
single frozen dataclass.  Configs are registered by id and selectable from
every launcher via ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Config dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N (SSD state size per head)
    head_dim: int = 64           # P (channels per SSM head)
    expand: int = 2              # d_inner = expand * d_model
    chunk: int = 256             # chunked-scan block length
    conv_width: int = 4          # short causal conv width


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Config for one architecture (transformer backbone semantics)."""

    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""             # citation (arXiv id / model card)

    # attention details
    d_head: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False          # qwen2-vl multimodal 3-axis rope
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of d_head/2
    sliding_window: int = 0      # 0 -> full causal attention

    # ffn details
    ffn_type: str = "swiglu"     # swiglu | gelu | relu2
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    tie_embeddings: bool = False

    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # enc-dec (audio)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    max_source_positions: int = 1500    # whisper: mel frames / 2
    max_target_positions: int = 448

    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0

    # ssm/hybrid layer pattern ("m"=mamba2, "s"=slstm, "x"=mlstm, "a"=attn)
    layer_pattern: str = ""

    # serving
    decode_supported: bool = True
    subquadratic: bool = False   # can serve long_500k natively

    # kernel routing: replace the pure-JAX attention / SSD-scan training
    # paths with the Pallas kernels (tiles/chunks come from the shared
    # autotune registry; interpret-mode off-TPU, compiled on TPU)
    use_pallas_attn: bool = False
    use_pallas_ssm: bool = False

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        c = self
        n = c.vocab * c.d_model                       # embedding
        if not self.tie_embeddings:
            n += c.vocab * c.d_model                  # lm head
        n += _block_params(c) * c.n_layers
        if c.is_encoder_decoder:
            n += _block_params(c, cross=False) * c.n_encoder_layers
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        c, m = self, self.moe
        dense = self.param_count()
        expert_p = 3 * c.d_model * m.d_ff_expert
        inactive = (m.n_experts - m.top_k) * expert_p * c.n_layers
        return dense - inactive


def _block_params(c: ArchConfig, cross: bool = False) -> int:
    """Approximate per-block parameter count."""
    attn = c.d_model * c.q_dim + 2 * c.d_model * c.kv_dim + c.q_dim * c.d_model
    if c.family == "ssm":
        d_in = (c.ssm.expand if c.ssm else 2) * c.d_model
        return 2 * (c.d_model * 2 * d_in)           # rough: mlstm/slstm proj
    if c.moe is not None:
        ffn = c.moe.n_experts * 3 * c.d_model * c.moe.d_ff_expert
        ffn += c.d_model * c.moe.n_experts          # router
    elif c.ffn_type == "swiglu":
        ffn = 3 * c.d_model * c.d_ff
    else:
        ffn = 2 * c.d_model * c.d_ff
    if c.family == "hybrid" and c.ssm is not None:
        d_inner = c.ssm.expand * c.d_model
        attn = 2 * c.d_model * d_inner + d_inner * c.d_model
        ffn = 0
    if cross:
        attn *= 2
    return attn + ffn + 2 * c.d_model


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "whisper-base",
    "dbrx-132b",
    "qwen2-vl-72b",
    "granite-20b",
    "nemotron-4-15b",
    "zamba2-1.2b",
    "olmoe-1b-7b",
    "xlstm-125m",
    "qwen2-1.5b",
    "phi4-mini-3.8b",
    "calo3dgan",                 # the paper's own architecture
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.config()


def reduced_config(arch_id: str) -> ArchConfig:
    """Reduced (smoke-test) variant of the same family: <=2 layers,
    d_model<=512, <=4 experts."""
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    if hasattr(mod, "reduced"):
        return mod.reduced()
    c = mod.config()
    kw = dict(
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(c.n_kv_heads, 4) if c.n_kv_heads > 1 else 1,
        d_head=64,
        d_ff=512 if c.d_ff else 0,
        vocab=512,
        n_encoder_layers=2 if c.is_encoder_decoder else 0,
    )
    if c.mrope:
        kw["mrope_sections"] = (8, 12, 12)      # sums to d_head//2 = 32
    if c.moe is not None:
        kw["moe"] = dataclasses.replace(
            c.moe, n_experts=4, top_k=min(c.moe.top_k, 2), d_ff_expert=256)
    if c.ssm is not None:
        kw["ssm"] = dataclasses.replace(c.ssm, state_dim=32, head_dim=32, chunk=64)
    if c.layer_pattern:
        kw["layer_pattern"] = c.layer_pattern[:2]
    if c.shared_attn_every:
        kw["shared_attn_every"] = 2
    return dataclasses.replace(c, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
