"""xlstm-125m [ssm]: alternating sLSTM + mLSTM blocks, no separate FFN.
[arXiv:2405.04517]"""
from repro.configs.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    # pattern: mostly mLSTM with sLSTM at positions 3 and 9 (paper's 1:3-ish mix)
    pattern = "".join("s" if i in (3, 9) else "x" for i in range(12))
    return ArchConfig(
        arch_id="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,                     # block-internal projection instead of FFN
        vocab=50_304,
        source="arXiv:2405.04517",
        norm_type="layernorm",
        ssm=SSMConfig(state_dim=192, head_dim=192, expand=2, chunk=256),
        layer_pattern=pattern,
        subquadratic=True,          # recurrent-state decode
    )


def reduced() -> ArchConfig:
    import dataclasses
    c = config()
    return dataclasses.replace(
        c, n_layers=2, d_model=256, n_heads=2, n_kv_heads=2, vocab=512,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=64),
        layer_pattern="xs")
