"""qwen2-vl-72b [vlm]: M-RoPE, dynamic resolution; ViT frontend stubbed.
[arXiv:2409.12191]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29_568,
        vocab=152_064,
        source="arXiv:2409.12191",
        ffn_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope=True,
        mrope_sections=(16, 24, 24),   # t/h/w split of rotary half-dim (=64)
    )
