"""dbrx-132b [moe]: 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10_752,
        vocab=100_352,
        source="hf:databricks/dbrx-base",
        ffn_type="swiglu",
        rope_theta=500_000.0,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10_752),
    )
