"""granite-20b [dense]: llama-arch code model, MQA (kv=1). [arXiv:2405.04324]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        arch_id="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,               # multi-query attention
        d_ff=24_576,
        vocab=49_152,
        source="arXiv:2405.04324",
        ffn_type="gelu",            # granite-20b-code uses gelu MLP
        norm_type="layernorm",
        qkv_bias=True,
        rope_theta=10_000.0,
    )
