"""Offline conv3d tile autotuner — measure once, every process benefits.

Sweeps the tile-candidate space (`kernels/conv3d/tiles.candidate_tiles`)
for every conv signature the 3DGAN hot path hits (forward, and with
``--train`` also the dx/dw backward signatures), TIMES each candidate on
the live device, and persists the winners to the on-disk cache under
``results/autotune/<device_kind>.json``.  `tiles.get_tiles` warm-loads
that cache on first use, so training, serving and the benchmarks all pick
the tuned tiles up automatically — no call-site changes.

The cache makes the sweep idempotent: a SECOND run performs ZERO
measurements (every signature hits the cache), which is also this CLI's
self-check — it prints the measurement count and exits nonzero if
``--expect-cached`` is given but anything had to be measured.

  PYTHONPATH=src python tools/autotune_conv3d.py \
      [--config bench|reduced|full] [--dtype float32 bfloat16] [--train]
      [--steps 3] [--cache-dir results/autotune] [--expect-cached]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="bench",
                    choices=("bench", "reduced", "full"))
    ap.add_argument("--dtype", nargs="+", default=["float32", "bfloat16"])
    ap.add_argument("--train", action="store_true",
                    help="also tune the backward (dx/dw) signatures")
    ap.add_argument("--steps", type=int, default=3,
                    help="timed executions per candidate")
    ap.add_argument("--cache-dir", default="",
                    help="override the results/autotune cache directory")
    ap.add_argument("--expect-cached", action="store_true",
                    help="exit 1 if any signature needed measuring "
                         "(the warm-start assertion)")
    ap.add_argument("--json", default="", help="also dump the report here")
    args = ap.parse_args(argv)

    import jax.numpy as jnp
    from repro.configs import calo3dgan
    from repro.kernels.conv3d import tiles as tiles_lib

    cfg = {"bench": calo3dgan.bench, "reduced": calo3dgan.reduced,
           "full": calo3dgan.config}[args.config]()
    cache_dir = args.cache_dir or None
    total = {"measured": 0, "cached": 0, "entries": []}
    for dtype_name in args.dtype:
        dtype = jnp.dtype(dtype_name)
        rep = tiles_lib.autotune_config(cfg, dtype, steps=args.steps,
                                        cache_dir=cache_dir,
                                        train=args.train)
        total["measured"] += rep["measured"]
        total["cached"] += rep["cached"]
        total["entries"] += rep["entries"]
        print(f"[{dtype_name}] {rep['cached']} cached signatures, "
              f"{rep['measured']} measurements "
              f"(device={rep['device_kind']})")
    for e in total["entries"]:
        t = e["tiles"]
        mark = "cache" if e["measurements"] == 0 else f"{e['measurements']}x"
        print(f"  {e['signature']:<42} -> bn={t['bn']:<4} "
              f"fuse_taps={t['fuse_taps']} [{mark}]")
    print(f"cache: {tiles_lib.cache_path(cache_dir=cache_dir)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(total, f, indent=1)
    if args.expect_cached and total["measured"]:
        print(f"EXPECTED warm cache but measured {total['measured']} "
              "candidates", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
