#!/usr/bin/env python
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
"""2x2 virtual-topology parity: grad-reduce strategies + ZeRO-1 optimizer.

Folds 4 virtual CPU devices into a ``(node=2, device=2)`` mesh (so both
collective levels are REAL multi-participant reductions) and runs the
reduced 3DGAN a few steps under every (loop, grad_reduce) combination —
flat psum-mean, hierarchical (intra-node psum + bucketed inter-node
psums), and overlap (reverse-order buckets issued from inside the
backward pass, `parallel/collectives.OverlapReduce`).  Every strategy
must match flat to f32 summation-order tolerance for BOTH engine loops.

A second gate trains the custom loop with the ZeRO-1 sharded optimizer
(`optim.optimizers.zero1`: reduce-scatter-style sharded update +
all-gather, master/optimizer state partitioned over the mesh axes) and
pins its trajectory to the replicated-optimizer run.

This is the fail-fast gate CI's scaleout-smoke job runs so topology or
sharded-state regressions never reach a pod.

  PYTHONPATH=src python tools/parity_scaleout.py   # exit 0 on parity
"""

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

STEPS = 2
TOL = 2e-5          # f32 summation-order rounding across 4 replicas


def _max_diff(a, b):
    import jax
    import numpy as np
    leaves = zip(
        jax.tree.leaves(a.g_params) + jax.tree.leaves(a.d_params),
        jax.tree.leaves(b.g_params) + jax.tree.leaves(b.d_params))
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in leaves)


def main():
    import jax

    from repro.configs import calo3dgan
    from repro.data.calo import CaloSimulator, CaloSpec
    from repro.launch.mesh import make_node_mesh
    from repro.optim import optimizers as opt_lib
    from repro.train import engine as engine_lib

    assert len(jax.devices()) >= 4, jax.devices()
    cfg = calo3dgan.reduced()
    mesh = make_node_mesh(2, 2)
    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=3)
    batches = [next(sim.batches(8)) for _ in range(STEPS)]

    def train(loop, strat, make_opt):
        task = engine_lib.gan_task(cfg, make_opt(), make_opt())
        eng = engine_lib.Engine(mesh, loop, dp_axes=("node", "device"),
                                grad_reduce=strat, bucket_mb=0.05)
        state = eng.init_state(task, jax.random.key(0))
        step = eng.compile_step(task, batches[0])
        rng = jax.random.key(1)
        for b in batches:
            rng, k = jax.random.split(rng)
            state, _ = step(state, b, k)
        return state

    rmsprop = lambda: opt_lib.rmsprop(1e-4)
    states = {(loop, strat): train(loop, strat, rmsprop)
              for loop in ("builtin", "custom")
              for strat in ("flat", "hierarchical", "overlap")}

    failed = False
    for loop in ("builtin", "custom"):
        for strat in ("hierarchical", "overlap"):
            diff = _max_diff(states[(loop, "flat")], states[(loop, strat)])
            ok = diff <= TOL
            failed |= not ok
            print(f"{loop:>8} loop: flat-vs-{strat} max param diff after "
                  f"{STEPS} steps on (node=2, device=2): {diff:.2e} "
                  f"[{'OK' if ok else 'FAIL'} tol={TOL:g}]")
    if failed:
        return 1
    print("parity OK: hierarchical and overlap grad-reduce match flat "
          "psum on the 2x2 virtual topology for both engine loops")

    zero1 = lambda: opt_lib.zero1(opt_lib.rmsprop(1e-4), 4,
                                  axis=("node", "device"))
    z_state = train("custom", "flat", zero1)
    diff = _max_diff(states[("custom", "flat")], z_state)
    ok = diff <= TOL
    print(f"  custom loop: replicated-vs-zero1 optimizer max param diff "
          f"after {STEPS} steps: {diff:.2e} "
          f"[{'OK' if ok else 'FAIL'} tol={TOL:g}]")
    if not ok:
        return 1
    print("zero1 parity OK: sharded optimizer matches the replicated "
          "update on the 2x2 virtual topology")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
