"""Offline multi-kernel autotuner — one sweep, every Pallas family.

Generalises ``tools/autotune_conv3d.py`` over the shared autotune
substrate (:mod:`repro.kernels.autotune`): for each requested family it
enumerates the signatures the training configs hit, TIMES the
candidate schedules on the live device, and persists the winners to the
on-disk cache under ``results/autotune/<device_kind>.json``.  Every
kernel wrapper warm-loads that cache on first use, so training, serving
and the benchmarks pick the tuned schedules up automatically.

- ``conv3d``: the 3DGAN generator/discriminator conv signatures
  (forward, plus dx/dw backward with ``--train``), via
  ``kernels/conv3d/tiles.autotune_config`` — unchanged behavior.
- ``attn``: the flash-attention (block_q, block_kv) signatures of an LM
  config at ``--seq-len``.
- ``ssm``: the SSD-scan chunk signatures of a hybrid (Mamba2) config at
  ``--seq-len``.
- ``decode``: the serving flash-decode (block_kv, num_splits) signatures
  of an LM config AND a hybrid config at ``--max-len`` cache capacity
  with ``--slots`` batch rows.

The cache makes the sweep idempotent: a SECOND run performs ZERO
measurements (every signature hits the cache), which is also this CLI's
self-check — it prints the measurement count and exits nonzero if
``--expect-cached`` is given but anything had to be measured.

  PYTHONPATH=src python tools/autotune_kernels.py \
      [--families conv3d attn ssm decode] [--dtype float32 bfloat16] \
      [--config bench|reduced|full] [--arch qwen2-1.5b] \
      [--ssm-arch zamba2-1.2b] [--seq-len 128] [--max-len 256] \
      [--slots 4] [--train] [--steps 3] \
      [--cache-dir results/autotune] [--expect-cached]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

FAMILIES = ("conv3d", "attn", "ssm", "decode")


def _tune_signatures(sigs, steps, cache_dir):
    """Drive ``autotune_signature`` over a list; return the report."""
    from repro.kernels import autotune as autotune_lib

    rep = {"measured": 0, "cached": 0, "entries": []}
    for sig in sigs:
        best, n = autotune_lib.autotune_signature(sig, steps=steps,
                                                  cache_dir=cache_dir)
        rep["measured"] += n
        rep["cached"] += int(n == 0)
        rep["entries"].append({
            "signature": autotune_lib._sig_to_str(sig),
            "schedule": dataclasses.asdict(best),
            "measurements": n,
        })
    return rep


def _conv3d_report(args, dtype, cache_dir):
    from repro.configs import calo3dgan
    from repro.kernels.conv3d import tiles as tiles_lib

    cfg = {"bench": calo3dgan.bench, "reduced": calo3dgan.reduced,
           "full": calo3dgan.config}[args.config]()
    rep = tiles_lib.autotune_config(cfg, dtype, steps=args.steps,
                                    cache_dir=cache_dir, train=args.train)
    for e in rep["entries"]:
        e["schedule"] = e.pop("tiles")
    return rep


def _attn_report(args, dtype, cache_dir):
    from repro.configs import base as config_base
    from repro.kernels.flash_attention import tune as tune_lib

    cfg = config_base.reduced_config(args.arch)
    sigs = tune_lib.model_signatures(cfg, args.seq_len, dtype)
    return _tune_signatures(sigs, args.steps, cache_dir)


def _ssm_report(args, dtype, cache_dir):
    from repro.configs import base as config_base
    from repro.kernels.ssm_scan import tune as tune_lib

    cfg = config_base.reduced_config(args.ssm_arch)
    sigs = tune_lib.model_signatures(cfg, args.seq_len, dtype)
    return _tune_signatures(sigs, args.steps, cache_dir)


def _decode_report(args, dtype, cache_dir):
    from repro.configs import base as config_base
    from repro.kernels.flash_attention import decode as decode_lib

    sigs = []
    for arch in (args.arch, args.ssm_arch):
        cfg = config_base.reduced_config(arch)
        sigs += decode_lib.model_signatures(cfg, args.max_len,
                                            batch=args.slots, dtype=dtype)
    return _tune_signatures(sigs, args.steps, cache_dir)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--families", nargs="+", default=list(FAMILIES),
                    choices=FAMILIES)
    ap.add_argument("--dtype", nargs="+", default=["float32", "bfloat16"])
    ap.add_argument("--config", default="bench",
                    choices=("bench", "reduced", "full"),
                    help="3DGAN config for the conv3d family")
    ap.add_argument("--arch", default="qwen2-1.5b",
                    help="LM arch for the attn family (reduced config)")
    ap.add_argument("--ssm-arch", default="zamba2-1.2b",
                    help="hybrid arch for the ssm family (reduced config)")
    ap.add_argument("--seq-len", type=int, default=128,
                    help="training sequence length for attn/ssm signatures")
    ap.add_argument("--max-len", type=int, default=256,
                    help="serving cache capacity for decode signatures")
    ap.add_argument("--slots", type=int, default=4,
                    help="serving slot count (decode batch rows)")
    ap.add_argument("--train", action="store_true",
                    help="also tune the conv3d backward (dx/dw) signatures")
    ap.add_argument("--steps", type=int, default=3,
                    help="timed executions per candidate")
    ap.add_argument("--cache-dir", default="",
                    help="override the results/autotune cache directory")
    ap.add_argument("--expect-cached", action="store_true",
                    help="exit 1 if any signature needed measuring "
                         "(the warm-start assertion)")
    ap.add_argument("--json", default="", help="also dump the report here")
    args = ap.parse_args(argv)

    import jax.numpy as jnp

    from repro.kernels import autotune as autotune_lib

    runners = {"conv3d": _conv3d_report, "attn": _attn_report,
               "ssm": _ssm_report, "decode": _decode_report}
    total = {"measured": 0, "cached": 0, "entries": []}
    for family in args.families:
        for dtype_name in args.dtype:
            rep = runners[family](args, jnp.dtype(dtype_name),
                                  args.cache_dir or None)
            total["measured"] += rep["measured"]
            total["cached"] += rep["cached"]
            total["entries"] += rep["entries"]
            print(f"[{family}/{dtype_name}] {rep['cached']} cached "
                  f"signatures, {rep['measured']} measurements")
    for e in total["entries"]:
        mark = "cache" if e["measurements"] == 0 else f"{e['measurements']}x"
        sched = ",".join(f"{k}={v}" for k, v in e["schedule"].items())
        print(f"  {e['signature']:<48} -> {sched} [{mark}]")
    print(f"cache: {autotune_lib.cache_path(cache_dir=args.cache_dir or None)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(total, f, indent=1)
    if args.expect_cached and total["measured"]:
        print(f"EXPECTED warm cache but measured {total['measured']} "
              "candidates", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
