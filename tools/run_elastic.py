#!/usr/bin/env python
import argparse
import json
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="results/elastic_trace.json",
                    help="committed FaultPlan JSON to replay")
    ap.add_argument("--steps", type=int, default=0,
                    help="global steps (0: read from the trace's meta)")
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual host devices (XLA_FLAGS, set pre-import)")
    ap.add_argument("--loop", default="builtin",
                    choices=("builtin", "custom"))
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--budget", type=float, default=40.0,
                    help="USD budget for the planner recommend() replay")
    ap.add_argument("--deadline", type=float, default=2e5,
                    help="deadline (s) for the planner recommend() replay")
    ap.add_argument("--loss-tol", type=float, default=2e-5,
                    help="max |faulted - clean| final-loss gap (--check)")
    ap.add_argument("--kl-tol", type=float, default=0.05,
                    help="max per-profile KL gap vs the clean run (--check)")
    ap.add_argument("--out", default="results/BENCH_elastic.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when a physics/loss gate fails")
    return ap.parse_args(argv)


ARGS = parse_args()
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={ARGS.devices} "
    + os.environ.get("XLA_FLAGS", ""))
"""Elastic-training driver: execute a planner schedule through a fault trace.

The end-to-end §5.1 story in one command: take the cost frontier's
preemptible recommendation, run the 3DGAN on a virtual ``(node, device)``
topology while replaying a committed preemption trace
(``results/elastic_trace.json``) through `train/faults.FaultInjector`,
and measure what elasticity actually costs:

- an UNINTERRUPTED run and the FAULTED run (same seed, same data replay,
  same checkpoint cadence) — final losses and physics-validation KLs are
  compared directly, the "zero lost physics" gate;
- lost steps / recovery seconds / checkpoint fallbacks / re-meshes from
  the `train/elastic.ElasticEngine` report;
- the measured overhead fraction folded back into the cost frontier
  (`cloud/planner.apply_elastic_overhead`) and ``recommend()`` re-asked —
  does preemptible capacity still win after paying for recovery?

Writes ``results/BENCH_elastic.json``; ``--check`` turns the loss + KL
comparisons into exit status for CI (elastic-smoke job).

  PYTHONPATH=src python tools/run_elastic.py --check
"""

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def main(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import calo3dgan
    from repro.core import gan, validation
    from repro.data.calo import CaloSimulator, CaloSpec
    from repro.cloud import planner
    from repro.train import faults
    from repro.train.elastic import ElasticEngine
    from repro.optim import optimizers as opt_lib
    from repro.train import engine as engine_lib

    assert len(jax.devices()) >= args.devices, jax.devices()

    with open(args.trace) as f:
        trace_meta = json.load(f)
    plan = faults.FaultPlan.from_json(trace_meta)
    steps = args.steps or int(trace_meta.get("steps", 12))
    nodes, dpn = trace_meta.get("topology", [2, 2])
    batch = int(trace_meta.get("global_batch", 8))
    cfg = calo3dgan.bench()
    spec = CaloSpec(image_shape=cfg.image_shape)
    rng = jax.random.key(1)

    def make_batches(start):
        # fresh seeded sim + skip: the stream from global step `start` on
        # is EXACTLY what an uninterrupted run would have seen
        return CaloSimulator(spec, seed=11).batches(batch, skip=start)

    def run(tmp, injector):
        task = engine_lib.gan_task(cfg, opt_lib.rmsprop(1e-4),
                                   opt_lib.rmsprop(1e-4))
        eng = ElasticEngine(nodes, dpn, loop=args.loop, ckpt_dir=tmp,
                            ckpt_every=args.ckpt_every, keep=args.keep)
        t0 = time.perf_counter()
        state, report = eng.fit(task, make_batches, steps, rng=rng,
                                injector=injector)
        jax.block_until_ready(state)
        return state, report, time.perf_counter() - t0

    def physics(state):
        mc = next(CaloSimulator(spec, seed=77).batches(256))
        noise = jax.random.normal(jax.random.key(7), (256, cfg.latent_dim))
        fake = gan.generate(state.g_params, noise, jnp.asarray(mc["e_p"]),
                            jnp.asarray(mc["theta"]), cfg)
        return validation.validation_report(np.asarray(fake), mc["image"],
                                            np.asarray(mc["e_p"]),
                                            mc["e_p"])

    import tempfile
    with tempfile.TemporaryDirectory() as td:
        print(f"[clean] {steps} steps on {nodes}x{dpn} ({args.loop} loop)")
        clean_state, clean_rep, clean_s = run(os.path.join(td, "clean"),
                                              None)
        print(f"[clean] {clean_s:.1f}s  "
              f"losses={_losses(clean_rep['metrics'])}")
        print(f"[faulted] replaying {args.trace}: "
              f"{[ (e.step, e.kind) for e in plan.events ]}")
        injector = faults.FaultInjector(plan)
        faulted_state, rep, faulted_s = run(os.path.join(td, "faulted"),
                                            injector)
        print(f"[faulted] {faulted_s:.1f}s  losses="
              f"{_losses(rep['metrics'])}  recoveries="
              f"{rep['preemptions']} (remesh {rep['remeshes']}, restart "
              f"{rep['restarts']}), lost {rep['lost_steps']} steps, "
              f"recovery {rep['recovery_s'] * 1e3:.0f}ms, "
              f"ckpt fallbacks {rep['fallbacks']}")
        unfired = [e for e in plan.events if e not in injector.fired]
        if unfired:
            print(f"WARNING: {len(unfired)} trace events never fired: "
                  f"{unfired}")

        loss_diff = max(abs(float(rep["metrics"][k])
                            - float(clean_rep["metrics"][k]))
                        for k in ("g_loss", "d_loss_real", "d_loss_fake"))
        clean_phys, faulted_phys = physics(clean_state), physics(
            faulted_state)
        kl_keys = [k for k in clean_phys if k.endswith("_kl")]
        kl_diff = max(abs(faulted_phys[k] - clean_phys[k]) for k in kl_keys)
        print(f"final-loss gap {loss_diff:.2e} (tol {args.loss_tol:g}); "
              f"physics-KL gap {kl_diff:.2e} (tol {args.kl_tol:g})")

    # -- fold the measured overhead back into the planner -------------------
    overhead = max(faulted_s / clean_s - 1.0, 0.0)
    frontier = planner.cost_frontier(5200.0)
    rec = planner.recommend(frontier, args.budget, args.deadline)
    derated = planner.apply_elastic_overhead(frontier, overhead)
    rec_el = planner.recommend(derated, args.budget, args.deadline)
    for tag, r in (("naive", rec), ("elastic-aware", rec_el)):
        print(f"recommend[{tag}]: "
              + (f"{r['device']} x{r['n']} ${r['total_cost_usd']:.2f}"
                 if r else "infeasible"))

    payload = {
        "bench": "elastic", "loop": args.loop, "steps": steps,
        "topology": [nodes, dpn], "trace": os.path.basename(args.trace),
        "rows": {
            "clean_s": clean_s, "faulted_s": faulted_s,
            "overhead_frac": overhead,
            "recovery_s": rep["recovery_s"],
            "lost_steps": rep["lost_steps"],
            "preemptions": rep["preemptions"],
            "remeshes": rep["remeshes"],
            "restarts": rep["restarts"],
            "ckpt_fallbacks": rep["fallbacks"],
            "ckpt_saved": rep["ckpt_stats"]["saved"],
            "loss_diff": loss_diff, "kl_diff": kl_diff,
        },
        "recommend": {
            "budget_usd": args.budget, "deadline_s": args.deadline,
            "naive": rec, "elastic_aware": rec_el,
        },
        "physics": {"clean": clean_phys, "faulted": faulted_phys},
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[wrote {args.out}]")

    if args.check:
        ok = (loss_diff <= args.loss_tol and kl_diff <= args.kl_tol
              and rep["lost_steps"] <= steps and not unfired)
        print("elastic gate:", "OK" if ok else "FAIL")
        return 0 if ok else 1
    return 0


def _losses(metrics):
    return {k: round(float(v), 5) for k, v in metrics.items()
            if k.endswith("loss") or "_loss_" in k}


if __name__ == "__main__":
    raise SystemExit(main(ARGS))
