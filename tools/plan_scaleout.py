#!/usr/bin/env python
"""Cloud scale-out planner CLI (paper Fig. 2 + Fig. 5, answered offline).

Replays the committed measured baselines (``results/BENCH_fig1_loop.json``)
through the topology-aware interconnect model and the GCP price table:

  PYTHONPATH=src python tools/plan_scaleout.py --results results
  PYTHONPATH=src python tools/plan_scaleout.py --budget 5 --deadline 600
  PYTHONPATH=src python tools/plan_scaleout.py --grad-reduce flat

Prints (1) the predicted Fig. 2 weak-scaling curve for V100 nodes ×
{1..16} from the measured single-node anchor, (2) the Fig. 5 cost/epoch
frontier with planner-derived efficiencies (nothing tabulated), and
(3) a ``recommend(budget, deadline)`` answer when both are given.
Exit code 1 when a recommendation is requested but infeasible.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.cloud import planner  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results",
                    help="dir with BENCH_fig1_loop.json (measured anchor)")
    ap.add_argument("--grad-reduce", default="overlap",
                    choices=("flat", "hierarchical", "overlap"))
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--base-epoch-s", type=float, default=5200.0,
                    help="paper's measured 2-GPU epoch anchor for the "
                         "cost table (seconds)")
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--budget", type=float, default=0.0,
                    help="USD budget for the recommend() query")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="wall-clock deadline (s) for recommend()")
    ap.add_argument("--elastic", action="store_true",
                    help="derate preemptible rows by the measured elastic "
                         "overhead (results/BENCH_elastic.json, recorded "
                         "by tools/run_elastic.py) before recommending")
    ap.add_argument("--out", default="", help="also write plan JSON here")
    args = ap.parse_args(argv)
    bucket_bytes = int(args.bucket_mb * (1 << 20))

    anchor = planner.load_anchor(args.results)
    print(f"measured anchor: {anchor.step_s * 1e3:.1f} ms/step at global "
          f"batch {anchor.global_batch} ({anchor.loop} loop, "
          f"{anchor.source})")

    print(f"\nFig. 2 — predicted weak scaling, V100 nodes x 8 GPUs "
          f"({args.grad_reduce} reduce, {args.bucket_mb:g} MiB buckets):")
    curve = planner.weak_scaling_curve(anchor, strategy=args.grad_reduce,
                                       bucket_bytes=bucket_bytes)
    print(f"{'topology':>10} {'devices':>8} {'step_s':>9} {'comm_ms':>9} "
          f"{'epoch_s':>9} {'eff':>6}")
    for r in curve:
        print(f"{r['topology']:>10} {r['devices']:>8} "
              f"{r['step_s_pred']:>9.3f} {r['comm_s_pred'] * 1e3:>9.3f} "
              f"{r['epoch_s_pred']:>9.1f} {r['efficiency_pred']:>6.3f}")

    print(f"\nFig. 5 — cost/epoch frontier (efficiencies derived from the "
          f"measured base step + interconnect model):")
    frontier = planner.cost_frontier(
        args.base_epoch_s, strategy=args.grad_reduce,
        bucket_bytes=bucket_bytes,
        tpu_epochs={"v3-8": 480.0, "v2-8": 1056.0, "v3-32": None})
    print(f"{'device':>16} {'n':>4} {'epoch_s':>9} {'cost_usd':>9}")
    for r in frontier:
        print(f"{r['device']:>16} {r['n']:>4} {r['epoch_s']:>9.0f} "
              f"{r['cost_usd']:>9.2f}")
    eff64 = next(r["efficiency"] for r in frontier
                 if r["device"] == "V100" and r["n"] == 64)
    print(f"predicted weak-scaling efficiency at 64 GPUs: {eff64:.4f} "
          "(measured step + interconnect model, no efficiency table)")

    if args.elastic:
        el = planner.load_elastic(args.results)
        if el is None:
            print("\n--elastic: no results/BENCH_elastic.json — run "
                  "tools/run_elastic.py first (frontier unchanged)")
        else:
            frontier = planner.apply_elastic_overhead(
                frontier, el["overhead_frac"])
            print(f"\nelastic overhead applied to preemptible rows: "
                  f"+{el['overhead_frac']:.1%} (measured, {el['source']})")

    rec = None
    if args.budget or args.deadline:
        budget = args.budget or float("inf")
        deadline = args.deadline or float("inf")
        rec = planner.recommend(frontier, budget, deadline,
                                epochs=args.epochs)
        if rec is None:
            print(f"\nrecommend: NO offering trains {args.epochs} epoch(s) "
                  f"within ${budget:g} and {deadline:g}s")
        else:
            print(f"\nrecommend: {rec['device']} x{rec['n']} — "
                  f"{rec['total_time_s']:.0f}s, "
                  f"${rec['total_cost_usd']:.2f} for {args.epochs} epoch(s)")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"anchor": anchor.__dict__, "weak_scaling": curve,
                       "cost_frontier": frontier, "recommend": rec},
                      f, indent=2, default=str)
        print(f"[wrote {args.out}]")
    if (args.budget or args.deadline) and rec is None:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
