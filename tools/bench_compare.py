"""Perf-regression gate: diff fresh BENCH_*.json against the committed ones.

The ``results/BENCH_*.json`` files are the repo's perf trajectory — every
PR re-records them, so a regression in step time or serving throughput is
visible in the diff.  This tool makes that gate mechanical:

- **time-like metrics** (keys ending ``_ms`` / ``_s``, plus ``step_ms``
  rows): a regression is FRESH > BASELINE * (1 + tol);
- **rate-like metrics** (``events_per_s``, ``samples_per_s``,
  ``*_speedup``, ``speedup``): a regression is FRESH < BASELINE * (1 - tol);
- **ratio metrics** (``*efficiency*``: lower is worse; ``*_frac`` —
  exposed-comm / overhead fractions: higher is worse): dimensionless and
  machine-normalized, so they are gated even under ``--relative-only``.

Rows are matched by their identity fields (non-numeric values like
``layer`` / ``global_batch``), so re-ordered rows still compare.  Metrics
present on only one side are reported but never fail the gate (benchmarks
grow columns over time).  Exits nonzero when any metric regresses by more
than ``--tol`` (default 0.10 = the 10% gate).

  PYTHONPATH=src python tools/bench_compare.py \
      --fresh results.fresh --baseline results [--tol 0.10] \
      [--only kernel_conv3d serve_fastsim]

CI runs the conv3d micro-bench into a scratch directory and compares it
back against the committed baseline with a container-noise-friendly
tolerance (see .github/workflows/ci.yml, perf-smoke job).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

RATE_KEYS = ("events_per_s", "samples_per_s", "tok_per_s")
SKIP_KEYS = ("seconds", "train_s", "compile_s")   # harness time, not perf


def _is_rate(key: str) -> bool:
    return key in RATE_KEYS or key.endswith("speedup")


def _is_time(key: str) -> bool:
    return (key.endswith("_ms") or key.endswith("_s")) \
        and key not in SKIP_KEYS


def _is_higher_better_ratio(key: str) -> bool:
    return "efficiency" in key


def _is_lower_better_ratio(key: str) -> bool:
    return key.endswith("_frac")


def _row_identity(row: dict):
    """Identity of a row = its non-numeric (label-like) fields."""
    ident = []
    for k in sorted(row):
        v = row[k]
        if isinstance(v, (str, bool)) or k in ("global_batch", "batch",
                                               "ci", "co", "stride"):
            ident.append((k, str(v)))
    return tuple(ident)


def _rows(payload: dict):
    """Normalise a BENCH payload to {identity: {metric: value}} plus the
    payload-level summary dicts (tile_summary etc.)."""
    out = {}
    rows = payload.get("rows")
    if isinstance(rows, dict):            # single-report benchmarks
        out[(("row", "summary"),)] = rows
    elif isinstance(rows, list):
        for i, row in enumerate(rows):
            if isinstance(row, dict):
                out[_row_identity(row) or (("idx", str(i)),)] = row
    for k, v in payload.items():
        if k != "rows" and isinstance(v, dict):
            out[(("section", k),)] = v
    return out


def compare_file(name: str, fresh: dict, base: dict, tol: float,
                 relative_only: bool = False):
    """Yields (identity, key, base, fresh, rel_change, is_regression)."""
    f_rows, b_rows = _rows(fresh), _rows(base)
    for ident, b_row in b_rows.items():
        f_row = f_rows.get(ident)
        if f_row is None:
            continue                      # row vanished: layout change
        for key, b_val in b_row.items():
            if not isinstance(b_val, (int, float)) or isinstance(b_val, bool):
                continue
            f_val = f_row.get(key)
            if not isinstance(f_val, (int, float)) or b_val == 0:
                continue
            rel = (f_val - b_val) / abs(b_val)
            # rate check FIRST: rate keys like events_per_s also end in
            # "_s" and would otherwise match the time rule inverted
            if _is_rate(key):
                # throughputs are machine-specific too; speedup ratios
                # (pallas-vs-lax, tuned-vs-default) are not
                if relative_only and not key.endswith("speedup"):
                    continue
                worse = rel < -tol
            elif _is_higher_better_ratio(key):
                worse = rel < -tol        # dimensionless: gated always
            elif _is_lower_better_ratio(key):
                worse = rel > tol
            elif _is_time(key):
                if relative_only:         # absolute ms: machine-specific
                    continue
                worse = rel > tol
            else:
                continue
            yield ident, key, b_val, f_val, rel, worse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory (or single file) of fresh BENCH json")
    ap.add_argument("--baseline", default="results",
                    help="committed results directory (or single file)")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="relative regression tolerance (0.10 = 10%%)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to these benchmark names")
    ap.add_argument("--relative-only", action="store_true",
                    help="compare only machine-normalized ratio metrics "
                         "(speedups, rates-of-rates) and skip absolute "
                         "wall-clock ms — for diffing runs from "
                         "DIFFERENT machines (e.g. CI runners vs the "
                         "recorded baseline host)")
    args = ap.parse_args(argv)

    if os.path.isfile(args.fresh):
        fresh_files = [args.fresh]
    else:
        fresh_files = sorted(glob.glob(os.path.join(args.fresh,
                                                    "BENCH_*.json")))
    n_regressions = n_metrics = 0
    for fpath in fresh_files:
        name = os.path.basename(fpath)[len("BENCH_"):-len(".json")]
        if args.only and name not in args.only:
            continue
        bpath = (args.baseline if os.path.isfile(args.baseline)
                 else os.path.join(args.baseline, os.path.basename(fpath)))
        if not os.path.exists(bpath):
            print(f"[{name}] no baseline at {bpath} — skipped")
            continue
        with open(fpath) as f:
            fresh = json.load(f)
        with open(bpath) as f:
            base = json.load(f)
        rows = list(compare_file(name, fresh, base, args.tol,
                                 relative_only=args.relative_only))
        worse = [r for r in rows if r[-1]]
        n_metrics += len(rows)
        n_regressions += len(worse)
        status = f"{len(worse)} regressions / {len(rows)} compared"
        print(f"[{name}] {status}")
        for ident, key, b, fv, rel, _ in worse:
            label = " ".join(f"{k}={v}" for k, v in ident)
            print(f"  REGRESSION {label} :: {key}: "
                  f"{b:.3f} -> {fv:.3f} ({rel:+.0%})")
    print(f"\nbench_compare: {n_regressions} regressions over "
          f"{n_metrics} metrics (tol {args.tol:.0%})")
    return 1 if n_regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
