"""Docs hygiene gate (CI: the docs-and-examples job).

Two checks, both fail-fast with a non-zero exit:

1. every module under src/repro has a module docstring (the repo's API
   surface is documented at module granularity — see README / paper_map);
2. every relative markdown link in docs/*.md and README.md resolves to a
   real file in the repo (external http(s) links and pure #anchors are
   skipped).

  python tools/check_docs.py
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# [text](target) — target captured up to the closing paren; images included
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def missing_docstrings() -> list:
    bad = []
    for p in sorted((ROOT / "src" / "repro").rglob("*.py")):
        try:
            tree = ast.parse(p.read_text())
        except SyntaxError as e:
            bad.append(f"{p.relative_to(ROOT)}: SYNTAX ERROR {e}")
            continue
        if not ast.get_docstring(tree):
            bad.append(f"{p.relative_to(ROOT)}: missing module docstring")
    return bad


def broken_links() -> list:
    bad = []
    files = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    for md in files:
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                bad.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return bad


def main() -> int:
    problems = missing_docstrings() + broken_links()
    for p in problems:
        print(f"FAIL {p}")
    if problems:
        print(f"\n{len(problems)} docs problem(s)")
        return 1
    n_mod = len(list((ROOT / "src" / "repro").rglob("*.py")))
    print(f"docs OK: {n_mod} modules documented, all markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
