"""Distribution layer: logical-axis spec resolution, collective-traffic HLO
parsing (incl. while-loop scaling), jaxpr cost analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import collectives, sharding
from repro.parallel.jaxpr_cost import cost_of, jaxpr_cost


def _mesh2(data=2, model=1):
    devs = np.array(jax.devices()[:1] * (data * model)).reshape(data, model)
    return Mesh(devs, ("data", "model"))


# ---------------------------------------------------------------------------
# resolve_spec
# ---------------------------------------------------------------------------


def test_resolve_spec_basic():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = sharding.resolve_spec(("embed", "heads"), (64, 64), mesh,
                                 sharding.FSDP_TP_RULES)
    assert spec == P("data", "model")


def test_resolve_spec_drops_non_dividing_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # dim 3 % mesh size 1 == 0 always with size-1 axes; use synthetic rules
    rules = {"x": "data"}
    spec = sharding.resolve_spec(("x",), (3,), mesh, rules)
    assert spec == P("data")        # size-1 axis always divides


def test_resolve_spec_never_reuses_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"a": "model", "b": "model"}
    spec = sharding.resolve_spec(("a", "b"), (8, 8), mesh, rules)
    assert spec == P("model", None)     # second use dropped


def test_resolve_spec_tuple_rule():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"batch": ("pod", "data")}      # pod not in mesh -> filtered
    spec = sharding.resolve_spec(("batch", None), (8, 4), mesh, rules)
    assert spec == P("data", None)


def test_dp_rules_replicate_params():
    """Paper-faithful mirrored strategy: every param spec resolves to fully
    replicated under DP_RULES."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = sharding.resolve_spec(("embed", "heads"), (64, 64), mesh,
                                 sharding.DP_RULES)
    assert spec == P(None, None)


def test_tree_specs_all_leaves_covered():
    from repro.configs import base as config_base
    from repro.models import api
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("qwen2-1.5b", "olmoe-1b-7b", "xlstm-125m", "zamba2-1.2b",
                 "whisper-base"):
        cfg = config_base.reduced_config(arch)
        model = api.get_model(cfg)
        shapes = jax.eval_shape(lambda m=model, c=cfg: m.init(
            jax.random.key(0), c))
        specs = sharding.tree_specs(model.logical_axes(cfg), shapes, mesh,
                                    sharding.FSDP_TP_RULES)
        n = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n == len(jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# collective HLO parsing
# ---------------------------------------------------------------------------

_FAKE_HLO = """\
HloModule test

%body.1 (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %p = (s32[], f32[16,128]) parameter(0)
  %ar = f32[16,128] all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[16,128]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[16,128])) -> pred[] {
  %p2 = (s32[], f32[16,128]) parameter(0)
  %iter = s32[] get-tuple-element(%p2), index=0
  %lim = s32[] constant(12)
  ROOT %cmp = pred[] compare(%iter, %lim), direction=LT
}

ENTRY %main (a: f32[16,128]) -> f32[16,128] {
  %a = f32[16,128] parameter(0)
  %ag = f32[32,128] all-gather(%a), dimensions={0}
  %w = (s32[], f32[16,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[16,128] get-tuple-element(%w), index=1
}
"""


def test_collective_stats_loop_scaling():
    unscaled = collectives.collective_stats(_FAKE_HLO, scale_loops=False)
    scaled = collectives.collective_stats(_FAKE_HLO)
    f32 = 4
    assert unscaled["all-gather"]["bytes"] == 32 * 128 * f32
    assert unscaled["all-reduce"]["bytes"] == 16 * 128 * f32
    # the all-reduce sits in a 12-trip while body
    assert scaled["all-reduce"]["bytes"] == 12 * 16 * 128 * f32
    assert scaled["all-gather"]["bytes"] == unscaled["all-gather"]["bytes"]
    assert scaled["all-reduce"]["count"] == 12


def test_ici_traffic_model():
    stats = {"all-reduce": {"bytes": 1000, "count": 1},
             "all-gather": {"bytes": 1000, "count": 1}}
    t = collectives.ici_traffic_bytes(stats, n_devices=4)
    # ring: AR = 2*(3/4)*b, AG = (3/4)*b
    assert abs(t - (2 * 750 + 750)) < 1e-6


# ---------------------------------------------------------------------------
# jaxpr cost
# ---------------------------------------------------------------------------


def test_jaxpr_cost_plain_matmul():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    stats = cost_of(lambda x, y: x @ y, a, b)
    assert stats["flops"] == 2 * 128 * 256 * 64


def test_jaxpr_cost_scan_multiplies_by_length():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def once(x):
        return x @ x

    def scanned(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    f1 = cost_of(once, a)["flops"]
    f10 = cost_of(scanned, a)["flops"]
    assert f10 == 10 * f1


def test_jaxpr_cost_sees_through_remat_and_grad():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loss(x):
        return jnp.sum(jax.checkpoint(lambda y: jnp.tanh(y @ y))(x))

    f_fwd = cost_of(lambda x: jnp.tanh(x @ x), a)["flops"]
    f_grad = cost_of(jax.grad(loss), a)["flops"]
    # grad with remat: forward + recompute + 2 backward matmuls >= 3x fwd
    assert f_grad >= 3 * f_fwd


def test_jaxpr_cost_conv():
    x = jax.ShapeDtypeStruct((1, 8, 8, 8, 4), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 3, 4, 8), jnp.float32)

    def conv(x_, w_):
        return jax.lax.conv_general_dilated(
            x_, w_, (1, 1, 1), "SAME",
            dimension_numbers=("NDHWC", "DHWIO", "NDHWC"))

    stats = cost_of(conv, x, w)
    assert stats["flops"] == 2 * (8 ** 3) * 27 * 4 * 8


def test_jaxpr_cost_train_step_vs_model_flops():
    """End-to-end: jaxpr flops for a reduced train step within sane bounds
    of the 6*N*D napkin estimate (remat adds ~4/3, attention adds more)."""
    from repro.configs import base as config_base
    from repro.models import api
    from repro.optim import optimizers as opt_lib
    from repro.substrate.precision import get_policy
    from repro.train import steps as steps_lib

    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    opt = opt_lib.adamw(1e-3)
    step = steps_lib.make_train_step(model, cfg, opt, get_policy("f32"))
    B, S = 4, 256
    p_shapes = jax.eval_shape(lambda: model.init(jax.random.key(0), cfg))
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    stats = cost_of(step, p_shapes, o_shapes, batch)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(p_shapes))
    model_flops = 6 * n_params * B * S
    assert model_flops < stats["flops"] < 3 * model_flops
