"""cloud/ as a unit: price-table invariants, EpochCost arithmetic, the
interconnect model's collective algebra, and planner monotonicity /
recommend() behavior — all offline (no jax tracing except gan_rounds)."""
import json
import os

import pytest

from repro.cloud import costs as cost_lib
from repro.cloud import interconnect, planner
from repro.launch.mesh import Link, gpu_topology, tpu_topology

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


# ---------------------------------------------------------------------------
# price table + EpochCost
# ---------------------------------------------------------------------------


def test_preemptible_v100_at_least_3x_cheaper():
    """Paper §5.1: preemptible V100s are >3x cheaper than reserved."""
    assert (cost_lib.PRICES["v100_reserved"]
            / cost_lib.PRICES["v100_preemptible"]) >= 3.0


def test_preemptible_tpu_cheaper_than_reserved():
    for v in ("v2", "v3"):
        assert (cost_lib.PRICES[f"tpu_{v}_8_preemptible"]
                < cost_lib.PRICES[f"tpu_{v}_8_reserved"])


def test_epoch_cost_arithmetic():
    ec = cost_lib.EpochCost("x", 4, epoch_time_s=1800.0, price_per_hour=2.0)
    assert ec.cost == pytest.approx(2.0 * 1800.0 / 3600.0)


def test_gpu_epoch_cost_includes_vm_share_per_8gpu_node():
    a = cost_lib.gpu_epoch_cost(8, 3600.0, preemptible=True)
    b = cost_lib.gpu_epoch_cost(16, 3600.0, preemptible=True)
    per_gpu = cost_lib.PRICES["v100_preemptible"]
    vm = cost_lib.PRICES["n1_vm_per_8gpu"]
    assert a.cost == pytest.approx(8 * per_gpu + vm)
    assert b.cost == pytest.approx(16 * per_gpu + 2 * vm)


def test_scaling_cost_table_accepts_injected_efficiencies():
    eff = {2: 1.0, 8: 0.5}
    rows = cost_lib.scaling_cost_table(1000.0, base_gpus=2,
                                       efficiencies=eff)
    assert [r.n_devices for r in rows] == [2, 8]
    # 8 GPUs at eff 0.5: t = 1000 * 2 / (8 * 0.5)
    assert rows[1].epoch_time_s == pytest.approx(500.0)


def test_scaling_cost_table_default_falls_back_to_paper_table():
    rows = cost_lib.scaling_cost_table(1000.0)
    assert [r.n_devices for r in rows] == sorted(
        cost_lib.PAPER_EFFICIENCIES)


# ---------------------------------------------------------------------------
# interconnect model
# ---------------------------------------------------------------------------


def test_ring_allreduce_zero_for_one_peer_or_no_bytes():
    link = Link(1e9, 1e-6)
    assert interconnect.ring_allreduce_s(1 << 20, 1, link) == 0.0
    assert interconnect.ring_allreduce_s(0, 8, link) == 0.0


def test_ring_allreduce_bandwidth_and_latency_terms():
    link = Link(bandwidth=1e9, latency=1e-5)
    t = interconnect.ring_allreduce_s(1e9, 4, link, n_buckets=2)
    assert t == pytest.approx(2 * 3 / 4 * 1.0 + 2 * 3 * 1e-5 * 2)


def test_hierarchical_beats_flat_across_nodes():
    """At matched (single-bucket) granularity the 2-level schedule wins
    outright: the slow NIC sees 2*(n-1) latency hops instead of
    2*(N-1), and the intra share rides NVLink."""
    topo = gpu_topology(8)           # 64 GPUs, NVLink + NIC
    nbytes = 64 << 20
    hier = interconnect.allreduce_s(nbytes, topo, "hierarchical",
                                    bucket_bytes=nbytes)
    flat = interconnect.allreduce_s(nbytes, topo, "flat",
                                    bucket_bytes=nbytes)
    assert 0 < hier < flat


def test_single_node_has_no_inter_node_term():
    one = gpu_topology(1)
    nbytes = 16 << 20
    flat = interconnect.allreduce_s(nbytes, one, "flat")
    hier = interconnect.allreduce_s(nbytes, one, "hierarchical",
                                    bucket_bytes=nbytes)
    # one node: both are the same NVLink ring, no NIC anywhere
    assert hier == pytest.approx(flat)


def test_allreduce_monotone_in_bytes():
    topo = gpu_topology(4)
    ts = [interconnect.allreduce_s(b, topo, "hierarchical")
          for b in (1 << 20, 8 << 20, 64 << 20)]
    assert ts == sorted(ts)


def test_tpu_pod_inter_link_is_ici():
    topo = tpu_topology("v3", 32)
    assert topo.inter_link == topo.intra_link
    assert topo.nodes == 4 and topo.devices_per_node == 8


def test_exposed_comm_overlap_hides_bucketed_reduction():
    topo = gpu_topology(8)
    rounds = [("g", 32 << 20)]
    total = interconnect.exposed_comm_s(rounds, topo, "overlap",
                                        compute_s=0.0)
    hidden = interconnect.exposed_comm_s(rounds, topo, "overlap",
                                         compute_s=10.0)
    assert 0 < hidden < total
    # post-backward strategies reduce after the gradients exist: no
    # backward window to hide under, every byte exposed
    for strat in ("flat", "hierarchical"):
        assert interconnect.exposed_comm_s(rounds, topo, strat,
                                           compute_s=10.0) \
            == interconnect.exposed_comm_s(rounds, topo, strat,
                                           compute_s=0.0)


def test_exposed_comm_overlap_floors_at_tail_buckets():
    # the exposed floor is the per-round tail: with huge compute the
    # remainder is exactly the tail buckets' reduction time
    topo = gpu_topology(8)
    rounds = [("g", 32 << 20)]
    tail = {"g": 4 << 20}
    floor = interconnect.exposed_comm_s(rounds, topo, "overlap",
                                        compute_s=100.0, tail_bytes=tail)
    assert floor == pytest.approx(
        interconnect.allreduce_s(4 << 20, topo, "overlap"))


def test_unknown_strategy_raises():
    with pytest.raises(ValueError):
        interconnect.allreduce_s(1 << 20, gpu_topology(2), "magic")


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


def test_load_anchor_from_committed_results():
    a = planner.load_anchor(RESULTS)
    assert a.step_s > 0 and a.global_batch > 0
    assert a.source.endswith("BENCH_fig1_loop.json")


def test_gan_rounds_match_algorithm1_structure():
    from repro.configs import calo3dgan
    rounds = planner.gan_rounds("reduced")
    names = [n for n, _ in rounds]
    cfg = calo3dgan.reduced()
    assert names[:2] == ["d_real", "d_fake"]
    assert len(names) == 2 + cfg.gen_steps_per_disc
    assert all(b > 0 for _, b in rounds)


def test_weak_scaling_epoch_time_never_increases_with_nodes():
    """Planner monotonicity: more nodes never increases epoch time (the
    per-step comm tax is always smaller than the 1/n step-count win)."""
    anchor = planner.Anchor(step_s=0.5, global_batch=32)
    rows = planner.weak_scaling_curve(anchor, rounds=[("g", 8 << 20)])
    epochs = [r["epoch_s_pred"] for r in rows]
    assert epochs == sorted(epochs, reverse=True)
    assert all(0 < r["efficiency_pred"] <= 1.0 for r in rows)


def test_efficiency_table_derived_and_decreasing():
    eff = planner.efficiency_table(5.0, rounds=[("g", 16 << 20)])
    vals = [eff[n] for n in sorted(eff)]
    assert vals == sorted(vals, reverse=True)
    assert all(0 < v <= 1.0 for v in vals)
    assert eff[2] > eff[128]


def test_cost_frontier_no_hardcoded_efficiencies(monkeypatch):
    """The planner path must DERIVE efficiencies, never read the paper
    fallback table."""
    monkeypatch.setattr(cost_lib, "PAPER_EFFICIENCIES",
                        {2: None})        # poison: any lookup would raise
    rows = planner.cost_frontier(5200.0, anchor_step_s=5.0,
                                 tpu_epochs={"v3-8": 480.0})
    assert all(r["eff_source"] == "planner" for r in rows
               if r["device"].startswith("V100"))


def test_cost_frontier_preemptible_cheaper():
    """GPU-price ratio is >3x (tested above on PRICES); the per-node VM
    share dilutes the all-in epoch ratio to >2x."""
    rows = planner.cost_frontier(5200.0, anchor_step_s=5.0)
    res = {(r["device"], r["n"]): r["cost_usd"] for r in rows}
    for n in (2, 8, 64):
        assert res[("V100-pre", n)] < res[("V100", n)] / 2.0


def test_recommend_picks_cheapest_feasible():
    rows = [
        {"device": "A", "n": 1, "epoch_s": 100.0, "cost_usd": 10.0},
        {"device": "B", "n": 2, "epoch_s": 50.0, "cost_usd": 2.0},
        {"device": "C", "n": 4, "epoch_s": 500.0, "cost_usd": 1.0},
    ]
    rec = planner.recommend(rows, budget_usd=20.0, deadline_s=200.0,
                            epochs=2)
    assert rec["device"] == "B" and rec["total_cost_usd"] == 4.0
    assert planner.recommend(rows, budget_usd=0.5, deadline_s=10.0) is None


def test_predicted_v3_32_epoch_matches_paper_anchor():
    """The planner predicts the v3-32 epoch from the v3-8 anchor through
    the ICI model — it must land on the paper's ~120 s measurement."""
    rows = planner.cost_frontier(5200.0, anchor_step_s=5.0,
                                 tpu_epochs={"v3-8": 480.0, "v3-32": None})
    v32 = next(r for r in rows if r["device"] == "TPU-v3-32")
    assert v32["epoch_s"] == pytest.approx(120.0, rel=0.05)


def test_apply_elastic_overhead_derates_only_preemptible():
    """The measured elastic overhead lands ONLY on the -pre rows, scaling
    both cost and epoch time; a small overhead keeps preemptible the
    cheapest offering (the paper's >3x gap survives recovery costs)."""
    rows = planner.cost_frontier(5200.0, anchor_step_s=5.0)
    out = planner.apply_elastic_overhead(rows, 0.10)
    by = {(r["device"], r["n"]): r for r in out}
    base = {(r["device"], r["n"]): r for r in rows}
    for key, r in by.items():
        ratio = r["cost_usd"] / base[key]["cost_usd"]
        if key[0].endswith("-pre"):
            assert ratio == pytest.approx(1.10)
            assert r["epoch_s"] == pytest.approx(
                base[key]["epoch_s"] * 1.10)
            assert r["elastic_overhead"] == 0.10
        else:
            assert ratio == 1.0 and "elastic_overhead" not in r
    assert by[("V100-pre", 8)]["cost_usd"] < by[("V100", 8)]["cost_usd"]


def test_elastic_overhead_can_flip_recommendation_to_reserved():
    """When recovery eats more than the spot discount, recommend() must
    flip to reserved capacity — the preemption-honest planner answer."""
    rows = [
        {"device": "V100", "n": 8, "epoch_s": 100.0, "cost_usd": 10.0},
        {"device": "V100-pre", "n": 8, "epoch_s": 100.0, "cost_usd": 3.0},
    ]
    cheap = planner.recommend(
        planner.apply_elastic_overhead(rows, 0.2), 100.0, 1e6)
    assert cheap["device"] == "V100-pre"
    flipped = planner.recommend(
        planner.apply_elastic_overhead(rows, 3.0), 100.0, 1e6)
    assert flipped["device"] == "V100"
    with pytest.raises(ValueError):
        planner.apply_elastic_overhead(rows, -0.1)


def test_load_elastic_reads_benchmark(tmp_path):
    assert planner.load_elastic(str(tmp_path)) is None
    payload = {"rows": {"overhead_frac": 0.07, "recovery_s": 1.5,
                        "lost_steps": 2}}
    with open(tmp_path / "BENCH_elastic.json", "w") as f:
        json.dump(payload, f)
    got = planner.load_elastic(str(tmp_path))
    assert got["overhead_frac"] == pytest.approx(0.07)
    assert got["lost_steps"] == 2 and got["recovery_s"] == 1.5
