"""Chaos suite for the elastic runtime (train/faults + checkpoint + elastic).

The acceptance bar of the elastic PR: a scripted preemption mid-training
resumes from the async checkpoint and reaches final state BIT-IDENTICAL
(builtin loop) / within 2e-6 (custom loop) to an uninterrupted run;
corrupt snapshots fall back; the 2x2 -> 1x2 re-mesh preserves parity
(subprocess, own 4-device pool); and the async snapshot path never blocks
or reads from device on the step-loop thread (transfer-guard + dispatch
discipline, same as test_engine.py).  Every fault here fires from a
deterministic `FaultPlan` — run the module twice and the trajectories,
including which snapshot gets corrupted, are identical.
"""
import json
import os
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from repro.configs import calo3dgan
from repro.data.calo import CaloSimulator, CaloSpec
from repro.launch.mesh import make_dev_mesh
from repro.optim import optimizers as opt_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import engine as engine_lib
from repro.train import faults
from repro.train.elastic import ElasticEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = calo3dgan.bench()
STEPS = 6


def _task(microbatches=1):
    return engine_lib.gan_task(CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4),
                               microbatches=microbatches)


@pytest.fixture(scope="module")
def gan_batches():
    sim = CaloSimulator(CaloSpec(image_shape=CFG.image_shape), seed=11)
    return [next(sim.batches(4)) for _ in range(STEPS)]


def _make_batches(batches):
    # the deterministic-replay contract: the stream for global step s on
    return lambda start: iter(batches[start:])


def _params(state):
    return jax.tree.leaves(state.g_params) + jax.tree.leaves(state.d_params)


def _max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(_params(a), _params(b)))


def _run(tmp_path, batches, *, loop="builtin", injector=None,
         microbatches=1, ckpt_every=2, name="run"):
    eng = ElasticEngine(1, 1, loop=loop,
                        ckpt_dir=str(tmp_path / name),
                        ckpt_every=ckpt_every, keep=3)
    state, report = eng.fit(_task(microbatches), _make_batches(batches),
                            len(batches), rng=jax.random.key(1),
                            injector=injector)
    return state, report


# ---------------------------------------------------------------------------
# preemption -> resume parity (same topology)
# ---------------------------------------------------------------------------


def test_preempt_resume_bit_identical_builtin(tmp_path, gan_batches):
    """Preempt at step 4, resume from the async step-4 snapshot: the
    builtin loop must finish BIT-IDENTICAL to the uninterrupted run (the
    per-step RNG is pinned to the global step, the data stream replays)."""
    clean, _ = _run(tmp_path, gan_batches, name="clean")
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(4, "preempt", lose_node=False),))
    inj = faults.FaultInjector(plan)
    state, rep = _run(tmp_path, gan_batches, injector=inj, name="faulted")
    assert rep["preemptions"] == 1 and rep["restarts"] == 1
    assert rep["recoveries"][0]["resume_step"] == 4   # ckpt_every=2
    assert rep["lost_steps"] == 0
    for x, y in zip(_params(clean), _params(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_preempt_resume_custom_loop(tmp_path, gan_batches):
    """Same-topology resume under the custom (shard_map) loop: within the
    2e-6 acceptance tolerance of the uninterrupted run."""
    clean, _ = _run(tmp_path, gan_batches, loop="custom", name="clean")
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(3, "preempt", lose_node=False),))
    state, rep = _run(tmp_path, gan_batches, loop="custom",
                      injector=faults.FaultInjector(plan), name="faulted")
    assert rep["preemptions"] == 1
    assert rep["lost_steps"] == 1                     # ckpt at 2, died at 3
    assert _max_diff(clean, state) <= 2e-6


def test_preempt_resume_grad_accum_window(tmp_path, gan_batches):
    """Resume lands cleanly inside a grad-accumulation schedule
    (microbatches=2): still bit-identical for the builtin loop."""
    clean, _ = _run(tmp_path, gan_batches, microbatches=2, name="clean")
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(4, "preempt", lose_node=False),))
    state, rep = _run(tmp_path, gan_batches, microbatches=2,
                      injector=faults.FaultInjector(plan), name="faulted")
    assert rep["preemptions"] == 1
    for x, y in zip(_params(clean), _params(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_stall_fault_preserves_numerics(tmp_path, gan_batches):
    """A slow-node stall costs wall clock, never numerics."""
    clean, _ = _run(tmp_path, gan_batches, name="clean")
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(2, "stall", stall_ms=15.0),))
    inj = faults.FaultInjector(plan)
    state, rep = _run(tmp_path, gan_batches, injector=inj, name="faulted")
    assert [e.kind for e in inj.fired] == ["stall"]
    assert rep["preemptions"] == 0
    for x, y in zip(_params(clean), _params(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_corrupt_checkpoint_falls_back_to_previous(tmp_path, gan_batches):
    """A corrupt latest snapshot must not kill recovery: restore falls
    back to the previous snapshot (losing the steps in between) and the
    run still finishes bit-identical to the clean one."""
    clean, _ = _run(tmp_path, gan_batches, name="clean")
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(3, "corrupt"),              # eats the step-4 snap
        faults.FaultEvent(5, "preempt", lose_node=False)))
    inj = faults.FaultInjector(plan)
    state, rep = _run(tmp_path, gan_batches, injector=inj, name="faulted")
    # NOTE: `fired` order races benignly (the preempt fires on the
    # prefetcher's producer thread, which runs AHEAD of the main-thread
    # corrupt hook) — the trajectory itself is deterministic
    assert sorted(e.kind for e in inj.fired) == ["corrupt", "preempt"]
    assert rep["fallbacks"] == 1
    assert rep["recoveries"][0]["resume_step"] == 2   # 4 corrupt -> 2
    assert rep["lost_steps"] == 3
    for x, y in zip(_params(clean), _params(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_remesh_2x2_to_1x2_subprocess(tmp_path):
    """Losing a node mid-run: 4 virtual devices as (node=2, device=2),
    preempt with lose_node=True re-meshes onto the surviving (1, 2) grid
    and resumes — final params must match the uninterrupted 2x2 run to
    f32 summation-order tolerance (subprocess: own device pool)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np, tempfile
from repro.configs import calo3dgan
from repro.data.calo import CaloSimulator, CaloSpec
from repro.optim import optimizers as opt_lib
from repro.train import engine as engine_lib, faults
from repro.train.elastic import ElasticEngine

cfg = calo3dgan.bench()
spec = CaloSpec(image_shape=cfg.image_shape)
make_batches = lambda start: CaloSimulator(spec, seed=11).batches(8,
                                                                  skip=start)
task = lambda: engine_lib.gan_task(cfg, opt_lib.rmsprop(1e-4),
                                   opt_lib.rmsprop(1e-4))
with tempfile.TemporaryDirectory() as td:
    eng = ElasticEngine(2, 2, loop="builtin", ckpt_dir=td + "/c",
                        ckpt_every=2, keep=3)
    clean, _ = eng.fit(task(), make_batches, 8, rng=jax.random.key(1))
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(5, "preempt", node=0, lose_node=True),))
    eng2 = ElasticEngine(2, 2, loop="builtin", ckpt_dir=td + "/f",
                         ckpt_every=2, keep=3)
    state, rep = eng2.fit(task(), make_batches, 8, rng=jax.random.key(1),
                          injector=faults.FaultInjector(plan))
    assert rep["remeshes"] == 1, rep
    assert rep["topology_final"] == [1, 2], rep
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(
                   jax.tree.leaves(clean.g_params)
                   + jax.tree.leaves(clean.d_params),
                   jax.tree.leaves(state.g_params)
                   + jax.tree.leaves(state.d_params)))
    assert diff <= 2e-6, diff
    print(f"remesh parity OK: {diff:.2e}")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env, cwd=REPO,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "remesh parity OK" in r.stdout


# ---------------------------------------------------------------------------
# async checkpointer
# ---------------------------------------------------------------------------


def test_async_snapshot_never_blocks_step_loop(tmp_path):
    """The snapshot hook must neither read from device nor sync on the
    step-loop thread: a whole fit with checkpointing enabled completes
    under a disallow-d2h transfer guard with zero host transfers — the
    device->host copy happens only on the writer thread."""
    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    task = _task()
    sim = CaloSimulator(CaloSpec(image_shape=CFG.image_shape), seed=11)
    batches = [next(sim.batches(4)) for _ in range(4)]
    ckpt = ckpt_lib.AsyncCheckpointer(str(tmp_path / "ck"), keep=3)
    state = eng.init_state(task, jax.random.key(0))
    with jax.transfer_guard_device_to_host("disallow"):
        state, _ = eng.fit(task, iter(batches), 4, rng=jax.random.key(1),
                           state=state, hooks=(ckpt.hook(2),))
    assert eng.last_fit_stats["host_transfers"] == 0
    ckpt.wait()
    assert ckpt.stats["saved"] == 2
    assert ckpt.stats["writer_thread"] is not threading.main_thread()
    assert ckpt_lib.checkpoint_steps(ckpt.root) == [2, 4]
    ckpt.close()


def test_async_checkpointer_keep_k_atomic_manifest(tmp_path):
    """Keep-last-K pruning, atomic publication (no temp dirs survive),
    and the manifest's step/topology/precision fields."""
    root = str(tmp_path / "ck")
    ckpt = ckpt_lib.AsyncCheckpointer(
        root, keep=2, extra={"topology": [1, 1], "precision": "f32"})
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    for step in (1, 2, 3, 4, 5):
        ckpt.save(step, tree)
    ckpt.close()
    assert ckpt.stats["saved"] == 5 and ckpt.stats["pruned"] == 3
    assert ckpt_lib.checkpoint_steps(root) == [4, 5]
    assert not [d for d in os.listdir(root) if d.startswith(".tmp")]
    man = ckpt_lib.manifest(ckpt_lib.step_dir(root, 5))
    assert man["step"] == 5
    assert man["extra"]["topology"] == [1, 1]
    assert ckpt_lib.manifest_precision(ckpt_lib.step_dir(root, 5)) == "f32"
    got = ckpt_lib.restore(ckpt_lib.step_dir(root, 4),
                           {"w": np.zeros((2, 3), np.float32)})
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_checkpointer_hook_cadence(tmp_path):
    ckpt = ckpt_lib.AsyncCheckpointer(str(tmp_path / "ck"), keep=10)
    hook = ckpt.hook(3)
    for gstep in range(9):
        hook(gstep, {"x": np.float32(gstep)})
    ckpt.close()
    # fires at gstep 2, 5, 8 -> completed-step checkpoints 3, 6, 9
    assert ckpt_lib.checkpoint_steps(ckpt.root) == [3, 6, 9]
    assert ckpt_lib.latest_step(ckpt_lib.step_dir(ckpt.root, 9)) == 9


def test_restore_strict_mismatch_raises(tmp_path):
    """The silent-partial-restore bug: extra/missing leaves must raise
    with the offending key path, never restore a subset quietly."""
    path = str(tmp_path / "ck")
    ckpt_lib.save(path, {"a": np.ones(2, np.float32),
                         "b": np.ones(3, np.float32)})
    with pytest.raises(ValueError, match="b"):
        ckpt_lib.restore(path, {"a": np.zeros(2, np.float32)})
    with pytest.raises(ValueError, match="c"):
        ckpt_lib.restore(path, {"a": np.zeros(2, np.float32),
                                "b": np.zeros(3, np.float32),
                                "c": np.zeros(1, np.float32)})
    # exact-match template still round-trips
    got = ckpt_lib.restore(path, {"a": np.zeros(2, np.float32),
                                  "b": np.zeros(3, np.float32)})
    np.testing.assert_array_equal(got["a"], np.ones(2, np.float32))


def test_old_manifest_without_precision_field(tmp_path):
    """Regression: manifests written before the ``precision`` extra existed
    (pre-mixed-precision checkpoints) still load and default to f32."""
    path = str(tmp_path / "old")
    ckpt_lib.save(path, {"w": np.ones(2, np.float32)}, step=7)
    man = ckpt_lib.manifest(path)
    assert "precision" not in man["extra"]
    assert ckpt_lib.manifest_precision(path) == "f32"
    assert ckpt_lib.latest_step(path) == 7


def test_restore_latest_empty_and_corrupt_fallback(tmp_path):
    root = str(tmp_path / "ck")
    template = {"w": np.zeros(4, np.float32)}
    assert ckpt_lib.restore_latest(root, template) == (0, None, None, 0)
    for step, val in ((2, 2.0), (4, 4.0)):
        ckpt_lib.save(ckpt_lib.step_dir(root, step),
                      {"w": np.full(4, val, np.float32)}, step=step)
    corrupted = faults.corrupt_latest(root)
    assert corrupted == 4
    step, tree, man, skipped = ckpt_lib.restore_latest(root, template)
    assert (step, skipped) == (2, 1)
    assert man["step"] == 2
    np.testing.assert_array_equal(tree["w"], np.full(4, 2.0, np.float32))


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------


def test_fault_plan_json_roundtrip(tmp_path):
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(3, "stall", stall_ms=10.0),
        faults.FaultEvent(5, "preempt", node=1, lose_node=False),
        faults.FaultEvent(7, "corrupt")), seed=42)
    path = str(tmp_path / "trace.json")
    plan.save(path, extra={"steps": 12})
    assert faults.FaultPlan.load(path) == plan
    with open(path) as f:
        assert json.load(f)["steps"] == 12
    assert faults.FaultPlan.from_json(plan.to_json()) == plan


def test_fault_plan_random_replayable():
    a = faults.FaultPlan.random(0, 50, n_preempt=2, n_stall=1, n_corrupt=1)
    b = faults.FaultPlan.random(0, 50, n_preempt=2, n_stall=1, n_corrupt=1)
    assert a == b                       # seed -> identical plan
    assert len(a.events) == 4
    assert all(1 <= e.step < 50 for e in a.events)
    assert len({e.step for e in a.events}) == 4     # without replacement
    c = faults.FaultPlan.random(1, 50, n_preempt=2, n_stall=1, n_corrupt=1)
    assert a != c
    assert faults.FaultPlan.random(0, 1).events == ()


def test_fault_event_validation_and_committed_trace():
    with pytest.raises(ValueError, match="kind"):
        faults.FaultEvent(3, "meteor")
    # the CI elastic-smoke trace must stay loadable and well-formed
    plan = faults.FaultPlan.load(os.path.join(REPO, "results",
                                              "elastic_trace.json"))
    kinds = [e.kind for e in plan.events]
    assert kinds.count("preempt") == 2
    assert any(e.lose_node for e in plan.events if e.kind == "preempt")


def test_sigterm_graceful_preemption_subprocess(tmp_path):
    """The wall-clock preemption path: a real SIGTERM mid-training is
    converted into the deterministic Preemption path — snapshot the
    completed step, flush the writer, exit 0 — and a relaunch with
    ``resume=True`` finishes bit-identical to an uninterrupted run."""
    ckpt_dir = str(tmp_path / "sig_ck")
    interrupted = r"""
import os, signal, sys
import jax
from repro.configs import calo3dgan
from repro.data.calo import CaloSimulator, CaloSpec
from repro.optim import optimizers as opt_lib
from repro.train import engine as engine_lib
from repro.train.elastic import ElasticEngine

cfg = calo3dgan.bench()
spec = CaloSpec(image_shape=cfg.image_shape)
task = engine_lib.gan_task(cfg, opt_lib.rmsprop(1e-4), opt_lib.rmsprop(1e-4))

def make_batches(start):
    def gen():
        sim = CaloSimulator(spec, seed=11)
        for i, b in enumerate(sim.batches(4, skip=start)):
            if start + i == 5:          # a real OS signal, mid-stream
                os.kill(os.getpid(), signal.SIGTERM)
            yield b
    return gen()

eng = ElasticEngine(1, 1, loop="builtin", ckpt_dir=sys.argv[1],
                    ckpt_every=2, keep=3)
eng.fit(task, make_batches, 12, rng=jax.random.key(1),
        handle_signals=(signal.SIGTERM, signal.SIGINT))
print("UNREACHABLE: fit returned despite the signal")
sys.exit(3)
"""
    resumed = r"""
import signal, sys
import jax, numpy as np
from repro.configs import calo3dgan
from repro.data.calo import CaloSimulator, CaloSpec
from repro.optim import optimizers as opt_lib
from repro.train import engine as engine_lib
from repro.train.elastic import ElasticEngine

cfg = calo3dgan.bench()
spec = CaloSpec(image_shape=cfg.image_shape)
make_batches = lambda start: CaloSimulator(spec, seed=11).batches(
    4, skip=start)
task = lambda: engine_lib.gan_task(cfg, opt_lib.rmsprop(1e-4),
                                   opt_lib.rmsprop(1e-4))
import tempfile
with tempfile.TemporaryDirectory() as td:
    clean_eng = ElasticEngine(1, 1, loop="builtin", ckpt_dir=td + "/c",
                              ckpt_every=2, keep=3)
    clean, _ = clean_eng.fit(task(), make_batches, 12,
                             rng=jax.random.key(1))
eng = ElasticEngine(1, 1, loop="builtin", ckpt_dir=sys.argv[1],
                    ckpt_every=2, keep=3)
state, rep = eng.fit(task(), make_batches, 12, rng=jax.random.key(1),
                     resume=True, handle_signals=(signal.SIGTERM,))
assert rep["resumed_from"] >= 2, rep
for a, b in zip(jax.tree.leaves(clean.g_params)
                + jax.tree.leaves(clean.d_params),
                jax.tree.leaves(state.g_params)
                + jax.tree.leaves(state.d_params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print(f"signal resume parity OK from step {rep['resumed_from']}")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", interrupted, ckpt_dir],
                       env=env, cwd=REPO, capture_output=True, text=True,
                       timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "exiting 0" in r.stdout, r.stdout + r.stderr
    assert ckpt_lib.checkpoint_steps(ckpt_dir), "no snapshot on disk"
    r2 = subprocess.run([sys.executable, "-c", resumed, ckpt_dir],
                        env=env, cwd=REPO, capture_output=True, text=True,
                        timeout=600)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "signal resume parity OK" in r2.stdout


def test_checkpointer_retries_transient_write_failure(tmp_path,
                                                      monkeypatch):
    """A transient filesystem failure costs retries, not the snapshot:
    the writer re-attempts with backoff and the snapshot still lands."""
    real_save = ckpt_lib.save
    fails = {"n": 2}

    def flaky_save(path, tree, step=0, extra=None):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("disk detached")
        return real_save(path, tree, step=step, extra=extra)

    monkeypatch.setattr(ckpt_lib, "save", flaky_save)
    ckpt = ckpt_lib.AsyncCheckpointer(str(tmp_path / "ck"), keep=3,
                                      retries=3, retry_backoff_s=0.001)
    ckpt.save(2, {"w": np.ones(3, np.float32)})
    ckpt.close()
    assert ckpt.stats["saved"] == 1
    assert ckpt.stats["write_retries"] == 2
    assert ckpt_lib.checkpoint_steps(ckpt.root) == [2]
    got = ckpt_lib.restore(ckpt_lib.step_dir(ckpt.root, 2),
                           {"w": np.zeros(3, np.float32)})
    np.testing.assert_array_equal(got["w"], np.ones(3, np.float32))


def test_checkpointer_write_failure_surfaces_without_retries(tmp_path,
                                                            monkeypatch):
    """retries=0 keeps the old contract: a write failure is stashed and
    re-raised on wait(), never swallowed."""
    def broken_save(path, tree, step=0, extra=None):
        raise OSError("disk gone for good")

    monkeypatch.setattr(ckpt_lib, "save", broken_save)
    ckpt = ckpt_lib.AsyncCheckpointer(str(tmp_path / "ck"), keep=3)
    ckpt.save(2, {"w": np.ones(3, np.float32)})
    with pytest.raises(OSError, match="disk gone"):
        ckpt.wait()


def test_checkpoint_mirror_bidirectional_fallback(tmp_path):
    """The mirror directory is a full second copy, and recovery falls
    back across BOTH sides: corrupt primary -> mirror serves the same
    step; corrupt both newest -> the previous step (primary) serves."""
    root, mirror = str(tmp_path / "ck"), str(tmp_path / "mirror")
    ckpt = ckpt_lib.AsyncCheckpointer(root, keep=3, mirror=mirror)
    for step, val in ((2, 2.0), (4, 4.0)):
        ckpt.save(step, {"w": np.full(3, val, np.float32)})
    ckpt.close()
    assert ckpt.stats["mirror_saved"] == 2
    assert ckpt_lib.checkpoint_steps(mirror) == [2, 4]
    template = {"w": np.zeros(3, np.float32)}

    assert faults.corrupt_latest(root) == 4   # primary's newest is torn
    step, tree, _, skipped = ckpt_lib.restore_latest_mirrored(
        root, mirror, template)
    assert (step, skipped) == (4, 1)          # mirror served step 4
    np.testing.assert_array_equal(tree["w"], np.full(3, 4.0, np.float32))

    assert faults.corrupt_latest(mirror) == 4  # now both copies of 4 die
    step, tree, _, skipped = ckpt_lib.restore_latest_mirrored(
        root, mirror, template)
    assert (step, skipped) == (2, 2)
    np.testing.assert_array_equal(tree["w"], np.full(3, 2.0, np.float32))

    # no mirror configured degrades to plain restore_latest
    step, _, _, _ = ckpt_lib.restore_latest_mirrored(root, None, template)
    assert step == 2


def test_injector_fires_each_event_once():
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(2, "preempt", lose_node=False),))
    inj = faults.FaultInjector(plan)
    stream = inj.wrap(iter(range(10)), start_step=0)
    got = []
    with pytest.raises(faults.Preemption) as ei:
        for x in stream:
            got.append(x)
    assert got == [0, 1] and ei.value.step == 2
    # the replayed stream passes global step 2 again: no re-fire
    assert list(inj.wrap(iter(range(2, 10)), start_step=2)) \
        == list(range(2, 10))
    assert len(inj.fired) == 1


# ---------------------------------------------------------------------------
# ZeRO-1 shard-aware snapshot/restore (re-mesh across device counts)
# ---------------------------------------------------------------------------

_Z1_PARAMS = {"b": np.ones(3, np.float32),
              "w": np.arange(10, dtype=np.float32)}
_Z1_GRADS = {"b": np.full(3, 0.5, np.float32),
             "w": np.linspace(0.1, 1.0, 10).astype(np.float32)}


def _zero1_state(n):
    """One real update so the inner (rmsprop) moments are non-trivial —
    padding entries stay zero by construction (zero grads keep
    element-wise moments at zero), which is the reshard invariant."""
    opt = opt_lib.zero1(opt_lib.rmsprop(1e-2), n)
    state = opt.init(_Z1_PARAMS)
    _, state = opt.update(_Z1_GRADS, state, _Z1_PARAMS)
    return opt, state


def _zero1_template(n):
    return jax.eval_shape(opt_lib.zero1(opt_lib.rmsprop(1e-2), n).init,
                          _Z1_PARAMS)


def test_zero1_snapshot_restores_across_shard_counts(tmp_path):
    """The elastic re-mesh contract for ZeRO-1 ``(N, L)`` state: the flat
    concatenation is the logical state and rows are just the deal across
    N devices, so 4-shard -> 2-shard (truncating zero padding) and
    2-shard -> 4-shard (extending it) both round-trip every logical
    entry bit-exactly — through `restore_latest`, the exact entry point
    the elastic recovery path calls with `reshard=zero1_reshard`."""
    logical = sum(a.size for a in _Z1_PARAMS.values())   # 13 entries

    for n_save, n_load in ((4, 2), (2, 4)):
        _, saved = _zero1_state(n_save)
        root = str(tmp_path / f"z1_{n_save}to{n_load}")
        ckpt_lib.save(ckpt_lib.step_dir(root, 1), saved, step=1)
        step, restored, _, skipped = ckpt_lib.restore_latest(
            root, _zero1_template(n_load),
            reshard=ckpt_lib.zero1_reshard)
        assert (step, skipped) == (1, 0)
        for a, b in zip(jax.tree.leaves(saved), jax.tree.leaves(restored)):
            fa = np.asarray(a).reshape(-1)
            fb = np.asarray(b).reshape(-1)
            np.testing.assert_array_equal(fa[:logical], fb[:logical])
            assert not np.any(fb[logical:])              # padding stays zero

    # and the restored state TRAINS identically: a further update from
    # the 4->2 restored state matches the natively-2-sharded trajectory
    _, saved4 = _zero1_state(4)
    root = str(tmp_path / "z1_traj")
    ckpt_lib.save(ckpt_lib.step_dir(root, 1), saved4, step=1)
    _, restored2, _, _ = ckpt_lib.restore_latest(
        root, _zero1_template(2), reshard=ckpt_lib.zero1_reshard)
    opt2, native2 = _zero1_state(2)
    upd_r, _ = opt2.update(_Z1_GRADS, restored2, _Z1_PARAMS)
    upd_n, _ = opt2.update(_Z1_GRADS, native2, _Z1_PARAMS)
    for a, b in zip(jax.tree.leaves(upd_r), jax.tree.leaves(upd_n)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero1_restore_strict_on_layout_mismatch(tmp_path):
    """The reshard hook must not weaken the strict restore contract:
    no hook -> shape mismatch still raises; a non-zero dropped tail
    (layouts genuinely disagree, e.g. a different model) -> the hook
    refuses and the strict error fires; non-ZeRO leaves and
    missing/extra leaves keep the plain strict behaviour."""
    _, state4 = _zero1_state(4)
    path = str(tmp_path / "strict")
    ckpt_lib.save(path, state4, step=0)
    template2 = _zero1_template(2)

    with pytest.raises(ValueError, match="ckpt"):
        ckpt_lib.restore(path, template2)                # no hook: strict

    bad = jax.tree.map(lambda a: np.array(a), state4)
    bad["zero1"]["master"].reshape(-1)[-1] = 7.0         # tail isn't padding
    bad_path = str(tmp_path / "bad")
    ckpt_lib.save(bad_path, bad, step=0)
    with pytest.raises(ValueError, match="master"):
        ckpt_lib.restore(bad_path, template2,
                         reshard=ckpt_lib.zero1_reshard)

    plain = str(tmp_path / "plain")
    ckpt_lib.save(plain, {"a": np.zeros(3, np.float32)})
    with pytest.raises(ValueError, match=r"ckpt \(3,\)"):
        ckpt_lib.restore(plain, {"a": np.zeros(4, np.float32)},
                         reshard=ckpt_lib.zero1_reshard)

    with pytest.raises(ValueError, match="missing"):
        ckpt_lib.restore(plain, template2,
                         reshard=ckpt_lib.zero1_reshard)


def test_zero1_preempt_resume_bit_identical(tmp_path, gan_batches):
    """Elastic preempt -> resume with the ZeRO-1 sharded optimizer: the
    ``(N, L)`` master/moment leaves round-trip through the async
    snapshot and the resumed run finishes bit-identical to the
    uninterrupted one (builtin loop)."""
    def zero1_task():
        return engine_lib.gan_task(CFG, opt_lib.zero1(opt_lib.rmsprop(1e-4), 4),
                                   opt_lib.zero1(opt_lib.rmsprop(1e-4), 4))

    def run(name, injector=None):
        eng = ElasticEngine(1, 1, loop="builtin",
                            ckpt_dir=str(tmp_path / name),
                            ckpt_every=2, keep=3)
        return eng.fit(zero1_task(), _make_batches(gan_batches),
                       len(gan_batches), rng=jax.random.key(1),
                       injector=injector)

    clean, _ = run("clean")
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(4, "preempt", lose_node=False),))
    state, rep = run("faulted", injector=faults.FaultInjector(plan))
    assert rep["preemptions"] == 1 and rep["lost_steps"] == 0
    for x, y in zip(_params(clean), _params(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
