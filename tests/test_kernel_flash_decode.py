"""Flash-decode Pallas kernel family (serving hot path): split-KV
single-query parity vs the ragged dot_attention reference over kv_len /
GQA / MQA / window, schedule (block_kv, num_splits) numerics-freedom,
the chunked-prefill kernel's offset-causal parity, the no-score-matrix
HLO guarantee of the decode route, and the DecodeBlocks autotune family.

This is the decode third of the kernel tier-1 suite — CI runs it
fail-fast alongside test_kernel_flash_attention.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as autotune_lib
from repro.kernels.flash_attention import decode as decode_lib
from repro.kernels.flash_attention.decode import (combine_splits,
                                                 flash_decode)
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_chunk)
from repro.substrate import attention as attn_lib

RNG = np.random.default_rng(13)


def _randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(0, 1, shape), dtype)


def _case(B, T, H, KH, D, dtype=jnp.float32):
    return (_randn((B, 1, H, D), dtype), _randn((B, T, KH, D), dtype),
            _randn((B, T, KH, D), dtype))


DECODE_CASES = [
    # B, T, H, KH, D, kv_lens
    (3, 96, 8, 2, 32, (1, 37, 96)),      # GQA, ragged
    (2, 64, 4, 1, 16, (5, 64)),          # MQA
    (1, 200, 4, 4, 64, (123,)),          # MHA, non-block T
    (4, 128, 6, 3, 32, (128, 1, 64, 7)),  # 3-way GQA, full spread
]


# ---------------------------------------------------------------------------
# single-query parity vs the ragged reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,KH,D,kv_lens", DECODE_CASES)
def test_flash_decode_parity_ragged(B, T, H, KH, D, kv_lens):
    q, k, v = _case(B, T, H, KH, D)
    kvl = jnp.asarray(kv_lens, jnp.int32)
    ref = attn_lib.dot_attention(q, k, v, causal=False, kv_len=kvl)
    out = flash_decode(q, k, v, kvl, block_kv=32, num_splits=2)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_decode_window_parity():
    """Sliding-window decode: the query sits at kv_len - 1, so the
    reference is dot_attention with explicit q_positions."""
    B, T, H, KH, D, w = 3, 128, 4, 2, 32, 48
    q, k, v = _case(B, T, H, KH, D)
    kvl = jnp.asarray([128, 60, 13], jnp.int32)
    ref = attn_lib.dot_attention(q, k, v, causal=True, window=w, kv_len=kvl,
                                 q_positions=(kvl - 1)[:, None])
    out = flash_decode(q, k, v, kvl, window=w, block_kv=32, num_splits=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("block_kv,num_splits",
                         [(16, 1), (16, 4), (32, 2), (64, 8), (128, 1)])
def test_flash_decode_schedule_is_numerics_free(block_kv, num_splits):
    """Every (block_kv, num_splits) candidate is a pure scheduling choice
    — the split-KV combine reproduces the single-sweep result."""
    q, k, v = _case(2, 96, 8, 2, 32)
    kvl = jnp.asarray([96, 41], jnp.int32)
    ref = attn_lib.dot_attention(q, k, v, causal=False, kv_len=kvl)
    out = flash_decode(q, k, v, kvl, block_kv=block_kv,
                       num_splits=num_splits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_decode_bf16():
    q32, k32, v32 = _case(2, 64, 4, 2, 32)
    qb, kb, vb = (t.astype(jnp.bfloat16) for t in (q32, k32, v32))
    kvl = jnp.asarray([64, 17], jnp.int32)
    out = flash_decode(qb, kb, vb, kvl, block_kv=32, num_splits=2)
    assert out.dtype == jnp.bfloat16
    ref = attn_lib.dot_attention(q32, k32, v32, causal=False, kv_len=kvl)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=3e-2)


def test_attend_routes_decode_to_kernel():
    """attend(kv_len=..., use_pallas=True) on a single query must match
    the pure-JAX serving branch bit-for-tolerance."""
    q, k, v = _case(2, 64, 4, 2, 32)
    kvl = jnp.asarray([30, 64], jnp.int32)
    ref = attn_lib.attend(q, k, v, causal=False, kv_len=kvl,
                          use_pallas=False)
    out = attn_lib.attend(q, k, v, causal=False, kv_len=kvl,
                          use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# combine_splits: the pure log-sum-exp merge
# ---------------------------------------------------------------------------


def test_combine_splits_matches_direct_softmax():
    """Partition a score row into contiguous splits, build each split's
    (acc, m, l) partials directly, and check the combine reproduces the
    un-split softmax-weighted sum — including an EMPTY split."""
    G, T, D, S = 4, 48, 16, 3
    s = jnp.asarray(RNG.normal(0, 2, (G, T)), jnp.float32)
    vv = jnp.asarray(RNG.normal(0, 1, (T, D)), jnp.float32)
    direct = jax.nn.softmax(s, axis=-1) @ vv

    bounds = [(0, 20), (20, 48), (48, 48)]          # last split empty
    accs, ms, ls = [], [], []
    for lo, hi in bounds:
        if hi == lo:
            accs.append(jnp.zeros((G, D)))
            ms.append(jnp.full((G,), decode_lib.NEG_INF))
            ls.append(jnp.zeros((G,)))
            continue
        blk = s[:, lo:hi]
        m = jnp.max(blk, axis=-1)
        e = jnp.exp(blk - m[:, None])
        accs.append(e @ vv[lo:hi])
        ms.append(m)
        ls.append(jnp.sum(e, axis=-1))
    out = combine_splits(jnp.stack(accs), jnp.stack(ms), jnp.stack(ls))
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# chunked-prefill kernel: offset-causal ragged parity
# ---------------------------------------------------------------------------


def test_flash_chunk_parity_offset_causal():
    B, C, T, H, KH, D = 3, 24, 96, 8, 2, 32
    q = _randn((B, C, H, D))
    k, v = _randn((B, T, KH, D)), _randn((B, T, KH, D))
    off = jnp.asarray([0, 10, 40], jnp.int32)
    lens = jnp.asarray([24, 24, 13], jnp.int32)
    kvl = off + lens
    qpos = off[:, None] + jnp.arange(C)[None]
    ref = attn_lib.dot_attention(q, k, v, causal=True, kv_len=kvl,
                                 q_positions=qpos)
    out = flash_attention_chunk(q, k, v, off, kvl, block_q=16, block_kv=32)
    for b in range(B):          # only rows inside each slot's live prompt
        n = int(lens[b])
        np.testing.assert_allclose(np.asarray(out[b, :n]),
                                   np.asarray(ref[b, :n]), atol=2e-5)
    assert bool(jnp.all(jnp.isfinite(out)))     # padded tail: exact zeros


def test_flash_chunk_inactive_row_is_finite_zero():
    B, C, T, H, KH, D = 2, 8, 32, 4, 2, 16
    q = _randn((B, C, H, D))
    k, v = _randn((B, T, KH, D)), _randn((B, T, KH, D))
    off = jnp.asarray([0, 0], jnp.int32)
    kvl = jnp.asarray([8, 0], jnp.int32)        # row 1 inactive
    out = flash_attention_chunk(q, k, v, off, kvl, block_q=8, block_kv=16)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)


# ---------------------------------------------------------------------------
# no-score-matrix guarantee: the decode route must not materialize the
# reference's (B, KH, G, 1, T) score tensor (no ref-oracle fallback)
# ---------------------------------------------------------------------------


def test_decode_hlo_has_no_materialized_scores():
    B, T, H, KH, D = 2, 256, 8, 2, 32
    q, k, v = _case(B, T, H, KH, D)
    kvl = jnp.asarray([256, 100], jnp.int32)
    tell = f"tensor<{B}x{KH}x{H // KH}x1x{T}xf32>"

    # validity: the tell-tale is present in the REFERENCE decode lowering
    ref_hlo = jax.jit(lambda a, b, c, l: attn_lib.dot_attention(
        a, b, c, causal=False, kv_len=l)).lower(q, k, v, kvl).as_text()
    assert tell in ref_hlo, "tell-tale string no longer matches the ref"

    ker_hlo = jax.jit(lambda a, b, c, l: flash_decode(
        a, b, c, l, block_kv=64, num_splits=2)).lower(
        q, k, v, kvl).as_text()
    assert tell not in ker_hlo, \
        "flash_decode materialized the full score row (ref fallback?)"


# ---------------------------------------------------------------------------
# DecodeBlocks autotune family
# ---------------------------------------------------------------------------


def test_decode_schedule_registry_default_and_override():
    sig = decode_lib.signature(4, 8192, 8, 2, 64, 0)
    try:
        d = autotune_lib.get_schedule(sig)
        assert d == decode_lib.default_blocks(sig)
        assert d.num_splits > 1     # long cache splits by default
        autotune_lib.register_schedule(
            sig, decode_lib.DecodeBlocks(block_kv=512, num_splits=4))
        assert autotune_lib.get_schedule(sig).block_kv == 512
        sigd = decode_lib.signature(4, 8192, 8, 2, 64, 0, jnp.bfloat16)
        assert autotune_lib.get_schedule(sigd).block_kv == 512
    finally:
        autotune_lib.clear_registry()


def test_decode_candidates_clamp_dedup():
    sig = decode_lib.signature(4, 128, 8, 2, 64, 0)
    cands = decode_lib.candidate_blocks(sig)
    assert cands
    effs = []
    for c in cands:
        eff_b = min(c.block_kv, 128)
        effs.append((eff_b, min(c.num_splits, -(-128 // eff_b))))
    assert len(effs) == len(set(effs)), "aliased effective schedules"


def test_decode_registered_schedule_drives_the_wrapper():
    q, k, v = _case(2, 96, 4, 2, 32)
    kvl = jnp.asarray([96, 30], jnp.int32)
    base = flash_decode(q, k, v, kvl)
    sig = decode_lib.signature(2, 96, 4, 2, 32, 0, q.dtype)
    try:
        autotune_lib.register_schedule(
            sig, decode_lib.DecodeBlocks(block_kv=16, num_splits=4))
        out = flash_decode(q, k, v, kvl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=1e-5)
    finally:
        autotune_lib.clear_registry()


def test_decode_model_signatures():
    from repro.configs import base as config_base
    from repro.models.zamba import _shared_cfg

    cfg = config_base.reduced_config("qwen2-1.5b")
    sigs = decode_lib.model_signatures(cfg, 256, batch=4)
    assert sigs == [decode_lib.signature(4, 256, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.d_head, 0)]
    hcfg = config_base.reduced_config("zamba2-1.2b")
    scfg = _shared_cfg(hcfg)
    (hsig,) = decode_lib.model_signatures(hcfg, 256, batch=4)
    assert hsig[3:6] == (scfg.n_heads, scfg.n_kv_heads, scfg.d_head)
