"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.parallel import collectives, sharding
from repro.substrate import attention as attn_lib
from repro.substrate import layers

SETTINGS = dict(max_examples=8, deadline=None)


# ---------------------------------------------------------------------------
# sharding spec resolution
# ---------------------------------------------------------------------------


@given(
    dims=st.lists(st.integers(1, 512), min_size=1, max_size=4),
    axis_names=st.permutations(("embed", "heads", "mlp", "vocab")),
)
@settings(**SETTINGS)
def test_resolve_spec_invariants(dims, axis_names):
    """For ANY shape/logical-axis combination: every mesh axis appears at
    most once, and every sharded dim is divisible by its axis size."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    logical = tuple(axis_names[:len(dims)])
    spec = sharding.resolve_spec(logical, tuple(dims), mesh,
                                 sharding.FSDP_TP_RULES)
    used = [a for entry in spec for a in
            ((entry,) if isinstance(entry, str) else (entry or ()))]
    assert len(used) == len(set(used))
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % size == 0


@given(st.integers(1, 64), st.integers(1, 8))
@settings(**SETTINGS)
def test_moe_group_pick_divides(T_mult, target_log):
    from repro.substrate.moe import _pick_groups
    T = T_mult * 8
    G = _pick_groups(T, 2 ** target_log)
    assert T % G == 0
    assert 1 <= G <= T


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


@given(
    s=st.integers(2, 8),
    d_half=st.sampled_from((4, 8, 16)),
    scale=st.floats(0.1, 10.0),
)
@settings(**SETTINGS)
def test_rope_is_isometry(s, d_half, scale):
    """RoPE rotation preserves vector norms for any position/scale."""
    d = 2 * d_half
    pos = jnp.broadcast_to(jnp.arange(s)[None], (1, s))
    cos, sin = attn_lib.rope_cos_sin(pos, d, 10_000.0)
    x = scale * jax.random.normal(jax.random.key(s), (1, s, 2, d))
    r = attn_lib.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(r, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4)


@given(
    b=st.integers(1, 3), s=st.integers(1, 32),
    scale=st.floats(0.5, 100.0),     # >= 0.5: below that the eps term in
                                     # rsqrt(var + 1e-5) legitimately bites
)
@settings(**SETTINGS)
def test_rmsnorm_output_rms_is_one(b, s, scale):
    p = layers.init_norm(64, "rmsnorm")
    x = scale * jax.random.normal(jax.random.key(b * 100 + s), (b, s, 64))
    y = layers.apply_norm(p, x, "rmsnorm")
    rms = np.asarray(jnp.sqrt(jnp.mean(jnp.square(y), axis=-1)))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_softmax_attention_rows_sum_to_one(seed):
    """Attention output of constant-value V equals that constant: the
    softmax weights sum to 1 for every query — incl. masked rows."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    q = jax.random.normal(k1, (1, 16, 2, 8))
    k = jax.random.normal(k2, (1, 16, 2, 8))
    v = jnp.full((1, 16, 2, 8), 3.5)
    out = attn_lib.dot_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-5)
    out_b = attn_lib.blockwise_attention(q, k, v, causal=True,
                                         q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out_b), 3.5, atol=1e-5)


# ---------------------------------------------------------------------------
# optimizer state / checkpoint
# ---------------------------------------------------------------------------


@given(
    shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                    min_size=1, max_size=4),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_checkpoint_roundtrip_any_tree(tmp_path_factory, shapes, seed):
    from repro.train import checkpoint as ckpt_lib
    rng = np.random.default_rng(seed)
    tree = {f"p{i}": {"w": jnp.asarray(rng.normal(size=s), jnp.float32)}
            for i, s in enumerate(shapes)}
    path = str(tmp_path_factory.mktemp("ck"))
    ckpt_lib.save(path, tree, step=seed)
    back = ckpt_lib.restore(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(1, 200), st.integers(1, 50))
@settings(**SETTINGS)
def test_epoch_iterator_covers_everything(n_per_shard, batch):
    """iter_epoch yields every index at most once and >= floor coverage."""
    import tempfile
    from repro.data.pipeline import ShardStore
    with tempfile.TemporaryDirectory() as d:
        store = ShardStore(d)
        store.write("s0", {"id": np.arange(n_per_shard, dtype=np.int64)})
        seen = []
        for b in store.iter_epoch(batch=batch, shuffle_seed=1):
            seen.extend(b["id"].tolist())
        assert len(seen) == len(set(seen))
        assert len(seen) == (n_per_shard // batch) * batch


# ---------------------------------------------------------------------------
# gradient bucket planning (elastic PR: the packing the 2-level reduction
# and the interconnect model both assume)
# ---------------------------------------------------------------------------


_LEAF = st.tuples(st.integers(1, 3000),
                  st.sampled_from(("float32", "bfloat16", "int32")))


@given(leaves=st.lists(_LEAF, min_size=0, max_size=12),
       bucket_kb=st.sampled_from((1, 4, 16)))
@settings(max_examples=25, deadline=None)
def test_plan_buckets_greedy_packing_invariants(leaves, bucket_kb):
    """For ANY leaf sizes/dtypes: the plan partitions the leaf indices
    EXACTLY in flatten order, every bucket is dtype-uniform (buckets are
    concatenated), and no bucket exceeds the cap unless it is a single
    oversize leaf."""
    arrs = [np.zeros(n, jnp.dtype(d)) for n, d in leaves]
    cap = bucket_kb * 1024
    plan = collectives.plan_buckets(arrs, cap)
    assert [i for b in plan for i in b] == list(range(len(arrs)))
    for b in plan:
        assert len({arrs[i].dtype for i in b}) <= 1
        total = sum(arrs[i].size * arrs[i].dtype.itemsize for i in b)
        assert total <= cap or len(b) == 1


def test_plan_buckets_rejects_nonpositive_cap():
    with pytest.raises(ValueError, match="bucket_bytes"):
        collectives.plan_buckets([np.zeros(4, np.float32)], 0)


@given(leaves=st.lists(_LEAF, min_size=0, max_size=12),
       bucket_kb=st.sampled_from((1, 4, 16)))
@settings(max_examples=25, deadline=None)
def test_reverse_bucket_schedule_is_exact_permutation(leaves, bucket_kb):
    """The overlap reducer's issue order: reverse_bucket_schedule must be
    EXACTLY plan_buckets reversed — same buckets, same intra-bucket leaf
    order, no leaf dropped or duplicated.  (A dropped leaf would silently
    skip its gradient reduction; a duplicate would double-reduce.)"""
    arrs = [np.zeros(n, jnp.dtype(d)) for n, d in leaves]
    cap = bucket_kb * 1024
    plan = collectives.plan_buckets(arrs, cap)
    sched = collectives.reverse_bucket_schedule(arrs, cap)
    assert sched == list(reversed(plan))
    flat = sorted(i for b in sched for i in b)
    assert flat == list(range(len(arrs)))


# ---------------------------------------------------------------------------
# checkpoint roundtrip over random pytrees / dtypes / shardings
# ---------------------------------------------------------------------------


_CKPT_LEAF = st.tuples(
    st.lists(st.integers(1, 5), min_size=0, max_size=3),   # shape (incl. 0-d)
    st.sampled_from(("float32", "float16", "int32")))


@given(leaves=st.lists(_CKPT_LEAF, min_size=1, max_size=5),
       seed=st.integers(0, 1000), nest=st.booleans())
@settings(**SETTINGS)
def test_checkpoint_roundtrip_random_pytrees(tmp_path_factory, leaves,
                                             seed, nest):
    """save -> restore is the identity for ANY pytree of mesh-placed
    arrays (mixed shapes/dtypes, flat or nested), preserving dtype; and
    dropping ANY leaf from the template raises naming its key path (the
    strict-restore contract)."""
    from repro.train import checkpoint as ckpt_lib
    mesh = jax.make_mesh((1,), ("data",))
    rep = jax.sharding.NamedSharding(mesh, P())
    rng = np.random.default_rng(seed)
    tree = {}
    for i, (shape, dt) in enumerate(leaves):
        leaf = jnp.asarray(rng.normal(size=shape) * 10, jnp.dtype(dt))
        tree[f"p{i}"] = {"w": jax.device_put(leaf, rep)} if nest \
            else jax.device_put(leaf, rep)
    path = str(tmp_path_factory.mktemp("ck"))
    ckpt_lib.save(path, tree, step=seed)
    back = ckpt_lib.restore(path, jax.tree.map(np.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    victim = f"p{rng.integers(len(leaves))}"
    partial = {k: v for k, v in tree.items() if k != victim}
    if partial:
        with pytest.raises(ValueError, match=victim):
            ckpt_lib.restore(path, jax.tree.map(np.zeros_like, partial))


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------


@given(
    trip=st.integers(1, 100),
    dim0=st.integers(1, 64),
    dim1=st.sampled_from((1, 8, 128)),
    dtype=st.sampled_from(("f32", "bf16", "s32")),
)
@settings(**SETTINGS)
def test_collective_scaling_parametric(trip, dim0, dim1, dtype):
    nbytes = {"f32": 4, "bf16": 2, "s32": 4}[dtype]
    hlo = f"""\
HloModule m

%body.7 (p: (s32[], {dtype}[{dim0},{dim1}])) -> (s32[], {dtype}[{dim0},{dim1}]) {{
  %ar = {dtype}[{dim0},{dim1}] all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], {dtype}[{dim0},{dim1}]) tuple(%i, %ar)
}}

%cond.7 (p: (s32[], {dtype}[{dim0},{dim1}])) -> pred[] {{
  %lim = s32[] constant({trip})
  ROOT %cmp = pred[] compare(%iter, %lim), direction=LT
}}

ENTRY %main (a: {dtype}[{dim0},{dim1}]) -> {dtype}[{dim0},{dim1}] {{
  %w = (s32[], {dtype}[{dim0},{dim1}]) while(%init), condition=%cond.7, body=%body.7
  ROOT %out = {dtype}[{dim0},{dim1}] get-tuple-element(%w), index=1
}}
"""
    stats = collectives.collective_stats(hlo)
    assert stats["all-reduce"]["bytes"] == trip * dim0 * dim1 * nbytes
    assert stats["all-reduce"]["count"] == trip


# ---------------------------------------------------------------------------
# generic kernel-autotune registry (kernels/autotune)
# ---------------------------------------------------------------------------


def _random_signature(draw):
    """A random signature from a random registered family, with the
    matching random schedule."""
    from repro.kernels import autotune as autotune_lib
    from repro.kernels.conv3d import tiles as conv_tiles
    from repro.kernels.flash_attention import tune as attn_tune
    from repro.kernels.ssm_scan import tune as ssm_tune

    family = draw(st.sampled_from(("conv3d", "attn", "ssm")))
    dtype = draw(st.sampled_from((None, jnp.float32, jnp.bfloat16)))
    dim = st.integers(1, 512)
    if family == "conv3d":
        sig = conv_tiles.signature(
            draw(st.sampled_from(("conv", "conv_t", "dw", "dw_t"))),
            tuple(draw(st.lists(dim, min_size=3, max_size=3))),
            draw(dim), draw(dim), 3, draw(st.sampled_from((1, 2))), dtype)
        sched = conv_tiles.ConvTiles(
            bn=draw(st.sampled_from((8, 64, 128))),
            fuse_taps=draw(st.booleans()))
    elif family == "attn":
        sig = attn_tune.signature(draw(dim), draw(dim), draw(dim),
                                  draw(dim), draw(dim),
                                  draw(st.booleans()), draw(dim), dtype)
        sched = attn_tune.AttnBlocks(
            block_q=draw(st.sampled_from((32, 128, 512))),
            block_kv=draw(st.sampled_from((32, 128, 512))))
    else:
        sig = ssm_tune.signature(draw(dim), draw(dim), draw(dim),
                                 draw(dim), dtype)
        sched = ssm_tune.ScanChunks(chunk=draw(st.sampled_from((16, 64,
                                                                256))))
    return sig, sched


@given(data=st.data())
@settings(**SETTINGS)
def test_autotune_cache_roundtrip_any_family(data, tmp_path_factory):
    """save_cache -> load_cache is the identity for ANY signature of ANY
    registered family — the cross-process contract every kernel's
    schedule lookup relies on."""
    from repro.kernels import autotune as autotune_lib

    cache = str(tmp_path_factory.mktemp("autotune"))
    entries = {}
    for _ in range(data.draw(st.integers(1, 4))):
        sig, sched = _random_signature(data.draw)
        entries[sig] = sched
    try:
        autotune_lib.clear_registry()     # warm-loaded entries would leak
        for sig, sched in entries.items():
            autotune_lib.register_schedule(sig, sched)
        autotune_lib.save_cache(cache_dir=cache)
        autotune_lib.clear_registry()
        n = autotune_lib.load_cache(cache_dir=cache)
        assert n == len(entries)
        for sig, sched in entries.items():
            assert autotune_lib.get_schedule(sig) == sched
    finally:
        autotune_lib.clear_registry()


@given(data=st.data(), garbage=st.text(max_size=64))
@settings(**SETTINGS)
def test_autotune_corrupt_cache_falls_back_to_default(data, garbage,
                                                      tmp_path_factory):
    """ANY corrupt cache content must never break a schedule lookup —
    get_schedule's lazy warm-load swallows it and falls back to the
    family heuristic default."""
    import os

    from repro.kernels import autotune as autotune_lib

    cache = tmp_path_factory.mktemp("autotune")
    kind = autotune_lib._device_kind()
    (cache / f"{kind}.json").write_text(garbage)
    sig, _ = _random_signature(data.draw)
    old_env = os.environ.get("REPRO_AUTOTUNE_DIR")
    os.environ["REPRO_AUTOTUNE_DIR"] = str(cache)
    try:
        autotune_lib.clear_registry()
        assert autotune_lib.load_cache(cache_dir=str(cache)) == 0
        # the warm-load path inside get_schedule reads the same corrupt
        # file (via REPRO_AUTOTUNE_DIR) and must still yield the default
        assert autotune_lib.get_schedule(sig) == \
            autotune_lib.default_schedule(sig)
    finally:
        autotune_lib.clear_registry()
        if old_env is None:
            os.environ.pop("REPRO_AUTOTUNE_DIR", None)
        else:
            os.environ["REPRO_AUTOTUNE_DIR"] = old_env


@given(data=st.data())
@settings(**SETTINGS)
def test_autotune_candidates_nonempty_and_schedule_valid(data):
    """For ANY shape, every family's candidate space is non-empty, holds
    only instances of the family's schedule class, and contains the
    heuristic default's type."""
    import dataclasses as dc

    from repro.kernels import autotune as autotune_lib

    sig, _ = _random_signature(data.draw)
    spec = autotune_lib.spec_for(sig)
    cands = autotune_lib.candidate_schedules(sig)
    assert cands
    for c in cands:
        assert isinstance(c, spec.schedule_cls)
        for f in dc.fields(c):
            v = getattr(c, f.name)
            if isinstance(v, int) and not isinstance(v, bool):
                assert v > 0, f"non-positive schedule field {f.name}={v}"
    assert isinstance(autotune_lib.default_schedule(sig),
                      spec.schedule_cls)


@given(data=st.data())
@settings(**SETTINGS)
def test_autotune_manual_registration_beats_disk(data, tmp_path_factory):
    """An in-memory register_schedule always wins over a different
    schedule persisted on disk for the same signature."""
    from repro.kernels import autotune as autotune_lib

    cache = str(tmp_path_factory.mktemp("autotune"))
    sig, disk_sched = _random_signature(data.draw)
    manual = autotune_lib.default_schedule(sig)
    if manual == disk_sched:        # make them observably different
        import dataclasses as dc
        f = dc.fields(disk_sched)[0].name
        v = getattr(disk_sched, f)
        disk_sched = dc.replace(
            disk_sched, **{f: (v + 1 if isinstance(v, int)
                               and not isinstance(v, bool) else not v)})
    try:
        autotune_lib.register_schedule(sig, disk_sched)
        autotune_lib.save_cache(cache_dir=cache)
        autotune_lib.clear_registry()
        autotune_lib.register_schedule(sig, manual)
        autotune_lib.load_cache(cache_dir=cache)
        assert autotune_lib.get_schedule(sig) == manual
    finally:
        autotune_lib.clear_registry()


# ---------------------------------------------------------------------------
# flash-decode split-KV combine: invariant to the split partition
# ---------------------------------------------------------------------------


@given(data=st.data())
@settings(**SETTINGS)
def test_decode_combine_invariant_to_split_partition(data):
    """For ANY contiguous partition of the KV axis (any split count, any
    cut points, empty splits included) and ANY order of the splits, the
    online-softmax combine equals the direct un-split softmax."""
    from repro.kernels.flash_attention import decode as decode_lib

    G = data.draw(st.integers(1, 4), label="groups")
    T = data.draw(st.integers(1, 64), label="kv_len")
    D = data.draw(st.integers(1, 8), label="d_head")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31), "seed"))
    s = jnp.asarray(rng.normal(0, 3, (G, T)), jnp.float32)
    vv = jnp.asarray(rng.normal(0, 1, (T, D)), jnp.float32)
    direct = jax.nn.softmax(s, axis=-1) @ vv

    n_cuts = data.draw(st.integers(0, 6), label="n_cuts")
    cuts = sorted(data.draw(st.lists(st.integers(0, T), min_size=n_cuts,
                                     max_size=n_cuts), label="cuts"))
    bounds = list(zip([0] + cuts, cuts + [T]))      # may contain empties
    order = data.draw(st.permutations(range(len(bounds))), label="order")

    accs, ms, ls = [], [], []
    for i in order:
        lo, hi = bounds[i]
        if hi == lo:                                # empty split partial
            accs.append(jnp.zeros((G, D)))
            ms.append(jnp.full((G,), decode_lib.NEG_INF))
            ls.append(jnp.zeros((G,)))
        else:
            blk = s[:, lo:hi]
            m = jnp.max(blk, axis=-1)
            e = jnp.exp(blk - m[:, None])
            accs.append(e @ vv[lo:hi])
            ms.append(m)
            ls.append(jnp.sum(e, axis=-1))
    out = decode_lib.combine_splits(jnp.stack(accs), jnp.stack(ms),
                                    jnp.stack(ls))
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               atol=2e-5)
