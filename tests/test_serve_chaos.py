"""Chaos suite for the resilient serving runtime (scheduler + replicas).

The serving acceptance bar mirrors the elastic training one: every fault
is scripted from a deterministic `train/faults.FaultPlan` (dispatch-
indexed, fire-once), every clock is injected, and the assertions are
exact — a replica kill mid-traffic must return showers BIT-IDENTICAL to
the fault-free run (per-event fold_in RNG makes a bucket step a pure
function of its inputs), a dead deadline must become a structured
rejection rather than a hang, an overload's shed count must replay
exactly under a seeded arrival trace, and a PhysicsGate drift alarm must
produce the degraded-mode ladder (shed low priority, structured report).
The committed CI trace (``results/serve_chaos_trace.json``) is replayed
twice here, same as the elastic smoke discipline.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import calo3dgan
from repro.core import gan, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.launch.mesh import make_dev_mesh
from repro.serve.replicas import (NoHealthyReplicas, ReplicaFaultInjector,
                                  ReplicaGroup)
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.serve.simulate import PhysicsGate, SimRequest, SimulateEngine
from repro.train.faults import FaultEvent, FaultPlan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRACE = os.path.join(REPO, "results", "serve_chaos_trace.json")
CFG = calo3dgan.bench()


@pytest.fixture(scope="module")
def g_params():
    return gan.init_generator(jax.random.key(0), CFG)


class Ticker:
    """Injected clock: advances only when the test says so."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t


def _engine(g_params, **kw):
    kw.setdefault("buckets", (4, 16))
    kw.setdefault("mesh", make_dev_mesh())
    return SimulateEngine(CFG, g_params, **kw)


def _requests(sizes, **kw):
    return [SimRequest(rid=i, primary_energy=100.0 + i, n_events=n,
                       seed=i, **kw) for i, n in enumerate(sizes)]


# ---------------------------------------------------------------------------
# replica failover: bit-identical showers
# ---------------------------------------------------------------------------


def test_replica_kill_failover_bit_identical(g_params):
    """A replica killed mid-traffic: its bucket step re-dispatches onto
    the survivor and every request's showers are BIT-IDENTICAL to the
    fault-free run — the tentpole acceptance bar."""
    sizes = [3, 5, 17, 1]
    clean = _engine(g_params)
    for r in _requests(sizes):
        clean.submit(r)
    baseline = {r.rid: r.images for r in clean.run()}

    # dispatch 1 round-robins onto rank 1 — the kill hits the replica
    # actually chosen for that bucket step
    plan = FaultPlan(events=(
        FaultEvent(1, "preempt", node=1, lose_node=False),))
    group = ReplicaGroup(2, injector=ReplicaFaultInjector(plan),
                         sleep=lambda s: None)
    eng = _engine(g_params, replicas=group)
    for r in _requests(sizes):
        eng.submit(r)
    done = {r.rid: r for r in eng.run()}

    assert group.stats["failovers"] == 1
    assert group.stats["respawns"] == 1          # lose_node=False came back
    assert len(done) == len(sizes) and not eng.rejected
    for rid, img in baseline.items():
        np.testing.assert_array_equal(img, done[rid].images)


def test_replica_stall_hedged_and_bit_identical(g_params):
    """A long scripted stall is hedged onto a peer (bounded wait, never
    the full stall) and numerics are untouched."""
    baseline = _engine(g_params).generate_events(150.0, 7, seed=4)
    plan = FaultPlan(events=(
        FaultEvent(0, "stall", node=0, stall_ms=5000.0),))
    waits = []
    group = ReplicaGroup(2, injector=ReplicaFaultInjector(plan),
                         hedge_stall_ms=200.0, sleep=waits.append)
    eng = _engine(g_params, replicas=group)
    img = eng.generate_events(150.0, 7, seed=4)
    assert group.stats["hedges"] == 1
    assert waits and max(waits) <= 0.2 + 1e-9    # never the 5s stall
    np.testing.assert_array_equal(baseline, img)


def test_total_outage_rejects_capacity_not_hang(g_params):
    """Both replicas dead: the queue is drained with structured
    ``capacity`` rejections and a degraded report — run() returns."""
    plan = FaultPlan(events=(
        FaultEvent(0, "preempt", node=0, lose_node=True),
        FaultEvent(0, "preempt", node=1, lose_node=True)))
    group = ReplicaGroup(2, injector=ReplicaFaultInjector(plan),
                         sleep=lambda s: None)
    eng = _engine(g_params, replicas=group)
    reqs = _requests([3, 9])
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert done == []
    assert [r.error["reason"] for r in eng.rejected] == ["capacity"] * 2
    assert all(r.status == "rejected" for r in reqs)
    report = eng.degraded_report()
    assert report["mode"] == "no_healthy_replicas"
    assert report["replicas"]["healthy"] == 0


def test_committed_trace_replays_identically(g_params):
    """The CI smoke contract: replaying results/serve_chaos_trace.json
    twice produces identical showers, identical failover/respawn/hedge
    counts, and identical health reports."""
    plan = FaultPlan.load(TRACE)

    def run_once():
        group = ReplicaGroup(2, injector=ReplicaFaultInjector(plan),
                             hedge_stall_ms=200.0, sleep=lambda s: None)
        # buckets=(4,) gives the 37-event trace 10 dispatches, spanning
        # every scripted fault index
        eng = _engine(g_params, buckets=(4,), replicas=group)
        for r in _requests([3, 5, 17, 1, 9, 2]):
            eng.submit(r)
        done = {r.rid: r.images for r in eng.run()}
        return done, dict(group.stats), group.health_report()

    a_imgs, a_stats, a_health = run_once()
    b_imgs, b_stats, b_health = run_once()
    assert sorted(a_imgs) == sorted(b_imgs) and len(a_imgs) == 6
    for rid in a_imgs:
        np.testing.assert_array_equal(a_imgs[rid], b_imgs[rid])
    a_stats.pop("backoff_s"), b_stats.pop("backoff_s")
    assert a_stats == b_stats
    assert a_health == b_health
    # the trace bites: one respawn kill, one hedge, one permanent kill
    assert a_stats["failovers"] == 2
    assert a_stats["hedges"] == 1
    assert a_stats["respawns"] == 1
    assert a_health["healthy"] == 1              # rank 1 stays dead


# ---------------------------------------------------------------------------
# deadlines: structured rejection, never a hang or a silent late serve
# ---------------------------------------------------------------------------


def test_deadline_expiry_in_queue_structured_rejection(g_params):
    clock = Ticker()
    eng = _engine(g_params, clock=clock)
    doomed = SimRequest(rid=0, primary_energy=90.0, n_events=3, seed=0,
                        deadline_s=1.0)
    fine = SimRequest(rid=1, primary_energy=90.0, n_events=3, seed=1)
    eng.submit(doomed)
    eng.submit(fine)
    clock.t = 2.0                                # the SLA window passes
    done = eng.run()
    assert [r.rid for r in done] == [1]
    assert doomed.status == "rejected" and doomed.images is None
    assert doomed.error["reason"] == "deadline"
    assert "expired in queue" in doomed.error["detail"]


def test_deadline_already_expired_or_infeasible_at_admission(g_params):
    clock = Ticker(10.0)
    eng = _engine(g_params, clock=clock,
                  sched=SchedulerConfig(drain_rate_ev_s=10.0))
    dead = SimRequest(rid=0, primary_energy=50.0, n_events=2, seed=0,
                      deadline_s=-1.0)
    eng.submit(dead)
    assert dead.status == "rejected"
    assert dead.error["reason"] == "deadline"
    # 100 events at 10 ev/s need 10s; a 1s deadline can never be met
    hopeless = SimRequest(rid=1, primary_energy=50.0, n_events=100, seed=1,
                          deadline_s=1.0)
    eng.submit(hopeless)
    assert hopeless.status == "rejected"
    assert "infeasible" in hopeless.error["detail"]
    assert eng.scheduler.queue_depth() == 0


def test_completed_late_is_rejected_not_served(g_params, monkeypatch):
    """A request whose last event lands after its deadline must come back
    as a structured ``deadline`` rejection, not a silently-late result."""
    clock = Ticker()
    eng = _engine(g_params, clock=clock)
    real_dispatch = eng._dispatch

    def slow_dispatch(bucket, inputs):           # each step costs 1.0s
        out = real_dispatch(bucket, inputs)
        clock.t += 1.0
        return out

    monkeypatch.setattr(eng, "_dispatch", slow_dispatch)
    late = SimRequest(rid=0, primary_energy=70.0, n_events=3, seed=0,
                      deadline_s=0.5)
    eng.submit(late)
    done = eng.run()
    assert done == [] and late.status == "rejected"
    assert late.error["reason"] == "deadline"
    assert "past its deadline" in late.error["detail"]
    assert eng.stats["events_wasted"] == 3


# ---------------------------------------------------------------------------
# admission control / overload shedding
# ---------------------------------------------------------------------------


def test_overload_shed_count_deterministic_seeded_trace(g_params):
    """A seeded arrival trace over the SLA-derived admission bound sheds
    an EXACT, replayable set of requests — run twice, compare."""
    def run_once():
        clock = Ticker()
        eng = _engine(g_params, clock=clock,
                      sched=SchedulerConfig(max_queue_events=24))
        rng = np.random.default_rng(0)
        reqs = [SimRequest(rid=i, primary_energy=float(rng.uniform(20, 400)),
                           n_events=int(rng.integers(1, 12)),
                           seed=i, priority=int(rng.integers(0, 3)))
                for i in range(16)]
        for r in reqs:
            eng.submit(r)
        shed = sorted(r.rid for r in eng.rejected)
        reasons = {r.error["reason"] for r in eng.rejected}
        done = eng.run()
        return shed, reasons, len(done), eng.scheduler.stats["rejected"]

    a = run_once()
    b = run_once()
    assert a == b                                 # bit-for-bit replay
    shed, reasons, n_done, counts = a
    assert shed and reasons == {"overload"}
    assert n_done + len(shed) == 16               # nothing lost silently
    assert counts["overload"] == len(shed)


def test_admission_evicts_lower_priority_first(g_params):
    clock = Ticker()
    eng = _engine(g_params, clock=clock,
                  sched=SchedulerConfig(max_queue_events=8))
    lo = SimRequest(rid=0, primary_energy=50.0, n_events=6, seed=0,
                    priority=0)
    hi = SimRequest(rid=1, primary_energy=50.0, n_events=6, seed=1,
                    priority=2)
    eng.submit(lo)
    eng.submit(hi)                                # over the bound: evict lo
    assert lo.status == "rejected" and lo.error["reason"] == "overload"
    assert "evicted" in lo.error["detail"]
    done = eng.run()
    assert [r.rid for r in done] == [1]


# ---------------------------------------------------------------------------
# graceful degradation: PhysicsGate drift alarm
# ---------------------------------------------------------------------------


def test_gate_drift_sheds_low_priority_with_report(g_params):
    """An untrained generator trips the gate after its first window; the
    engine enters quality-degraded mode: queued priority-0 work is shed
    with reason ``degraded``, priority>=1 keeps being served, later
    low-priority arrivals are refused at the door, and the structured
    report says why."""
    mc = next(CaloSimulator(CaloSpec(image_shape=CFG.image_shape),
                            seed=0).batches(64))
    gate = PhysicsGate(validation.reference_profiles(mc["image"], mc["e_p"]),
                       window=4)
    eng = _engine(g_params, buckets=(4,), gate=gate, max_kl=0.0,
                  sched=SchedulerConfig(degrade_shed_below=1))
    hi = SimRequest(rid=0, primary_energy=200.0, n_events=8, seed=0,
                    priority=1)
    lo = SimRequest(rid=1, primary_energy=200.0, n_events=8, seed=1,
                    priority=0)
    eng.submit(hi)
    eng.submit(lo)
    done = eng.run()
    assert [r.rid for r in done] == [0]           # high priority survives
    assert lo.status == "rejected"
    assert lo.error["reason"] == "degraded"
    assert "drifted" in lo.error["detail"]
    report = eng.degraded_report()
    assert report["mode"] == "gate_drift" and report["drifted"]
    assert report["shed"]["degraded"] == 1
    # degraded mode also gates the door
    late_lo = SimRequest(rid=2, primary_energy=100.0, n_events=2, seed=2,
                         priority=0)
    eng.submit(late_lo)
    assert late_lo.status == "rejected"
    assert late_lo.error["reason"] == "degraded"


def test_healthy_report_by_default(g_params):
    eng = _engine(g_params)
    eng.generate_events(100.0, 3, seed=0)
    report = eng.degraded_report()
    assert report["mode"] == "healthy" and not report["transitions"]
    assert report["served"] == 1 and report["rejected"] == 0


# ---------------------------------------------------------------------------
# anti-starvation: age-based promotion (satellite regression)
# ---------------------------------------------------------------------------


def _starvation_trace(config):
    """Mixed arrival trace at the scheduler level: an old small request
    races a continuous stream of newer high-priority large ones."""
    sched = Scheduler(config, clock=Ticker())
    sched.admit("old-small", rid=0, n_events=2, priority=0)
    served_at = None
    for step in range(8):
        sched.admit(f"hi-{step}", rid=step + 1, n_events=4, priority=5)
        plan = sched.plan_step((4,))
        assert plan is not None
        if any(e.item == "old-small" for e, _ in plan[1]):
            served_at = step
            break
        sched.commit(plan)
    return served_at


def test_age_promotion_prevents_starvation():
    """Without promotion the old request starves behind the stream; with
    ``promote_after_steps`` it jumps the order within the bound."""
    assert _starvation_trace(SchedulerConfig()) is None
    served_at = _starvation_trace(SchedulerConfig(promote_after_steps=2))
    assert served_at is not None and served_at <= 3


def test_promotion_mixed_arrivals_engine_level(g_params):
    """Engine-level mixed arrival trace: a 2-event request submitted
    first must not wait out six 4-event priority-5 arrivals when
    promotion is on."""
    eng = _engine(g_params, buckets=(4,),
                  sched=SchedulerConfig(promote_after_steps=2))
    small = SimRequest(rid=0, primary_energy=80.0, n_events=2, seed=0,
                       priority=0)
    eng.submit(small)
    for i in range(1, 7):
        eng.submit(SimRequest(rid=i, primary_energy=80.0, n_events=4,
                              seed=i, priority=5))
        eng.run(max_steps=1)
        if small.done:
            break
    assert small.done and small.images.shape[0] == 2
    assert eng.scheduler.stats["promotions"] >= 1


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------


def test_plan_is_pure_commit_applies():
    sched = Scheduler(SchedulerConfig(), clock=Ticker())
    sched.admit("a", rid=0, n_events=6, priority=0)
    plan = sched.plan_step((4,))
    assert sched.backlog_events() == 6            # planning consumed nothing
    sched.commit(plan)
    assert sched.backlog_events() == 2
    again = sched.plan_step((4,))
    sched.commit(again)
    assert sched.backlog_events() == 0
    assert sched.plan_step((4,)) is None


def test_rejection_reason_validated():
    from repro.serve.scheduler import Rejection
    with pytest.raises(ValueError, match="reason"):
        Rejection(0, "bored", "nope")


def test_replica_group_raises_on_empty_and_exhausted():
    with pytest.raises(ValueError):
        ReplicaGroup(0)
    plan = FaultPlan(events=(
        FaultEvent(0, "preempt", node=0, lose_node=True),))
    group = ReplicaGroup(1, injector=ReplicaFaultInjector(plan),
                         sleep=lambda s: None)
    with pytest.raises(NoHealthyReplicas):
        group.dispatch(lambda rep: "unreachable")
