"""End-to-end behaviour: training reduces loss, the serving engine serves,
and the build layer lowers + compiles on the dev mesh (the same code path
the 512-chip dry-run exercises)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as config_base
from repro.data.tokens import MarkovTokens
from repro.launch.mesh import make_dev_mesh
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.serve.engine import Request, ServeEngine
from repro.substrate.precision import get_policy
from repro.train import steps as steps_lib

POLICY = get_policy("f32")


def _cost(compiled) -> dict:
    """cost_analysis() returns a per-device list on newer jax versions."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca


def test_lm_training_reduces_loss():
    """40 steps on the low-entropy Markov stream: loss must drop clearly."""
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    opt = opt_lib.adamw(3e-3)
    ostate = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(model, cfg, opt, POLICY),
                   donate_argnums=(0, 1))
    data = MarkovTokens(cfg.vocab, seed=0)
    losses = []
    for i in range(40):
        batch = {"tokens": jnp.asarray(data.sample(8, 128))}
        params, ostate, m = step(params, ostate, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, \
        losses[:3] + losses[-3:]


def test_ssm_training_reduces_loss():
    """The recurrent family trains too (different gradient path: scans)."""
    cfg = config_base.reduced_config("xlstm-125m")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    opt = opt_lib.adamw(3e-3)
    ostate = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(model, cfg, opt, POLICY),
                   donate_argnums=(0, 1))
    data = MarkovTokens(cfg.vocab, seed=1)
    losses = []
    for i in range(30):
        batch = {"tokens": jnp.asarray(data.sample(8, 128))}
        params, ostate, m = step(params, ostate, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_serve_engine_end_to_end():
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 6,
                                               dtype=np.int32),
                           max_new_tokens=5))
    done = eng.run()
    assert len(done) == 4
    for r in done:
        assert len(r.tokens) == 5
        assert all(0 <= t < cfg.vocab for t in r.tokens)


def test_build_lowers_and_compiles_on_dev_mesh():
    """The dry-run build path compiles on the real (1-CPU) mesh for a
    reduced arch — catching spec/tree mismatches without the 512-dev run."""
    import repro.configs.base as cb
    from repro.launch import build as build_lib

    mesh = make_dev_mesh()
    arch = "olmoe-1b-7b"
    orig = cb.get_config
    try:
        cb.get_config = lambda a: (config_base.reduced_config(a)
                                   if a == arch else orig(a))
        with mesh:
            built = build_lib.build_train(arch, "train_4k", mesh,
                                          rules_name="dp")
            b = {"tokens": jax.ShapeDtypeStruct((2, 256), jnp.int32)}
            lowered = built.fn.lower(built.args[0], built.args[1], b)
            compiled = lowered.compile()
            assert _cost(compiled).get("flops", 0) > 0
    finally:
        cb.get_config = orig


def test_gan_build_lowers_on_dev_mesh():
    from repro.launch import build as build_lib
    mesh = make_dev_mesh()
    with mesh:
        built = build_lib.build_gan_train(mesh, reduced=True,
                                          policy_name="f32")
        compiled = built.lower().compile()
        assert _cost(compiled).get("flops", 0) > 0


def test_ragged_engine_matches_single_request():
    """Per-slot vector positions: a request served alongside OTHER ragged
    requests must produce the same tokens as served alone."""
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 9, 7)]

    # alone
    solo = []
    for p in prompts:
        eng = ServeEngine(cfg, params, slots=1, max_len=64)
        eng.submit(Request(rid=0, prompt=p, max_new_tokens=4))
        solo.append(eng.run()[0].tokens)

    # together, ragged
    eng = ServeEngine(cfg, params, slots=3, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    together = {r.rid: r.tokens for r in eng.run()}
    for i in range(3):
        assert together[i] == solo[i], (i, together[i], solo[i])


def test_engine_eos_stops_early():
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 5, dtype=np.int32)
    # find what the model emits first, then use it as the eos token
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    first = eng.run()[0].tokens[0]
    eng2 = ServeEngine(cfg, params, slots=1, max_len=64)
    eng2.submit(Request(rid=1, prompt=prompt, max_new_tokens=8,
                        eos_id=int(first)))
    done = eng2.run()[0]
    assert done.tokens[-1] == first
    assert len(done.tokens) < 8


def test_engine_serves_recurrent_arch():
    """The engine is family-agnostic: xlstm's O(1) state cache serves the
    same way as a KV cache."""
    cfg = config_base.reduced_config("xlstm-125m")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 4 + rid,
                                               dtype=np.int32),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.tokens) == 4 for r in done)


def test_ragged_engine_recurrent_state_isolation():
    """Recurrent-state version of the ragged test: serving alongside other
    requests must not perturb a request's state (regression for the
    snapshot/merge fix in ServeEngine._prefill_slot)."""
    cfg = config_base.reduced_config("xlstm-125m")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (4, 8)]
    solo = []
    for p in prompts:
        eng = ServeEngine(cfg, params, slots=1, max_len=64)
        eng.submit(Request(rid=0, prompt=p, max_new_tokens=4))
        solo.append(eng.run()[0].tokens)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    together = {r.rid: r.tokens for r in eng.run()}
    for i in range(2):
        assert together[i] == solo[i], (i, together[i], solo[i])


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-1.2b"])
def test_chunked_prefill_matches_sequential(arch):
    """The chunked batched prefill path must emit BIT-IDENTICAL tokens to
    the legacy sequential prefill, including mid-run slot refills with
    other slots actively decoding (5 requests through 3 slots)."""
    cfg = config_base.reduced_config(arch)
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 12, 3, 9, 7)]

    results = {}
    for mode in ("sequential", "chunked"):
        eng = ServeEngine(cfg, params, slots=3, max_len=64,
                          prefill=mode, prefill_chunk=4)
        assert eng.prefill_mode == mode
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        results[mode] = {r.rid: r.tokens for r in eng.run()}
    assert results["chunked"] == results["sequential"]


def test_chunked_prefill_freezes_other_slots():
    """A chunked prefill of a newly-filled slot must not advance the
    decode position or next-token state of slots that are mid-decode."""
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64,
                      prefill="chunked", prefill_chunk=4)
    rng = np.random.default_rng(2)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6,
                                                  dtype=np.int32),
                       max_new_tokens=10))
    eng._fill_slots()
    eng._step()
    pos0, tok0 = int(eng.pos[0]), int(eng.cur_tok[0, 0])

    eng.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab, 11,
                                                  dtype=np.int32),
                       max_new_tokens=10))
    eng._fill_slots()            # chunked prefill of slot 1 only
    assert int(eng.pos[0]) == pos0
    assert int(eng.cur_tok[0, 0]) == tok0
    assert int(eng.pos[1]) == 11
    done = eng.run()
    assert sorted(len(r.tokens) for r in done) == [10, 10]


def test_chunked_prefill_mode_validation():
    """auto falls back to sequential for archs without a chunked prefill
    path; asking for chunked explicitly there is an error."""
    cfg = config_base.reduced_config("xlstm-125m")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, max_len=64, prefill="auto")
    assert eng.prefill_mode == "sequential"
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, slots=1, max_len=64, prefill="chunked")
    kcfg = config_base.reduced_config("qwen2-1.5b")
    kmodel = api.get_model(kcfg)
    kparams = kmodel.init(jax.random.key(0), kcfg)
    keng = ServeEngine(kcfg, kparams, slots=1, max_len=64)
    assert keng.prefill_mode == "chunked"     # auto picks it up


def test_engine_deadline_expires_in_flight_request():
    """A request whose SLA deadline passes MID-DECODE is rejected with a
    structured deadline rejection and frees its slot (regression: the
    sweep used to cover only queued requests)."""
    class _Clock:
        t = 0.0

        def __call__(self):
            return self.t

    clk = _Clock()
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, max_len=64, clock=clk)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 5,
                                                  dtype=np.int32),
                       max_new_tokens=20, deadline_s=5.0))
    eng._fill_slots()
    eng._step()
    eng._step()
    clk.t = 10.0                 # SLA blown with the request in a slot
    eng._sweep_slot_deadlines()
    assert eng.slot_req[0] is None
    (req,) = eng.rejected
    assert req.status == "rejected"
    assert req.error["reason"] == "deadline"
    assert "mid-decode" in req.error["detail"]
    assert 0 < len(req.tokens) < 20
    assert eng.run() == []       # engine is drained and idle again
