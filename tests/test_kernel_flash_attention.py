"""Flash-attention Pallas kernel family: forward AND backward parity vs
the pure-JAX reference (interpret mode on CPU), the no-score-matrix
guarantee in the lowered HLO of the BACKWARD (no ref-oracle fallback), a
grad-check through a full use_pallas_attn LM training step, and the
shared autotune registry routes.

This is the attention half of the kernel tier-1 suite — CI runs it
fail-fast alongside test_kernel_conv3d.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as autotune_lib
from repro.kernels.flash_attention import tune as tune_lib
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_bwd, flash_attention_fwd)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(11)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


def _qkv(B, S, T, H, KH, D, dtype=jnp.float32):
    return (_randn((B, S, H, D), dtype), _randn((B, T, KH, D), dtype),
            _randn((B, T, KH, D), dtype))


FLASH_CASES = [
    # B, S, T, H, KH, D, causal, window
    (1, 128, 128, 4, 2, 32, True, 0),      # GQA, block-multiple
    (2, 160, 160, 8, 2, 24, True, 64),     # sliding window, non-128 D
    (1, 100, 100, 4, 4, 32, True, 0),      # odd seq, MHA
    (1, 64, 256, 4, 2, 32, False, 0),      # non-causal cross S != T
    (1, 72, 40, 6, 3, 16, False, 0),       # ragged cross, 3-way GQA
    (1, 300, 300, 4, 1, 64, True, 0),      # MQA, seq not block-divisible
]


# ---------------------------------------------------------------------------
# forward + backward parity vs the reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,T,H,KH,D,causal,window", FLASH_CASES)
def test_flash_fwd_bwd_parity(B, S, T, H, KH, D, causal, window):
    q, k, v = _qkv(B, S, T, H, KH, D)
    out = flash_attention(q, k, v, causal, window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    # cotangent-level parity: dq/dk/dv against jax.vjp of the reference
    _, vjp_ref = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    _, vjp_ker = jax.vjp(
        lambda q_, k_, v_: flash_attention(q_, k_, v_, causal, window),
        q, k, v)
    g = _randn(out.shape)
    for a, b in zip(vjp_ker(g), vjp_ref(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("block_q,block_kv", [(32, 32), (64, 128), (128, 64)])
def test_flash_bwd_block_sizes_are_numerics_free(block_q, block_kv):
    """The autotuner's schedule space must not change the math: every
    (block_q, block_kv) candidate reproduces the reference gradients,
    including blocks that do not divide the sequence."""
    q, k, v = _qkv(1, 96, 96, 4, 2, 32)
    _, vjp_ref = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True), q, k, v)

    def kernel(q_, k_, v_):
        out, lse = flash_attention_fwd(q_, k_, v_, causal=True, window=0,
                                       block_q=block_q, block_kv=block_kv,
                                       return_lse=True)
        return out, (out, lse)

    out, (o, lse) = kernel(q, k, v)
    g = _randn(out.shape)
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, g, causal=True,
                                     window=0, block_q=block_q,
                                     block_kv=block_kv)
    for a, b in zip((dq, dk, dv), vjp_ref(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_flash_bf16_fwd_and_bwd():
    """bf16 operands flow through fwd AND the Pallas backward (f32 score
    and accumulator math keeps the error at bf16 resolution)."""
    q32, k32, v32 = _qkv(1, 128, 128, 4, 2, 32)
    qb, kb, vb = (t.astype(jnp.bfloat16) for t in (q32, k32, v32))
    out = flash_attention(qb, kb, vb, True, 0)
    assert out.dtype == jnp.bfloat16
    ref = attention_ref(q32, k32, v32, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=3e-2)
    f = lambda q_, k_, v_: jnp.sum(
        flash_attention(q_, k_, v_, True, 0).astype(jnp.float32) ** 2)
    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(qb, kb, vb)
    assert gq.dtype == jnp.bfloat16 and gk.dtype == jnp.bfloat16
    rq, rk, rv = jax.grad(
        lambda q_, k_, v_: jnp.sum(
            attention_ref(q_, k_, v_, causal=True) ** 2),
        argnums=(0, 1, 2))(q32, k32, v32)
    np.testing.assert_allclose(np.asarray(gq, np.float32), np.asarray(rq),
                               rtol=0.1, atol=0.1)


# ---------------------------------------------------------------------------
# no ref-oracle fallback: the backward must lower to the Pallas kernels —
# the reference's (B, KH, G, S, T) score matrix must not exist in the HLO
# ---------------------------------------------------------------------------


def _score_tell(B, S, T, H, KH):
    return f"tensor<{B}x{KH}x{H // KH}x{S}x{T}xf32>"


def test_flash_bwd_hlo_has_no_materialized_scores():
    B, S, H, KH, D = 1, 128, 4, 2, 32
    q, k, v = _qkv(B, S, S, H, KH, D)
    tell = _score_tell(B, S, S, H, KH)

    def loss(op):
        return lambda q_, k_, v_: jnp.sum(op(q_, k_, v_) ** 2)

    # the tell-tale must be a VALID detector: present in the ref grad HLO
    ref_hlo = jax.jit(jax.grad(
        loss(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True)),
        (0, 1, 2))).lower(q, k, v).as_text()
    assert tell in ref_hlo, "tell-tale string no longer matches the ref"

    ker_hlo = jax.jit(jax.grad(
        loss(lambda q_, k_, v_: flash_attention(q_, k_, v_, True, 0)),
        (0, 1, 2))).lower(q, k, v).as_text()
    assert tell not in ker_hlo, \
        "flash_attention backward materialized the full score matrix " \
        "(ref-oracle fallback?)"


# ---------------------------------------------------------------------------
# grad-check through a full use_pallas_attn LM training loss
# ---------------------------------------------------------------------------


def test_lm_loss_grads_match_jax_path():
    """d(loss)/d(params) through every attention layer of the reduced LM
    — Pallas fwd and bwd kernels selected via cfg.use_pallas_attn —
    agrees with the pure-JAX attention route."""
    from repro.configs import base as config_base
    from repro.models import lm
    from repro.substrate.precision import get_policy

    policy = get_policy("f32")
    cfg = config_base.reduced_config("qwen2-1.5b")
    cfg_p = dataclasses.replace(cfg, use_pallas_attn=True)
    params = lm.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
    batch = {"tokens": tokens}

    def loss(pp, c):
        return lm.loss_fn(pp, batch, c, policy=policy)[0]

    l_ref, g_ref = jax.value_and_grad(loss)(params, cfg)
    l_pal, g_pal = jax.value_and_grad(loss)(params, cfg_p)
    np.testing.assert_allclose(float(l_pal), float(l_ref), atol=1e-4)
    for a, b in zip(jax.tree.leaves(g_pal), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)


# ---------------------------------------------------------------------------
# shared autotune registry routes
# ---------------------------------------------------------------------------


def test_attn_schedule_registry_default_and_override():
    sig = tune_lib.signature(4096, 4096, 8, 2, 64, True, 0)
    try:
        assert autotune_lib.get_schedule(sig) == tune_lib.AttnBlocks()
        autotune_lib.register_schedule(sig,
                                       tune_lib.AttnBlocks(block_q=256))
        assert autotune_lib.get_schedule(sig).block_q == 256
        # dtype-qualified lookup falls back to the registered base
        sigd = tune_lib.signature(4096, 4096, 8, 2, 64, True, 0,
                                  jnp.bfloat16)
        assert autotune_lib.get_schedule(sigd).block_q == 256
    finally:
        autotune_lib.clear_registry()


def test_attn_candidates_clamp_dedup():
    sig = tune_lib.signature(64, 64, 4, 4, 32, True, 0)
    cands = tune_lib.candidate_blocks(sig)
    assert cands, "candidate space must be non-empty"
    effs = [(min(c.block_q, 64), min(c.block_kv, 64)) for c in cands]
    assert len(effs) == len(set(effs)), "aliased effective schedules"


def test_attn_registered_blocks_drive_the_wrapper():
    """ops.flash_attention must pick registered blocks up by signature —
    and the result must be schedule-independent."""
    q, k, v = _qkv(1, 80, 80, 4, 2, 32)
    base = flash_attention(q, k, v, True, 0)
    sig = tune_lib.signature(80, 80, 4, 2, 32, True, 0, q.dtype)
    try:
        autotune_lib.register_schedule(
            sig, tune_lib.AttnBlocks(block_q=32, block_kv=32))
        out = flash_attention(q, k, v, True, 0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=1e-5)
    finally:
        autotune_lib.clear_registry()
