"""Mixed-precision GAN step: bf16 physics parity vs the f32 step, dynamic
loss-scale skip-on-nonfinite, donation under the policy, and f32 metric
accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import calo3dgan
from repro.core import adversarial, gan, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.launch.mesh import make_dev_mesh
from repro.optim import optimizers as opt_lib
from repro.substrate import precision as precision_lib
from repro.substrate.precision import get_policy
from repro.train import engine as engine_lib
from repro.train import metrics as metrics_lib

CFG = calo3dgan.bench()


def _train(policy, steps=12, batch=8, seed=0):
    g_opt = opt_lib.rmsprop(2e-4)
    d_opt = opt_lib.rmsprop(2e-4)
    state = adversarial.init_state(jax.random.key(seed), CFG, g_opt, d_opt,
                                   policy=policy)
    step = jax.jit(adversarial.make_fused_step(CFG, g_opt, d_opt,
                                               policy=policy))
    sim = CaloSimulator(CaloSpec(image_shape=CFG.image_shape), seed=seed)
    rng = jax.random.key(seed + 1)
    it = sim.batches(batch)
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        rng, k = jax.random.split(rng)
        state, m = step(state, b, k)
    return state, m


def _kls(state, seed=99, n=256):
    sim = CaloSimulator(CaloSpec(image_shape=CFG.image_shape), seed=7)
    mc = next(sim.batches(n))
    noise = jax.random.normal(jax.random.key(seed), (n, CFG.latent_dim))
    fake = gan.generate(state.g_params, noise, jnp.asarray(mc["e_p"]),
                        jnp.asarray(mc["theta"]), CFG)
    rep = validation.validation_report(
        np.asarray(fake, np.float32), mc["image"], mc["e_p"], mc["e_p"])
    return {k: rep[k] for k in ("longitudinal_kl", "transverse_x_kl",
                                "transverse_y_kl")}


# ---------------------------------------------------------------------------
# bf16 vs f32 physics parity
# ---------------------------------------------------------------------------


def test_bf16_step_preserves_physics_within_2x_gate():
    """The paper's bf16 claim: reduced-precision training must keep the
    profile divergences in the same regime as f32 — the serving gate's
    existing 2x bar, applied to the KL ratio between the two policies."""
    s32, m32 = _train(get_policy("f32"))
    s16, m16 = _train(get_policy("bf16"))
    assert "loss_scale" in m16 and "loss_scale" not in m32
    k32, k16 = _kls(s32), _kls(s16)
    for key in k32:
        ratio = (k16[key] + 1e-6) / (k32[key] + 1e-6)
        assert 0.5 <= ratio <= 2.0, (key, k32[key], k16[key])


def test_bf16_master_params_and_opt_state_stay_f32():
    state, _ = _train(get_policy("bf16"), steps=2)
    for leaf in jax.tree.leaves((state.g_params, state.d_params)):
        assert leaf.dtype == jnp.float32
    for leaf in jax.tree.leaves((state.g_opt["nu"], state.d_opt["nu"])):
        assert leaf.dtype == jnp.float32


# ---------------------------------------------------------------------------
# dynamic loss scaling: skip-on-nonfinite
# ---------------------------------------------------------------------------


def test_loss_scale_skips_nonfinite_phase_and_halves_scale():
    """A poisoned batch (NaN image) must not write NaNs into the master
    params: the D-real phase is skipped, its scale halves, and every
    param stays finite."""
    policy = get_policy("fp16")
    g_opt = opt_lib.rmsprop(1e-4)
    d_opt = opt_lib.rmsprop(1e-4)
    state = adversarial.init_state(jax.random.key(0), CFG, g_opt, d_opt,
                                   policy=policy)
    step = jax.jit(adversarial.make_fused_step(CFG, g_opt, d_opt,
                                               policy=policy))
    sim = CaloSimulator(CaloSpec(image_shape=CFG.image_shape), seed=0)
    b = {k: jnp.asarray(v) for k, v in next(sim.batches(8)).items()}
    b["image"] = b["image"].at[0, 0, 0, 0, 0].set(jnp.nan)
    scale0 = float(state.loss_scale.scale)
    state, m = step(state, b, jax.random.key(1))
    assert float(m["nonfinite_skips"]) >= 1.0
    assert float(state.loss_scale.scale) <= scale0 / 2.0
    for leaf in jax.tree.leaves((state.g_params, state.d_params)):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_loss_scale_state_machine():
    ls = precision_lib.LossScaleState(jnp.float32(1024.0),
                                      jnp.zeros((), jnp.int32))
    dn = precision_lib.next_loss_scale(ls, jnp.bool_(False), 4)
    assert float(dn.scale) == 512.0 and int(dn.good_steps) == 0
    up = ls
    for _ in range(4):
        up = precision_lib.next_loss_scale(up, jnp.bool_(True), 4)
    assert float(up.scale) == 2048.0      # grew once after 4 clean phases
    frozen = precision_lib.next_loss_scale(ls, jnp.bool_(True), 0)
    assert float(frozen.scale) == 1024.0  # growth_interval=0: bf16 mode
    floor = precision_lib.LossScaleState(jnp.float32(1.0),
                                         jnp.zeros((), jnp.int32))
    assert float(precision_lib.next_loss_scale(
        floor, jnp.bool_(False), 0).scale) == 1.0   # never below 1


def test_all_finite_and_select():
    good = {"a": jnp.ones((3,)), "b": None}
    bad = {"a": jnp.array([1.0, jnp.inf, 0.0]), "b": None}
    assert bool(precision_lib.all_finite(good))
    assert not bool(precision_lib.all_finite(bad))
    out = precision_lib.select_finite(jnp.bool_(False), bad, good)
    np.testing.assert_array_equal(np.asarray(out["a"]), 1.0)


# ---------------------------------------------------------------------------
# donation still holds under the policy
# ---------------------------------------------------------------------------


def test_donation_holds_under_bf16_policy():
    """The compiled engine step donates its state argument; under the
    bf16 policy (extra loss-scale leaves in the state) the input buffers
    must still alias — i.e. be deleted after the call."""
    mesh = make_dev_mesh()
    task = engine_lib.gan_task(calo3dgan.reduced(), opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4),
                               policy=get_policy("bf16"))
    eng = engine_lib.Engine(mesh, "builtin")        # donate=True default
    sim = CaloSimulator(CaloSpec(image_shape=calo3dgan.reduced()
                                 .image_shape), seed=0)
    batch = next(sim.batches(8))
    state = eng.init_state(task, jax.random.key(0))
    donated_leaf = state.g_params["out"]["w"]
    step = eng.compile_step(task, batch)
    new_state, _ = step(state, batch, jax.random.key(1))
    jax.block_until_ready(new_state.g_params)
    assert donated_leaf.is_deleted()      # buffer reused: aliasing held
    assert new_state.loss_scale is not None


# ---------------------------------------------------------------------------
# f32 metric accumulation (cast at add, not at drain)
# ---------------------------------------------------------------------------


def test_metric_accumulator_sums_bf16_in_f32():
    """A 256-step window of ~1.0-ish bf16 losses: a bf16 running sum
    saturates (1 ULP at 256 is 2.0), an f32 sum does not — the
    accumulator must cast at add time."""
    acc = metrics_lib.MetricAccumulator()
    val = jnp.asarray(1.015625, jnp.bfloat16)   # exactly representable
    for _ in range(256):
        acc.update({"loss": val})
    assert acc.sums["loss"].dtype == jnp.float32
    mean = acc.means()["loss"]
    assert mean == pytest.approx(float(val), rel=1e-5)
    # the bf16 running sum drifts measurably — the bug this guards
    drift = jnp.zeros((), jnp.bfloat16)
    for _ in range(256):
        drift = drift + val
    assert abs(float(drift) / 256 - float(val)) > 1e-3
