"""SSD-scan Pallas kernel family: forward AND backward parity vs the
sequential-scan reference (interpret mode on CPU), the
no-stacked-residuals guarantee in the lowered HLO of the BACKWARD (no
ref-oracle ``jax.vjp`` detour), a grad-check through a full
use_pallas_ssm zamba training step, and the shared autotune registry
routes.

This is the SSM half of the kernel tier-1 suite — CI runs it fail-fast
alongside test_kernel_conv3d.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as autotune_lib
from repro.kernels.ssm_scan import tune as tune_lib
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

RNG = np.random.default_rng(13)


def _scan_args(Bt, S, H, P, N, dtype=jnp.float32):
    x = jnp.asarray(RNG.normal(0, 1, (Bt, S, H, P)), dtype)
    B = jnp.asarray(RNG.normal(0, 1, (Bt, S, N)), dtype)
    C = jnp.asarray(RNG.normal(0, 1, (Bt, S, N)), dtype)
    dt = jnp.asarray(np.log1p(np.exp(RNG.normal(0, 1, (Bt, S, H)))), dtype)
    A = -jnp.exp(jnp.asarray(RNG.normal(0, 1, (H,)), jnp.float32))
    return x, B, C, dt, A


SSM_CASES = [
    # Bt, S, H, P, N, chunk
    (1, 64, 2, 8, 4, 32),        # chunk-multiple
    (2, 128, 4, 16, 8, 64),      # batch, taller state
    (1, 100, 2, 8, 4, 32),       # S not divisible by chunk
    (1, 37, 3, 8, 4, 16),        # odd S, odd H
    (1, 64, 2, 8, 4, 128),       # chunk > S (clamped)
]


# ---------------------------------------------------------------------------
# forward + backward parity vs the sequential reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Bt,S,H,P,N,chunk", SSM_CASES)
def test_ssm_fwd_bwd_parity(Bt, S, H, P, N, chunk):
    args = _scan_args(Bt, S, H, P, N)
    out = ssm_scan(*args, chunk)
    ref = ssm_scan_ref(*args)[0]
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)

    # cotangent-level parity: dx/dB/dC/ddt/dA against jax.vjp of the ref
    _, vjp_ref = jax.vjp(lambda *a: ssm_scan_ref(*a)[0], *args)
    _, vjp_ker = jax.vjp(lambda *a: ssm_scan(*a, chunk), *args)
    g = jnp.asarray(RNG.normal(0, 1, out.shape), jnp.float32)
    for i, (a, b) in enumerate(zip(vjp_ker(g), vjp_ref(g))):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4,
            err_msg=f"grad {'x B C dt A'.split()[i]}")


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_ssm_bwd_chunk_sizes_are_numerics_free(chunk):
    """The autotuner's chunk space must not change the math: every
    candidate chunk reproduces the reference gradients, including chunks
    that do not divide the sequence."""
    args = _scan_args(1, 96, 2, 8, 4)
    _, vjp_ref = jax.vjp(lambda *a: ssm_scan_ref(*a)[0], *args)
    out, vjp_ker = jax.vjp(lambda *a: ssm_scan(*a, chunk), *args)
    g = jnp.asarray(RNG.normal(0, 1, out.shape), jnp.float32)
    for a, b in zip(vjp_ker(g), vjp_ref(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_ssm_bf16_fwd_and_bwd():
    """bf16 operands flow through fwd AND the Pallas backward (all scan
    math is f32 in VMEM; only the operands are bf16)."""
    x32, B32, C32, dt32, A = _scan_args(1, 64, 2, 8, 4)
    xb, Bb, Cb, dtb = (t.astype(jnp.bfloat16)
                       for t in (x32, B32, C32, dt32))
    out = ssm_scan(xb, Bb, Cb, dtb, A, 32)
    ref = ssm_scan_ref(x32, B32, C32, dt32, A)[0]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=2e-2, rtol=2e-2)
    f = lambda *a: jnp.sum(ssm_scan(*a, 32).astype(jnp.float32) ** 2)
    gx, gB, gC, gdt = jax.grad(f, argnums=(0, 1, 2, 3))(xb, Bb, Cb, dtb, A)
    assert gx.dtype == jnp.bfloat16 and gdt.dtype == jnp.bfloat16
    r = jax.grad(lambda *a: jnp.sum(ssm_scan_ref(*a)[0] ** 2),
                 argnums=(0, 1, 2, 3))(x32, B32, C32, dt32, A)
    np.testing.assert_allclose(np.asarray(gx, np.float32), np.asarray(r[0]),
                               rtol=0.15, atol=0.15)


# ---------------------------------------------------------------------------
# no ref-oracle fallback: the backward must lower to the reverse-chunk
# Pallas kernel — the reference's per-timestep stacked scan residuals
# (S leading axis) must not exist in the HLO
# ---------------------------------------------------------------------------


def test_ssm_bwd_hlo_has_no_stacked_scan_residuals():
    Bt, S, H, P, N = 1, 64, 2, 8, 4
    args = _scan_args(Bt, S, H, P, N)
    tell = f"tensor<{S}x{Bt}x{H}x{P}x{N}xf32>"

    def loss(op):
        return lambda *a: jnp.sum(op(*a) ** 2)

    # the tell-tale must be a VALID detector: present in the ref grad HLO
    ref_hlo = jax.jit(jax.grad(loss(lambda *a: ssm_scan_ref(*a)[0]),
                               (0, 1, 2, 3, 4))).lower(*args).as_text()
    assert tell in ref_hlo, "tell-tale string no longer matches the ref"

    ker_hlo = jax.jit(jax.grad(loss(lambda *a: ssm_scan(*a, 32)),
                               (0, 1, 2, 3, 4))).lower(*args).as_text()
    assert tell not in ker_hlo, \
        "ssm_scan backward stacked per-timestep residuals " \
        "(ref-oracle jax.vjp fallback?)"


# ---------------------------------------------------------------------------
# grad-check through a full use_pallas_ssm zamba training loss
# ---------------------------------------------------------------------------


def test_zamba_loss_grads_match_jax_path():
    """d(loss)/d(params) through every Mamba2 layer of the reduced zamba
    — Pallas SSD fwd and bwd kernels selected via cfg.use_pallas_ssm —
    agrees with the chunked lax.scan route."""
    from repro.configs import base as config_base
    from repro.models import zamba
    from repro.substrate.precision import get_policy

    policy = get_policy("f32")
    cfg = config_base.reduced_config("zamba2-1.2b")
    cfg_p = dataclasses.replace(cfg, use_pallas_ssm=True)
    params = zamba.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
    batch = {"tokens": tokens}

    def loss(pp, c):
        return zamba.loss_fn(pp, batch, c, policy=policy)[0]

    l_ref, g_ref = jax.value_and_grad(loss)(params, cfg)
    l_pal, g_pal = jax.value_and_grad(loss)(params, cfg_p)
    np.testing.assert_allclose(float(l_pal), float(l_ref), atol=1e-4)
    for a, b in zip(jax.tree.leaves(g_pal), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)


def test_apply_mamba2_pallas_route_matches_scan():
    """substrate.ssm.apply_mamba2(use_pallas=True) == the lax.scan form,
    in value and in dx (the stateless training path only — stateful
    prefill keeps the scan)."""
    from repro.configs import base as config_base
    from repro.substrate import ssm as ssm_lib

    cfg = config_base.reduced_config("zamba2-1.2b")
    p = ssm_lib.init_mamba2(jax.random.key(0), cfg.d_model, cfg.ssm)
    x = jnp.asarray(RNG.normal(0, 1, (2, 64, cfg.d_model)), jnp.float32)
    y0 = ssm_lib.apply_mamba2(p, x, cfg.d_model, cfg.ssm)
    y1 = ssm_lib.apply_mamba2(p, x, cfg.d_model, cfg.ssm, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-4, rtol=2e-4)
    g0 = jax.grad(lambda xx: jnp.sum(jnp.sin(
        ssm_lib.apply_mamba2(p, xx, cfg.d_model, cfg.ssm))))(x)
    g1 = jax.grad(lambda xx: jnp.sum(jnp.sin(ssm_lib.apply_mamba2(
        p, xx, cfg.d_model, cfg.ssm, use_pallas=True))))(x)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1),
                               atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# shared autotune registry routes
# ---------------------------------------------------------------------------


def test_ssm_schedule_registry_default_and_override():
    sig = tune_lib.signature(8192, 16, 64, 64)
    try:
        assert autotune_lib.get_schedule(sig) == tune_lib.ScanChunks()
        autotune_lib.register_schedule(sig, tune_lib.ScanChunks(chunk=256))
        assert autotune_lib.get_schedule(sig).chunk == 256
        # dtype-qualified lookup falls back to the registered base
        sigd = tune_lib.signature(8192, 16, 64, 64, jnp.bfloat16)
        assert autotune_lib.get_schedule(sigd).chunk == 256
    finally:
        autotune_lib.clear_registry()


def test_ssm_candidates_clamp_dedup():
    sig = tune_lib.signature(48, 4, 16, 16)
    cands = tune_lib.candidate_chunks(sig)
    assert cands, "candidate space must be non-empty"
    effs = [min(c.chunk, 48) for c in cands]
    assert len(effs) == len(set(effs)), "aliased effective schedules"


def test_ssm_registered_chunk_drives_the_wrapper():
    """ops.ssm_scan must pick the registered chunk up by signature when
    called with chunk=None — and the result must be chunk-independent."""
    args = _scan_args(1, 80, 2, 8, 4)
    base = ssm_scan(*args, 80)
    sig = tune_lib.signature(80, 2, 8, 4, args[0].dtype)
    try:
        autotune_lib.register_schedule(sig, tune_lib.ScanChunks(chunk=16))
        out = ssm_scan(*args)          # chunk=None -> registry winner
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=1e-4, rtol=1e-4)
    finally:
        autotune_lib.clear_registry()
