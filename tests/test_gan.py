"""The paper's core: 3DGAN adversarial training (Algorithm 1).

Integration tests: naive and fused loops agree where they share RNG-free
math, a short fused training run improves the discriminator/physics
metrics, and the physics validation utilities behave."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import calo3dgan
from repro.core import adversarial, gan, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.optim import optimizers as opt_lib

CFG = calo3dgan.reduced()


@pytest.fixture(scope="module")
def sim():
    return CaloSimulator(CaloSpec(image_shape=CFG.image_shape), seed=11)


@pytest.fixture(scope="module")
def batch(sim):
    b = next(sim.batches(16))
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.fixture(scope="module")
def opts():
    return opt_lib.rmsprop(1e-4), opt_lib.rmsprop(1e-4)


def test_generator_output_shape_and_nonnegative():
    p = gan.init_generator(jax.random.key(0), CFG)
    noise = jax.random.normal(jax.random.key(1), (4, CFG.latent_dim))
    e_p = jnp.array([50.0, 100.0, 200.0, 400.0])
    theta = jnp.full((4,), jnp.pi / 2)
    img = gan.generate(p, noise, e_p, theta, CFG)
    X, Y, Z = CFG.image_shape
    assert img.shape == (4, X, Y, Z, 1)
    assert (np.asarray(img) >= 0).all()          # softplus energies


def test_generator_energy_conditioning():
    """Higher E_p must produce more total deposited energy (built-in
    response scaling — the physics prior the GAN starts from)."""
    p = gan.init_generator(jax.random.key(0), CFG)
    noise = jnp.zeros((2, CFG.latent_dim))
    e_p = jnp.array([50.0, 400.0])
    theta = jnp.full((2,), jnp.pi / 2)
    img = gan.generate(p, noise, e_p, theta, CFG)
    tot = np.asarray(img.sum(axis=(1, 2, 3, 4)))
    assert tot[1] > tot[0]


def test_discriminator_heads(batch):
    p = gan.init_discriminator(jax.random.key(0), CFG)
    v, e, t = gan.discriminate(p, batch["image"], CFG)
    assert v.shape == e.shape == t.shape == (16,)
    assert (np.asarray(e) >= 0).all()            # softplus energy head


def test_naive_and_fused_agree_on_d_real_loss(batch, opts):
    """The D-on-real update has no RNG: the naive (train_on_batch) and the
    fused (custom loop) implementations must produce the same loss."""
    g_opt, d_opt = opts
    state = adversarial.init_state(jax.random.key(0), CFG, g_opt, d_opt)
    naive = adversarial.NaiveStep(CFG, g_opt, d_opt, seed=1)
    fused = jax.jit(adversarial.make_fused_step(CFG, g_opt, d_opt))
    _, m_naive = naive(state, {k: np.asarray(v) for k, v in batch.items()})
    _, m_fused = fused(state, batch, jax.random.key(2))
    assert m_naive["d_loss_real"] == pytest.approx(
        float(m_fused["d_loss_real"]), rel=1e-4)


def test_fused_step_trains(sim, opts):
    """25 fused steps: losses stay finite, D accuracy on real data improves
    over the first steps, generator output remains non-degenerate."""
    g_opt, d_opt = opts
    state = adversarial.init_state(jax.random.key(0), CFG, g_opt, d_opt)
    fused = jax.jit(adversarial.make_fused_step(CFG, g_opt, d_opt),
                    donate_argnums=(0,))
    rng = jax.random.key(3)
    accs, g_losses = [], []
    it = sim.batches(16)
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        rng, k = jax.random.split(rng)
        state, m = fused(state, b, k)
        accs.append(float(m["d_acc_real"]))
        g_losses.append(float(m["g_loss"]))
        assert np.isfinite(g_losses[-1])
    assert np.mean(accs[-5:]) > np.mean(accs[:5]) - 0.05
    noise = jax.random.normal(jax.random.key(9), (8, CFG.latent_dim))
    img = gan.generate(state.g_params, noise,
                       jnp.full((8,), 200.0), jnp.full((8,), jnp.pi / 2), CFG)
    assert np.isfinite(np.asarray(img)).all()
    assert float(img.max()) > 0


def test_gen_steps_per_disc_is_two():
    """Algorithm 1 trains G twice per D step."""
    assert CFG.gen_steps_per_disc == 2


# ---------------------------------------------------------------------------
# physics validation (Fig. 3/7 machinery)
# ---------------------------------------------------------------------------


def test_calo_simulator_profiles(sim):
    img, e_p, theta, ecal = sim.generate(128)
    # response ~ sampling fraction
    resp = ecal / e_p
    assert 0.01 < resp.mean() < 0.05
    # longitudinal profile has a single interior maximum (shower max)
    prof = validation.longitudinal_profile(img[..., None])
    peak = prof.argmax()
    assert 0 < peak < len(prof) - 1
    # transverse profile peaks near the centre
    tx = validation.transverse_profile(img[..., None], "x")
    assert abs(int(tx.argmax()) - CFG.image_shape[0] // 2) <= 2


def test_profile_divergence_sane():
    p = np.array([0.2, 0.5, 0.3])
    assert validation.profile_divergence(p, p) == pytest.approx(0.0, abs=1e-9)
    q = np.array([0.5, 0.2, 0.3])
    assert validation.profile_divergence(p, q) > 0.01


def test_validation_report_mc_self_consistency():
    """MC vs MC (different seeds) is the noise floor: divergences tiny.
    Fresh, fixed-seed simulators — independent of test execution order."""
    spec = CaloSpec(image_shape=CFG.image_shape)
    a, e_a, _, _ = CaloSimulator(spec, seed=101).generate(512)
    b, e_b, _, _ = CaloSimulator(spec, seed=202).generate(512)
    rep = validation.validation_report(a[..., None], b[..., None], e_a, e_b)
    assert rep["longitudinal_kl"] < 2e-3
    assert rep["transverse_x_kl"] < 2e-3
    assert rep["response_rel_err"] < 0.05


def test_theta_conditioning_tilts_shower(sim):
    """Off-perpendicular incidence shifts the shower centroid along x with
    depth — the angle physics the ACGAN aux head must learn."""
    spec = CaloSpec(image_shape=CFG.image_shape)
    s = CaloSimulator(spec, seed=5)
    n = 64
    e_p = np.full(n, 200.0, np.float32)
    img_tilt = []
    for theta in (np.deg2rad(70.0), np.deg2rad(110.0)):
        sim2 = CaloSimulator(spec, seed=5)
        img, *_ = sim2.generate(n)
        img_tilt.append(img)
    # centroid_x at last depth layer differs between 70 and 110 degrees
    def centroid_last_z(img):
        last = img[..., -1]
        xs = np.arange(img.shape[1])
        w = last.sum(axis=2)
        return (w * xs[None]).sum() / max(w.sum(), 1e-9)
    # same seed -> same E_p/theta draws... so instead check correlation
    # between theta and centroid within one sample set
    img, e_p, theta, _ = s.generate(256)
    cx = [(img[i].sum(axis=(1,))[:, -1] * np.arange(img.shape[1])).sum()
          / max(img[i].sum(axis=(1,))[:, -1].sum(), 1e-9)
          for i in range(256)]
    corr = np.corrcoef(theta, cx)[0, 1]
    assert abs(corr) > 0.5


def test_gan_generator_pallas_conv_path():
    """The Pallas implicit-GEMM conv path produces the same generator
    output as the lax.conv path (interpret mode, tiny config)."""
    import dataclasses
    cfg = dataclasses.replace(calo3dgan.bench(), image_shape=(8, 8, 8),
                              gen_channels=(8, 4), disc_channels=(4, 8),
                              latent_dim=16)
    p = gan.init_generator(jax.random.key(0), cfg)
    noise = jax.random.normal(jax.random.key(1), (2, cfg.latent_dim))
    e_p = jnp.array([100.0, 300.0])
    th = jnp.full((2,), jnp.pi / 2)
    ref = gan.generate(p, noise, e_p, th, cfg)
    with gan.use_pallas_conv():
        out = gan.generate(p, noise, e_p, th, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)

    dp = gan.init_discriminator(jax.random.key(2), cfg)
    v_ref, e_ref, t_ref = gan.discriminate(dp, ref, cfg)
    with gan.use_pallas_conv():
        v, e, t = gan.discriminate(dp, ref, cfg)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), atol=1e-3)
