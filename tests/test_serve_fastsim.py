"""Fast-simulation serving engine: bucket packing/masking must hand back
exactly the requested events, compilation must be one program per bucket,
generation must be bit-identical across packings and across a checkpoint
round-trip, and the rolling physics gate must count only real (unmasked)
events.  Plus the ServeEngine cache-dtype-follows-policy fix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as config_base, calo3dgan
from repro.core import adversarial, gan, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.launch.mesh import make_dev_mesh
from repro.optim import optimizers as opt_lib
from repro.serve.simulate import PhysicsGate, SimRequest, SimulateEngine
from repro.train import checkpoint as ckpt_lib

CFG = calo3dgan.bench()


@pytest.fixture(scope="module")
def g_params():
    return gan.init_generator(jax.random.key(0), CFG)


@pytest.fixture(scope="module")
def mc_reference():
    mc = next(CaloSimulator(CaloSpec(image_shape=CFG.image_shape),
                            seed=0).batches(64))
    return validation.reference_profiles(mc["image"], mc["e_p"])


def _engine(g_params, buckets=(4, 16), gate=None):
    return SimulateEngine(CFG, g_params, buckets=buckets,
                          mesh=make_dev_mesh(), gate=gate)


# ---------------------------------------------------------------------------
# bucket packing / masking
# ---------------------------------------------------------------------------


def test_odd_request_sizes_get_exactly_n_events(g_params):
    """Non-bucket-aligned sizes (3, 5, 17, 1) span padding, bucket sharing
    and multi-step requests — each must get back exactly n_events."""
    eng = _engine(g_params)
    sizes = [3, 5, 17, 1]
    for rid, n in enumerate(sizes):
        eng.submit(SimRequest(rid=rid, primary_energy=100.0 + rid,
                              n_events=n, seed=rid))
    done = eng.run()
    assert [r.rid for r in sorted(done, key=lambda r: r.rid)] == [0, 1, 2, 3]
    for r, n in zip(sorted(done, key=lambda r: r.rid), sizes):
        assert r.done and r.images.shape == (n, *CFG.image_shape, 1)
        assert np.all(np.isfinite(r.images))
        assert np.all(r.images >= 0)          # softplus output
    assert eng.stats["events_generated"] == sum(sizes)
    # one device->host drain per request, never per step
    assert eng.stats["device_transfers"] == len(sizes)


def test_one_compiled_program_per_bucket(g_params):
    """Many request shapes, ONE compile per bucket actually used."""
    eng = _engine(g_params, buckets=(4, 16))
    for rid, n in enumerate([1, 2, 3, 4]):     # all fit the 4-bucket
        eng.submit(SimRequest(rid=rid, primary_energy=50.0, n_events=n,
                              seed=rid))
        eng.run()
    assert eng.compile_count == 1
    eng.submit(SimRequest(rid=9, primary_energy=50.0, n_events=30, seed=9))
    eng.run()
    assert eng.compile_count == 2              # the 16-bucket, once
    for rid, n in enumerate([7, 19, 33], start=10):
        eng.submit(SimRequest(rid=rid, primary_energy=50.0, n_events=n,
                              seed=rid))
    eng.run()
    assert eng.compile_count == 2              # nothing new to compile
    assert eng.stats["bucket_steps"][4] > 0
    assert eng.stats["bucket_steps"][16] > 0


def test_warmup_precompiles_all_buckets(g_params):
    eng = _engine(g_params, buckets=(4, 16))
    eng.warmup()
    assert eng.compile_count == 2
    eng.warmup()                               # idempotent
    assert eng.compile_count == 2
    eng.submit(SimRequest(rid=0, primary_energy=80.0, n_events=5, seed=0))
    eng.run()
    assert eng.compile_count == 2


def test_bucket_validation_errors(g_params):
    with pytest.raises(ValueError):
        SimulateEngine(CFG, g_params, buckets=())
    with pytest.raises(ValueError):
        SimulateEngine(CFG, g_params, buckets=(0, 8))
    eng = _engine(g_params)
    with pytest.raises(ValueError):
        eng.submit(SimRequest(rid=0, primary_energy=10.0, n_events=0))


# ---------------------------------------------------------------------------
# determinism: packing invariance + checkpoint round-trip
# ---------------------------------------------------------------------------


def test_generation_bit_identical_across_packings(g_params):
    """Per-event RNG keys make a request's showers independent of which
    other requests shared its bucket batch."""
    alone = _engine(g_params).generate_events(200.0, 5, seed=7)
    eng = _engine(g_params)
    eng.submit(SimRequest(rid=0, primary_energy=200.0, n_events=5, seed=7))
    eng.submit(SimRequest(rid=1, primary_energy=40.0, n_events=9, seed=8))
    done = {r.rid: r for r in eng.run()}
    assert np.array_equal(alone, done[0].images)


def test_checkpoint_roundtrip_bit_identical_generation(g_params, tmp_path):
    """Save trained generator params, restore them through the serving
    loader, and require bit-identical showers vs the in-process params."""
    g_opt, d_opt = opt_lib.rmsprop(2e-4), opt_lib.rmsprop(2e-4)
    state = adversarial.init_state(jax.random.key(1), CFG, g_opt, d_opt)
    fused = jax.jit(adversarial.make_fused_step(CFG, g_opt, d_opt))
    sim = CaloSimulator(CaloSpec(image_shape=CFG.image_shape), seed=1)
    it = sim.batches(8)
    for i in range(2):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, _ = fused(state, b, jax.random.key(i + 2))

    ckpt = str(tmp_path / "gan")
    ckpt_lib.save(ckpt, state.g_params, step=2, extra={"kind": "gan_generator"})
    restored = ckpt_lib.restore_gan_generator(ckpt, CFG)

    in_proc = _engine(state.g_params).generate_events(250.0, 11, seed=3)
    from_ckpt = _engine(restored).generate_events(250.0, 11, seed=3)
    assert in_proc.shape == (11, *CFG.image_shape, 1)
    assert np.array_equal(in_proc, from_ckpt)


# ---------------------------------------------------------------------------
# physics gate
# ---------------------------------------------------------------------------


def test_gate_counts_only_real_events(g_params, mc_reference):
    """Padded bucket rows must not reach the gate: window counts add up to
    exactly the requested events despite padding on every step."""
    gate = PhysicsGate(mc_reference, window=8)
    eng = _engine(g_params, gate=gate)
    sizes = [3, 5, 17, 1]                      # 26 events, heavy padding
    for rid, n in enumerate(sizes):
        eng.submit(SimRequest(rid=rid, primary_energy=120.0, n_events=n,
                              seed=rid))
    eng.run()
    gate.flush()
    assert gate.reports                         # windows drained during run
    assert sum(rep["count"] for rep in gate.reports) == sum(sizes)
    for rep in gate.reports:
        for k in ("longitudinal_kl", "transverse_x_kl", "transverse_y_kl",
                  "response_rel_err"):
            assert np.isfinite(rep[k]) and rep[k] >= 0
    assert gate.flush() is None                 # nothing pending


def test_gate_profiles_match_host_validation(g_params):
    """The gate's masked on-device sums must reproduce the host-side
    profile functions over the same (unpadded) events."""
    imgs = jnp.asarray(np.random.default_rng(0).gamma(
        2.0, 1.0, size=(6, *CFG.image_shape, 1)).astype(np.float32))
    e_p = jnp.asarray(np.linspace(50, 400, 6, dtype=np.float32))
    mask = jnp.asarray(np.array([1, 1, 1, 1, 0, 0], np.float32))
    sums = jax.device_get(validation.profile_sums(imgs, e_p, mask))
    sub = np.asarray(imgs)[:4]
    for name, fn in (("longitudinal", validation.longitudinal_profile),
                     ("transverse_x",
                      lambda im: validation.transverse_profile(im, "x")),
                     ("transverse_y",
                      lambda im: validation.transverse_profile(im, "y"))):
        prof = sums[name] / sums[name].sum()
        np.testing.assert_allclose(prof, fn(sub), rtol=1e-5)
    assert sums["count"] == 4
    np.testing.assert_allclose(
        sums["e_cal"] / sums["e_p"],
        np.sum(sub) / np.sum(np.asarray(e_p)[:4]), rtol=1e-5)
    # response estimator is the UNWEIGHTED per-event mean, matching
    # energy_response(...).mean() in the training-time report
    np.testing.assert_allclose(
        sums["response"] / sums["count"],
        validation.energy_response(sub, np.asarray(e_p)[:4]).mean(),
        rtol=1e-5)


def test_gate_drift_detection(g_params, mc_reference):
    gate = PhysicsGate(mc_reference, window=4)
    eng = _engine(g_params, gate=gate)
    eng.generate_events(300.0, 8, seed=0)
    gate.flush()
    assert gate.drifted(max_kl=0.0)            # untrained G always "drifts"
    assert not gate.drifted(max_kl=1e9)


# ---------------------------------------------------------------------------
# ServeEngine cache dtype follows the precision policy (regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy_name,expect", [("f32", jnp.float32),
                                                ("bf16", jnp.bfloat16)])
def test_serve_engine_cache_dtype_follows_policy(policy_name, expect):
    from repro.models import api
    from repro.serve.engine import ServeEngine

    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=16,
                      policy_name=policy_name)
    assert eng.cache_dtype == expect
    floats = [l for l in jax.tree.leaves(eng.cache)
              if jnp.issubdtype(l.dtype, jnp.floating)]
    assert floats and all(l.dtype == expect for l in floats)
    eng._zero_slot(0)                          # refill keeps the dtype
    floats = [l for l in jax.tree.leaves(eng.cache)
              if jnp.issubdtype(l.dtype, jnp.floating)]
    assert all(l.dtype == expect for l in floats)


# ---------------------------------------------------------------------------
# restore honors the checkpoint's recorded precision policy
# ---------------------------------------------------------------------------


def test_from_checkpoint_restores_recorded_precision(g_params, tmp_path):
    path = str(tmp_path / "ckpt_bf16")
    ckpt_lib.save(path, g_params, step=3,
                  extra={"kind": "gan_generator", "precision": "bf16"})
    assert ckpt_lib.manifest_precision(path) == "bf16"
    eng = SimulateEngine.from_checkpoint(path, CFG, buckets=(4,))
    assert eng.policy.compute_dtype == jnp.bfloat16
    # explicit override beats the manifest
    eng32 = SimulateEngine.from_checkpoint(path, CFG, buckets=(4,),
                                           policy_name="f32")
    assert eng32.policy.compute_dtype == jnp.float32


def test_from_checkpoint_old_manifest_defaults_to_f32(g_params, tmp_path):
    """Manifests written before the precision field existed (extra lacks
    the key) must restore as the f32 they were trained in."""
    path = str(tmp_path / "ckpt_old")
    ckpt_lib.save(path, g_params, step=3, extra={"kind": "gan_generator"})
    assert ckpt_lib.manifest_precision(path) == "f32"
    eng = SimulateEngine.from_checkpoint(path, CFG, buckets=(4,))
    assert eng.policy.compute_dtype == jnp.float32
    img = eng.generate_events(100.0, 3, seed=1)
    assert img.shape[0] == 3 and np.isfinite(img).all()
