"""Training substrate: optimizers vs analytic math, schedules, checkpoint
roundtrip, data pipeline coverage, metric log."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ShardStore, prefetch
from repro.data.tokens import MarkovTokens
from repro.optim import optimizers as opt_lib
from repro.train import checkpoint as ckpt_lib


# ---------------------------------------------------------------------------
# optimizers vs analytic updates
# ---------------------------------------------------------------------------


def test_adam_first_step_is_signed_lr():
    """After one step from zero state, Adam's update is -lr * sign(g)
    (bias correction makes m_hat/sqrt(v_hat) = g/|g|)."""
    opt = opt_lib.adam(1e-2, eps=0.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.5, -0.25, 1.0])}
    upd, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               -1e-2 * np.sign(np.asarray(g["w"])), rtol=1e-4)


def test_adam_matches_reference_sequence():
    """5 steps of our Adam == a hand-rolled reference implementation."""
    lr, b1, b2, eps = 3e-3, 0.9, 0.999, 1e-8
    opt = opt_lib.adam(lr, b1=b1, b2=b2, eps=eps)
    rng = np.random.default_rng(3)
    p = jnp.asarray(rng.normal(size=(7,)), jnp.float32)
    state = opt.init({"w": p})
    m = np.zeros(7); v = np.zeros(7); pref = np.asarray(p, np.float64)
    pj = {"w": p}
    for t in range(1, 6):
        g = rng.normal(size=(7,)).astype(np.float32)
        upd, state = opt.update({"w": jnp.asarray(g)}, state, pj)
        pj = opt_lib.apply_updates(pj, upd)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        pref = pref - lr * mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(np.asarray(pj["w"]), pref, rtol=1e-5, atol=1e-6)


def test_rmsprop_matches_keras_math():
    lr, decay, eps = 1e-3, 0.9, 1e-8
    opt = opt_lib.rmsprop(lr, decay=decay, eps=eps)
    g = np.array([1.0, -2.0], np.float32)
    p = {"w": jnp.zeros(2)}
    state = opt.init(p)
    nu = np.zeros(2); pref = np.zeros(2)
    for _ in range(3):
        upd, state = opt.update({"w": jnp.asarray(g)}, state, p)
        p = opt_lib.apply_updates(p, upd)
        nu = decay * nu + (1 - decay) * g * g
        pref = pref - lr * g / (np.sqrt(nu) + eps)
    np.testing.assert_allclose(np.asarray(p["w"]), pref, rtol=1e-5)


def test_weight_decay_decoupled():
    """AdamW decays weights even with zero gradient moments history."""
    opt = opt_lib.adamw(1e-2, weight_decay=0.1)
    p = {"w": jnp.ones(3)}
    upd, _ = opt.update({"w": jnp.zeros(3)}, opt.init(p), p)
    np.testing.assert_allclose(np.asarray(upd["w"]), -1e-2 * 0.1 * 1.0,
                               rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    total = opt_lib.global_norm(clipped)
    assert abs(float(total) - 1.0) < 1e-5


def test_warmup_cosine_schedule():
    sched = opt_lib.warmup_cosine(1.0, warmup=10, total=110, floor=0.1)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 0.11
    assert float(sched(jnp.int32(110))) == pytest.approx(0.1, rel=1e-3)
    # monotone decreasing after warmup
    xs = [float(sched(jnp.int32(t))) for t in range(12, 110, 10)]
    assert all(a >= b for a, b in zip(xs, xs[1:]))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.zeros(3)},
            "blocks": [jnp.ones(2), jnp.full(2, 7.0)]}
    ckpt_lib.save(str(tmp_path / "ck"), tree, step=42)
    template = jax.tree.map(jnp.zeros_like, tree)
    back = ckpt_lib.restore(str(tmp_path / "ck"), template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt_lib.latest_step(str(tmp_path / "ck")) == 42


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt_lib.save(str(tmp_path / "ck"), {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt_lib.restore(str(tmp_path / "ck"), {"w": jnp.zeros((3, 2))})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_shard_store_epoch_covers_every_record(tmp_path):
    store = ShardStore(str(tmp_path / "shards"))
    n = 0
    for i in range(3):
        ids = np.arange(n, n + 10, dtype=np.int64)
        store.write(f"s{i}", {"id": ids})
        n += 10
    seen = []
    for batch in store.iter_epoch(batch=5, shuffle_seed=0):
        seen.extend(batch["id"].tolist())
    assert sorted(seen) == list(range(30))


def test_prefetch_preserves_order_and_content(tmp_path):
    batches = [{"x": np.full((2,), i, np.float32)} for i in range(17)]
    out = list(prefetch(iter(batches), size=3))
    assert len(out) == 17
    for i, b in enumerate(out):
        np.testing.assert_array_equal(np.asarray(b["x"]), i)


def test_markov_tokens_learnable_structure():
    """The synthetic LM data must be lower-entropy than uniform (so short
    training runs can show loss decreasing)."""
    src = MarkovTokens(vocab=64, seed=0, branching=4)
    seq = src.sample(8, 256)
    assert seq.shape == (8, 256)
    assert seq.min() >= 0 and seq.max() < 64
    # successor entropy: given x_t, x_{t+1} concentrates on few tokens
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in seq:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    top1 = np.mean([c.most_common(1)[0][1] / sum(c.values())
                    for c in succ.values() if sum(c.values()) >= 10])
    assert top1 > 0.3        # uniform would be ~1/64
