"""Fused conv3d Pallas kernel family: forward AND backward parity vs the
lax.conv oracles (interpret mode on CPU), the no-materialized-im2col
guarantee in the lowered HLO, the fused bias+activation epilogue, a
grad-check through a full use_pallas_conv GAN step, and the tile registry.

This is the kernel half of the tier-1 suite — CI runs it fail-fast."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv3d import (ConvTiles, autotune, conv3d,
                                  conv3d_bias_act, conv3d_bias_act_ref,
                                  conv3d_ref, conv3d_transpose,
                                  conv3d_transpose_bias_act,
                                  conv3d_transpose_bias_act_ref,
                                  conv3d_transpose_ref, gemm, get_tiles,
                                  register_tiles, signature)
from repro.kernels.conv3d import tiles as tiles_lib

RNG = np.random.default_rng(7)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


CONV_CASES = [
    # N, D, H, W, Ci, Co, k, stride
    (1, 8, 8, 8, 4, 8, 3, 1),
    (2, 13, 13, 13, 8, 16, 3, 2),
    (1, 7, 9, 5, 3, 5, 3, 1),        # odd, ragged spatial; non-128 channels
    (1, 6, 6, 6, 3, 5, 3, 2),
    (1, 5, 5, 5, 1, 4, 3, 2),        # Ci=1 (the discriminator input layer)
]

TRANSPOSE_CASES = [
    (1, 4, 4, 4, 4, 8, 3, 2),
    (2, 7, 7, 4, 8, 4, 3, 2),
    (1, 5, 5, 5, 3, 5, 3, 1),        # stride 1, odd channels
    (1, 3, 5, 3, 2, 3, 3, 2),        # ragged spatial
]


# ---------------------------------------------------------------------------
# forward + backward parity vs the lax oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,D,H,W,Ci,Co,k,s", CONV_CASES)
def test_conv3d_fwd_bwd_parity(N, D, H, W, Ci, Co, k, s):
    x = _randn((N, D, H, W, Ci))
    w = _randn((k, k, k, Ci, Co), scale=0.1)
    out = conv3d(x, w, s)
    ref = conv3d_ref(x, w, s)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)

    # cotangent-level parity: dx and dw against jax.vjp of the oracle
    _, vjp_ref = jax.vjp(lambda x_, w_: conv3d_ref(x_, w_, s), x, w)
    _, vjp_ker = jax.vjp(lambda x_, w_: conv3d(x_, w_, s), x, w)
    g = _randn(out.shape)
    for a, b in zip(vjp_ker(g), vjp_ref(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("N,D,H,W,Ci,Co,k,s", TRANSPOSE_CASES)
def test_conv3d_transpose_fwd_bwd_parity(N, D, H, W, Ci, Co, k, s):
    x = _randn((N, D, H, W, Ci))
    w = _randn((k, k, k, Ci, Co), scale=0.1)
    out = conv3d_transpose(x, w, s)
    ref = conv3d_transpose_ref(x, w, s)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)

    _, vjp_ref = jax.vjp(lambda x_, w_: conv3d_transpose_ref(x_, w_, s), x, w)
    _, vjp_ker = jax.vjp(lambda x_, w_: conv3d_transpose(x_, w_, s), x, w)
    g = _randn(out.shape)
    for a, b in zip(vjp_ker(g), vjp_ref(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("activation", ["none", "leaky_relu", "softplus"])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv3d_fused_bias_act_epilogue(activation, stride):
    """conv + bias + activation as ONE kernel == the unfused composition,
    in value and in (dx, dw, db)."""
    x = _randn((2, 7, 7, 5, 3))
    w = _randn((3, 3, 3, 3, 6), scale=0.1)
    b = _randn((6,), scale=0.1)

    def fused(x_, w_, b_):
        return jnp.sum(conv3d_bias_act(x_, w_, b_, stride, activation) ** 2)

    def unfused(x_, w_, b_):
        return jnp.sum(
            conv3d_bias_act_ref(x_, w_, b_, stride, activation) ** 2)

    out = conv3d_bias_act(x, w, b, stride, activation)
    ref = conv3d_bias_act_ref(x, w, b, stride, activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    gk = jax.grad(fused, (0, 1, 2))(x, w, b)
    gr = jax.grad(unfused, (0, 1, 2))(x, w, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("activation", ["none", "leaky_relu", "softplus"])
def test_conv3d_transpose_fused_bias_act_epilogue(activation):
    x = _randn((1, 4, 4, 4, 4))
    w = _randn((3, 3, 3, 4, 6), scale=0.1)
    b = _randn((6,), scale=0.1)
    out = conv3d_transpose_bias_act(x, w, b, 2, activation)
    ref = conv3d_transpose_bias_act_ref(x, w, b, 2, activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    gk = jax.grad(lambda *a: jnp.sum(
        conv3d_transpose_bias_act(*a, 2, activation) ** 2), (0, 1, 2))(x, w, b)
    gr = jax.grad(lambda *a: jnp.sum(
        conv3d_transpose_bias_act_ref(*a, 2, activation) ** 2),
        (0, 1, 2))(x, w, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=5e-4, rtol=1e-4)


def test_conv3d_bf16_inputs():
    x = _randn((1, 6, 6, 6, 4), jnp.bfloat16)
    w = _randn((3, 3, 3, 4, 8), jnp.bfloat16, scale=0.1)
    out = conv3d(x, w, 2)
    ref = conv3d_ref(x, w, 2)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=5e-2)


# ---------------------------------------------------------------------------
# no materialized im2col: the (P, KD*KH*KW*Ci) patches matrix must not
# exist anywhere in the lowered HLO, forward or backward
# ---------------------------------------------------------------------------


def _assert_no_im2col(hlo: str, P: int, k3ci: int):
    for dt in ("f32", "bf16"):
        assert f"{dt}[{P},{k3ci}]" not in hlo.replace(" ", ""), \
            f"found materialized im2col patches buffer {dt}[{P},{k3ci}]"


def test_no_materialized_im2col_forward():
    N, D, H, W, Ci, Co, k, s = 1, 8, 8, 8, 4, 8, 3, 1
    x = _randn((N, D, H, W, Ci))
    w = _randn((k, k, k, Ci, Co), scale=0.1)
    hlo = jax.jit(lambda a, b: conv3d(a, b, s)).lower(x, w).as_text()
    _assert_no_im2col(hlo, N * D * H * W, k ** 3 * Ci)


def test_no_materialized_im2col_backward():
    N, D, H, W, Ci, Co, k, s = 1, 6, 6, 6, 4, 8, 3, 2
    x = _randn((N, D, H, W, Ci))
    w = _randn((k, k, k, Ci, Co), scale=0.1)

    def loss(x_, w_):
        return jnp.sum(conv3d(x_, w_, s) ** 2)

    hlo = jax.jit(jax.grad(loss, (0, 1))).lower(x, w).as_text()
    OD = -(-D // s)
    _assert_no_im2col(hlo, N * OD ** 3, k ** 3 * Ci)       # dw gather
    _assert_no_im2col(hlo, N * D * H * W, k ** 3 * Co)     # dx gather


# ---------------------------------------------------------------------------
# grad-check through a full use_pallas_conv GAN step (interpret mode)
# ---------------------------------------------------------------------------


def _tiny_gan_cfg(**kw):
    from repro.configs import calo3dgan
    return dataclasses.replace(
        calo3dgan.bench(), image_shape=(6, 6, 6), latent_dim=8,
        gen_channels=(6, 4), disc_channels=(4, 6), batch_size=2, **kw)


def test_gan_loss_grads_match_lax_path():
    """d(gen_loss)/d(params) through BOTH networks — every conv fwd and
    bwd kernel in the stack — agrees with the lax.conv route."""
    from repro.core import gan
    cfg = _tiny_gan_cfg()
    cfg_p = dataclasses.replace(cfg, use_pallas_conv=True)
    gp = gan.init_generator(jax.random.key(0), cfg)
    dp = gan.init_discriminator(jax.random.key(1), cfg)
    noise = _randn((2, cfg.latent_dim))
    labels = (jnp.array([100.0, 300.0]), jnp.full((2,), jnp.pi / 2),
              jnp.array([2.0, 6.0]))

    def loss(gp_, dp_, c):
        return gan.gen_loss(gp_, dp_, noise, labels, c)[0]

    (l_ref, g_ref) = jax.value_and_grad(loss, (0, 1))(gp, dp, cfg)
    (l_pal, g_pal) = jax.value_and_grad(loss, (0, 1))(gp, dp, cfg_p)
    np.testing.assert_allclose(float(l_pal), float(l_ref), atol=1e-4)
    flat_ref = jax.tree.leaves(g_ref)
    flat_pal = jax.tree.leaves(g_pal)
    assert len(flat_ref) == len(flat_pal)
    for a, b in zip(flat_pal, flat_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_gan_fused_step_matches_lax_path():
    """One full Algorithm-1 fused step (D real, D fake, G twice) with the
    Pallas conv route == the lax route: same metrics, same updated params."""
    from repro.core import adversarial
    from repro.data.calo import CaloSimulator, CaloSpec
    from repro.optim import optimizers as opt_lib

    cfg = _tiny_gan_cfg()
    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(sim.batches(2)).items()}
    outs = {}
    for name, c in (("lax", cfg),
                    ("pallas", dataclasses.replace(cfg,
                                                   use_pallas_conv=True))):
        g_opt, d_opt = opt_lib.rmsprop(1e-4), opt_lib.rmsprop(1e-4)
        state = adversarial.init_state(jax.random.key(0), c, g_opt, d_opt)
        step = adversarial.make_fused_step(c, g_opt, d_opt)
        new, metrics = jax.jit(step)(state, batch, jax.random.key(1))
        outs[name] = (new, metrics)
    for k in outs["lax"][1]:
        np.testing.assert_allclose(float(outs["pallas"][1][k]),
                                   float(outs["lax"][1][k]), atol=1e-3)
    for a, b in zip(jax.tree.leaves(outs["pallas"][0].g_params),
                    jax.tree.leaves(outs["lax"][0].g_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


# ---------------------------------------------------------------------------
# tile registry + autotune hook
# ---------------------------------------------------------------------------


def test_tile_registry_heuristic_and_override():
    sig = signature("conv", (51, 51, 25), 1, 16, 3, 2)
    try:
        t = get_tiles(sig)
        assert t.bn == 16                 # heuristic: shrink to padded Co
        big = signature("conv", (13, 13, 13), 128, 128, 3, 2)
        assert get_tiles(big).bn == 128   # MXU-native when the problem is
        register_tiles(sig, ConvTiles(bn=8))
        assert get_tiles(sig).bn == 8     # registry beats heuristic
    finally:
        tiles_lib.clear_registry()


def test_tile_autotune_registers_argmin():
    sig = signature("conv_t", (8, 8, 8), 8, 8, 3, 2)
    try:
        best = autotune(sig, measure=lambda t: abs(t.bn - 64),
                        candidates=[ConvTiles(bn=n) for n in (32, 64, 128)])
        assert best.bn == 64
        assert get_tiles(sig).bn == 64
    finally:
        tiles_lib.clear_registry()


def test_gemm_skips_noop_pads():
    """Tile-multiple GEMMs must lower without any pad op (the no-op
    jnp.pad + trailing slice used to cost two extra HBM copies)."""
    a = _randn((128, 128))
    b = _randn((128, 128))
    np.testing.assert_allclose(np.asarray(gemm(a, b)), np.asarray(a @ b),
                               atol=5e-4, rtol=1e-4)
    hlo = jax.jit(lambda x, y: gemm(x, y)).lower(a, b).as_text()
    assert "pad(" not in hlo


# ---------------------------------------------------------------------------
# autotune subsystem: persistent cache, corrupt-cache fallback, tile routes
# ---------------------------------------------------------------------------


def test_autotune_cache_roundtrip(tmp_path):
    """Winners persist to disk and warm-load into a fresh registry — the
    cross-process contract behind tools/autotune_conv3d.py's second run
    performing zero measurements."""
    cache = str(tmp_path / "autotune")
    sig = signature("conv", (9, 9, 9), 4, 8, 3, 2, jnp.bfloat16)
    try:
        register_tiles(sig, ConvTiles(bn=32, fuse_taps=True))
        tiles_lib.save_cache(cache_dir=cache)
        tiles_lib.clear_registry()
        assert sig not in tiles_lib._REGISTRY
        n = tiles_lib.load_cache(cache_dir=cache)
        assert n == 1
        got = get_tiles(sig)
        assert got.bn == 32 and got.fuse_taps is True
    finally:
        tiles_lib.clear_registry()


def test_autotune_signature_uses_cache_without_measuring(tmp_path):
    """Second autotune of the same signature must perform ZERO
    measurements (the warm-start the CLI asserts on)."""
    cache = str(tmp_path / "autotune")
    sig = signature("conv", (5, 5, 5), 2, 4, 3, 1, jnp.float32)
    try:
        best1, n1 = tiles_lib.autotune_signature(sig, steps=1,
                                                 cache_dir=cache)
        assert n1 > 0
        tiles_lib.clear_registry()
        best2, n2 = tiles_lib.autotune_signature(sig, steps=1,
                                                 cache_dir=cache)
        assert n2 == 0
        assert best2 == best1
    finally:
        tiles_lib.clear_registry()


def test_corrupt_cache_falls_back_to_default_tiles(tmp_path):
    """A truncated/garbage cache file must never break the kernels —
    get_tiles falls back to the shape heuristic."""
    cache = tmp_path / "autotune"
    cache.mkdir()
    kind = tiles_lib._device_kind()
    (cache / f"{kind}.json").write_text("{not valid json!!")
    try:
        assert tiles_lib.load_cache(cache_dir=str(cache)) == 0
        sig = signature("conv", (9, 9, 9), 1, 16, 3, 2)
        assert get_tiles(sig) == tiles_lib.default_tiles(sig)
        # and save_cache over the corrupt file recovers it (the registry
        # may also hold warm-loaded entries from the repo's committed
        # default cache — only OUR entry's round trip is asserted)
        register_tiles(sig, ConvTiles(bn=8))
        tiles_lib.save_cache(cache_dir=str(cache))
        tiles_lib.clear_registry()
        assert tiles_lib.load_cache(cache_dir=str(cache)) >= 1
        assert get_tiles(sig).bn == 8
    finally:
        tiles_lib.clear_registry()


def test_fuse_taps_and_dw_tiling_parity():
    """The autotuner's tile space must be numerics-free: fused-tap
    schedule + a bn that tiles Co (dw kernel included) reproduce the lax
    gradients exactly as the default schedule does."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1, (2, 7, 7, 5, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (3, 3, 3, 3, 12)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.1, (12,)), jnp.float32)
    loss = lambda op: (lambda *a: jnp.sum(op(*a, 2, "leaky_relu") ** 2))
    ref = jax.grad(loss(conv3d_bias_act_ref), argnums=(0, 1, 2))(x, w, b)
    try:
        for spec in [ConvTiles(bn=4, fuse_taps=False),
                     ConvTiles(bn=4, fuse_taps=True),
                     ConvTiles(bn=128, fuse_taps=True)]:
            tiles_lib.clear_registry()
            # route EVERY signature (fwd, dx, dw) through this tile spec
            orig = tiles_lib.get_tiles
            tiles_lib.get_tiles = lambda sig, s=spec: s
            try:
                got = jax.grad(loss(conv3d_bias_act),
                               argnums=(0, 1, 2))(x, w, b)
            finally:
                tiles_lib.get_tiles = orig
            for g, r in zip(got, ref):
                np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                           rtol=2e-4, atol=2e-4)
    finally:
        tiles_lib.clear_registry()


def test_bf16_operands_fwd_and_bwd_run_and_match_f32_loosely():
    """bf16 operands flow through fwd AND the Pallas backward kernels
    (f32 VMEM accumulation keeps the error at bf16 resolution)."""
    rng = np.random.default_rng(6)
    x32 = jnp.asarray(rng.normal(0, 1, (2, 9, 9, 7, 4)), jnp.float32)
    w32 = jnp.asarray(rng.normal(0, 0.1, (3, 3, 3, 4, 8)), jnp.float32)
    b32 = jnp.zeros((8,), jnp.float32)
    xb, wb, bb = (a.astype(jnp.bfloat16) for a in (x32, w32, b32))
    y16 = conv3d_bias_act(xb, wb, bb, 2)
    assert y16.dtype == jnp.bfloat16
    y32 = conv3d_bias_act(x32, w32, b32, 2)
    np.testing.assert_allclose(np.asarray(y16, np.float32),
                               np.asarray(y32), rtol=0.05, atol=0.05)
    f = lambda x_, w_, b_: jnp.sum(
        conv3d_bias_act(x_, w_, b_, 2).astype(jnp.float32) ** 2)
    gx, gw, gb = jax.grad(f, argnums=(0, 1, 2))(xb, wb, bb)
    assert gx.dtype == jnp.bfloat16 and gw.dtype == jnp.bfloat16
    rx, rw, rb = jax.grad(f, argnums=(0, 1, 2))(x32, w32, b32)
    np.testing.assert_allclose(np.asarray(gw, np.float32), np.asarray(rw),
                               rtol=0.1, atol=0.1)
