"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py
pure-jnp oracles (kernels run with interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv3d import (conv3d, conv3d_ref, conv3d_transpose,
                                  conv3d_transpose_ref, gemm)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssm_scan import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan as ssm_scan_fwd

RNG = np.random.default_rng(42)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, S, T, H, KH, D, causal, window
    (1, 128, 128, 4, 2, 32, True, 0),
    (2, 256, 256, 8, 1, 64, True, 0),       # MQA
    (1, 100, 100, 4, 4, 32, True, 0),       # non-multiple of block
    (1, 64, 256, 4, 2, 32, False, 0),       # cross attention
    (1, 256, 256, 4, 2, 32, True, 64),      # sliding window
    (1, 128, 128, 8, 8, 16, True, 0),       # MHA, small head
]


@pytest.mark.parametrize("B,S,T,H,KH,D,causal,window", FLASH_CASES)
def test_flash_attention_matches_ref(B, S, T, H, KH, D, causal, window):
    q = _randn((B, S, H, D))
    k = _randn((B, T, KH, D))
    v = _randn((B, T, KH, D))
    out = flash_attention(q, k, v, causal, window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q = _randn((1, 128, 4, 32), dtype)
    k = _randn((1, 128, 2, 32), dtype)
    v = _randn((1, 128, 2, 32), dtype)
    out = flash_attention(q, k, v, True, 0)
    ref = attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_attention_grads_match_ref():
    q = _randn((1, 64, 4, 32))
    k = _randn((1, 64, 2, 32))
    v = _randn((1, 64, 2, 32))

    def loss_kernel(q_, k_, v_):
        return jnp.sum(flash_attention(q_, k_, v_) ** 2)

    def loss_ref(q_, k_, v_):
        return jnp.sum(attention_ref(q_, k_, v_) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# conv3d implicit GEMM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (100, 70, 50),
                                   (300, 200, 150), (1, 1, 1)])
def test_gemm(M, K, N):
    a = _randn((M, K))
    b = _randn((K, N))
    np.testing.assert_allclose(np.asarray(gemm(a, b)), np.asarray(a @ b),
                               atol=5e-4, rtol=1e-4)


CONV_CASES = [
    # N, D, H, W, Ci, Co, k, stride
    (1, 8, 8, 8, 4, 8, 3, 1),
    (2, 13, 13, 13, 8, 16, 3, 2),
    (1, 51, 51, 25, 1, 8, 3, 2),     # the 3DGAN discriminator input shape
    (1, 7, 9, 5, 2, 4, 3, 1),        # ragged spatial dims
]


@pytest.mark.parametrize("N,D,H,W,Ci,Co,k,s", CONV_CASES)
def test_conv3d_matches_lax(N, D, H, W, Ci, Co, k, s):
    x = _randn((N, D, H, W, Ci))
    w = _randn((k, k, k, Ci, Co), scale=0.1)
    out = conv3d(x, w, s)
    ref = conv3d_ref(x, w, s)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("N,D,H,W,Ci,Co,k,s", [
    (1, 4, 4, 4, 4, 8, 3, 2),
    (2, 7, 7, 4, 8, 4, 3, 2),
    (1, 5, 5, 5, 4, 4, 4, 2),        # even kernel
    (1, 6, 6, 6, 4, 4, 3, 3),        # stride 3
])
def test_conv3d_transpose_matches_lax(N, D, H, W, Ci, Co, k, s):
    x = _randn((N, D, H, W, Ci))
    w = _randn((k, k, k, Ci, Co), scale=0.1)
    out = conv3d_transpose(x, w, s)
    ref = conv3d_transpose_ref(x, w, s)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_conv3d_grad_matches_lax():
    x = _randn((1, 6, 6, 6, 2))
    w = _randn((3, 3, 3, 2, 4), scale=0.1)
    gk = jax.grad(lambda x_: jnp.sum(conv3d(x_, w, 2) ** 2))(x)
    gr = jax.grad(lambda x_: jnp.sum(conv3d_ref(x_, w, 2) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

SSM_CASES = [
    # B, S, H, P, N, chunk
    (1, 64, 2, 16, 16, 32),
    (2, 128, 4, 32, 8, 64),
    (1, 96, 1, 8, 4, 32),            # chunk not power-of-two multiple
    (1, 64, 2, 16, 16, 64),          # single chunk
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSM_CASES)
def test_ssm_scan_matches_sequential_ref(B, S, H, P, N, chunk):
    x = _randn((B, S, H, P))
    Bm = _randn((B, S, N), scale=0.5)
    Cm = _randn((B, S, N), scale=0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, (H,)), jnp.float32)
    y, sf = ssm_scan_fwd(x, Bm, Cm, dt, A, chunk=chunk)
    yr, sr = ssm_scan_ref(x, Bm, Cm, dt, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), atol=1e-4)


def test_ssm_scan_carries_init_state():
    B, S, H, P, N = 1, 64, 2, 16, 16
    x = _randn((B, S, H, P))
    Bm = _randn((B, S, N), scale=0.5)
    Cm = _randn((B, S, N), scale=0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    s0 = _randn((B, H, P, N))
    y, sf = ssm_scan_fwd(x, Bm, Cm, dt, A, init_state=s0, chunk=32)
    yr, sr = ssm_scan_ref(x, Bm, Cm, dt, A, init_state=s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), atol=1e-4)


def test_ssm_scan_split_equals_joint():
    """Running two halves with state carry == running the whole sequence."""
    B, S, H, P, N = 1, 128, 2, 16, 8
    x = _randn((B, S, H, P))
    Bm = _randn((B, S, N), scale=0.5)
    Cm = _randn((B, S, N), scale=0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    y_full, s_full = ssm_scan_fwd(x, Bm, Cm, dt, A, chunk=32)
    h = S // 2
    y1, s1 = ssm_scan_fwd(x[:, :h], Bm[:, :h], Cm[:, :h], dt[:, :h], A,
                          chunk=32)
    y2, s2 = ssm_scan_fwd(x[:, h:], Bm[:, h:], Cm[:, h:], dt[:, h:], A,
                          init_state=s1, chunk=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


# ---------------------------------------------------------------------------
# substrate cross-validation: the model-internal chunked scans must agree
# with the kernel oracle
# ---------------------------------------------------------------------------


def test_substrate_mamba2_matches_kernel_oracle():
    """substrate.ssm.apply_mamba2's chunked math == the sequential ref,
    on the SSD core (isolated by driving the same B/C/dt/A through both)."""
    from repro.configs.base import SSMConfig
    from repro.substrate import ssm as ssm_lib

    cfg = SSMConfig(state_dim=8, head_dim=8, expand=2, chunk=32, conv_width=4)
    d_model = 32
    key = jax.random.key(0)
    p = ssm_lib.init_mamba2(key, d_model, cfg)
    x = _randn((2, 64, d_model), scale=0.3)
    out, st = ssm_lib.apply_mamba2(p, x, d_model, cfg, return_state=True)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # decode-step consistency: feeding tokens one by one must reproduce the
    # chunked forward output
    st0 = ssm_lib.mamba2_init_state(d_model, cfg, 2)
    outs = []
    s = st0
    for t in range(8):
        y1, s = ssm_lib.mamba2_step(p, x[:, t:t + 1], s, d_model, cfg)
        outs.append(y1)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(out[:, :8]),
                               atol=2e-3, rtol=2e-2)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-3),
                                        (jnp.bfloat16, 1e-1)])
def test_conv3d_dtypes(dtype, atol):
    x = _randn((1, 8, 8, 8, 4), dtype)
    w = _randn((3, 3, 3, 4, 8), dtype, scale=0.1)
    out = conv3d(x, w, 1)
    ref = conv3d_ref(x, w, 1)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=atol)


def test_ssm_scan_bf16_inputs():
    """bf16 x/B/C inputs: kernel state math stays f32 internally."""
    B, S, H, P, N = 1, 64, 2, 16, 8
    x = _randn((B, S, H, P), jnp.bfloat16)
    Bm = _randn((B, S, N), jnp.bfloat16, scale=0.5)
    Cm = _randn((B, S, N), jnp.bfloat16, scale=0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    y, sf = ssm_scan_fwd(x, Bm, Cm, dt, A, chunk=32)
    yr, sr = ssm_scan_ref(x, Bm, Cm, dt, A)
    assert y.dtype == jnp.float32        # state math in f32
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-2)


def test_flash_kernel_matches_substrate_blockwise():
    """The Pallas kernel and the pure-JAX blockwise path (what the models
    use inside jit) agree — same online-softmax math, two implementations."""
    from repro.substrate.attention import blockwise_attention
    q = _randn((1, 256, 4, 32))
    k = _randn((1, 256, 2, 32))
    v = _randn((1, 256, 2, 32))
    a = flash_attention(q, k, v, True, 0)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


def test_gemm_bf16_accumulates_f32():
    a = _randn((128, 256), jnp.bfloat16)
    b = _randn((256, 64), jnp.bfloat16)
    out = gemm(a, b)
    ref = (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.15, rtol=0.05)
