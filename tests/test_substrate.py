"""Substrate unit tests: attention math, RoPE, MoE dispatch, norms, FFN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.substrate import attention as attn_lib
from repro.substrate import layers
from repro.substrate import moe as moe_lib
from repro.substrate.precision import get_policy

RNG = np.random.default_rng(1)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,H,KH,D,window", [
    (256, 4, 2, 32, 0), (256, 4, 4, 32, 0), (300, 8, 1, 16, 0),
    (256, 4, 2, 32, 64),
])
def test_blockwise_equals_dot_attention(S, H, KH, D, window):
    q = _randn((2, S, H, D))
    k = _randn((2, S, KH, D))
    v = _randn((2, S, KH, D))
    blk = attn_lib.blockwise_attention(q, k, v, causal=True, window=window,
                                       q_chunk=64, kv_chunk=64)
    ref = attn_lib.dot_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_dot_attention_kv_len_masks_cache_tail():
    """Decode semantics: keys beyond kv_len must not contribute."""
    q = _randn((2, 1, 4, 16))
    k = _randn((2, 32, 2, 16))
    v = _randn((2, 32, 2, 16))
    kv_len = jnp.array([8, 16])
    out = attn_lib.dot_attention(q, k, v, causal=False, kv_len=kv_len)
    k2 = k.at[0, 8:].set(99.0).at[1, 16:].set(-99.0)
    v2 = v.at[0, 8:].set(99.0).at[1, 16:].set(-99.0)
    out2 = attn_lib.dot_attention(q, k2, v2, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_rope_preserves_norm_and_relative_phase():
    pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    cos, sin = attn_lib.rope_cos_sin(pos, 32, 10_000.0)
    x = _randn((1, 16, 2, 32))
    r = attn_lib.apply_rope(x, cos, sin)
    # rotation preserves per-pair norm
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(r, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # q.k after rope depends only on relative distance
    q = _randn((1, 1, 1, 32))
    k = _randn((1, 1, 1, 32))
    def dot_at(pq, pk):
        pqv = jnp.full((1, 1), pq)
        pkv = jnp.full((1, 1), pk)
        cq, sq = attn_lib.rope_cos_sin(pqv, 32, 10_000.0)
        ck, sk = attn_lib.rope_cos_sin(pkv, 32, 10_000.0)
        qr = attn_lib.apply_rope(q, cq, sq)
        kr = attn_lib.apply_rope(k, ck, sk)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6   # but not absolute


def test_mrope_reduces_to_rope_when_positions_equal():
    """If t/h/w positions coincide, M-RoPE == plain RoPE."""
    B, S, D = 1, 8, 32
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3 = jnp.broadcast_to(pos[None], (3, B, S))
    c1, s1 = attn_lib.rope_cos_sin(pos, D, 10_000.0)
    c3, s3 = attn_lib.mrope_cos_sin(pos3, D, 10_000.0, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c3), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s3), atol=1e-6)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


class _MoECfg:
    def __init__(self, **kw):
        from repro.configs.base import ArchConfig, MoEConfig
        self.cfg = ArchConfig(
            arch_id="t", family="moe", n_layers=1, d_model=kw.get("d", 32),
            n_heads=4, n_kv_heads=4, d_ff=64, vocab=128, ffn_type="swiglu",
            moe=MoEConfig(n_experts=kw.get("E", 8), top_k=kw.get("K", 2),
                          d_ff_expert=64,
                          capacity_factor=kw.get("cap", 2.0)))


def test_moe_output_shape_and_finite():
    cfg = _MoECfg().cfg
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = _randn((2, 64, cfg.d_model), scale=0.5)
    y, aux, stats = moe_lib.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0
    assert 0.0 <= float(stats["moe_drop_frac"]) <= 1.0


def test_moe_respects_capacity():
    """With capacity_factor ~0, nearly all tokens are dropped -> y ~ 0."""
    cfg = _MoECfg(cap=1e-6).cfg
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = _randn((1, 64, cfg.d_model), scale=0.5)
    y, _, stats = moe_lib.apply_moe(p, x, cfg)
    # capacity floor is top_k slots per expert, so a few tokens survive
    assert float(stats["moe_drop_frac"]) > 0.5


def test_moe_uniform_router_balance():
    """With identical tokens every expert sees the same router prob."""
    cfg = _MoECfg(E=4, K=1).cfg
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))     # uniform router
    x = jnp.ones((1, 64, cfg.d_model)) * 0.1
    _, _, stats = moe_lib.apply_moe(p, x, cfg)
    # load-balance loss at uniform routing equals 1.0 (its minimum)
    assert abs(float(stats["moe_load_balance"]) - 1.0) < 0.05


# ---------------------------------------------------------------------------
# layers / precision
# ---------------------------------------------------------------------------


def test_rmsnorm_unit_scale():
    p = layers.init_norm(64, "rmsnorm")
    x = _randn((4, 64), scale=10.0)
    y = layers.apply_norm(p, x, "rmsnorm")
    rms = np.asarray(jnp.sqrt(jnp.mean(jnp.square(y), axis=-1)))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_layernorm_zero_mean_unit_var():
    p = layers.init_norm(64, "layernorm")
    x = _randn((4, 64), scale=3.0) + 5.0
    y = np.asarray(layers.apply_norm(p, x, "layernorm"))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


def test_norm_statistics_in_f32_for_bf16_inputs():
    p = layers.init_norm(512, "rmsnorm")
    x = _randn((2, 512), jnp.bfloat16, scale=100.0)
    y = layers.apply_norm(p, x, "rmsnorm")
    assert y.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_policy_casts():
    pol = get_policy("bf16")
    tree = {"w": jnp.ones((4,), jnp.float32), "i": jnp.ones((4,), jnp.int32)}
    c = pol.cast_to_compute(tree)
    assert c["w"].dtype == jnp.bfloat16
    assert c["i"].dtype == jnp.int32          # ints untouched
    back = pol.cast_to_param(c)
    assert back["w"].dtype == jnp.float32


@pytest.mark.parametrize("ffn_type", ["swiglu", "gelu", "relu2"])
def test_ffn_types(ffn_type):
    p = layers.init_ffn(jax.random.key(0), 32, 64, ffn_type)
    x = _randn((2, 8, 32))
    y = layers.apply_ffn(p, x, ffn_type)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
