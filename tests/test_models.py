"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant of the same family (<=2 layers, d_model<=512,
<=4 experts) and runs one forward/train step + one decode step on CPU,
asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as config_base
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.substrate.precision import get_policy
from repro.train import steps as steps_lib

POLICY = get_policy("f32")
ARCHS = [a for a in config_base.ARCH_IDS if a != "calo3dgan"]
B, S = 2, 128


def _train_batch(cfg):
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        return {"audio_emb": jnp.asarray(
                    rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (B, 64)), jnp.int32)}
    if cfg.family == "vlm":
        n_patch = 16
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))
        return {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (B, S - n_patch)), jnp.int32),
                "embeds": jnp.asarray(
                    rng.normal(0, 1, (B, n_patch, cfg.d_model)), jnp.float32),
                "positions": jnp.asarray(pos)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_is_reduced(arch):
    cfg = config_base.reduced_config(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The registered full config must carry the exact assigned shape."""
    expect = {
        "whisper-base": (6, 512, 8, 8, 2048, 51_865),
        "dbrx-132b": (40, 6144, 48, 8, 10_752, 100_352),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29_568, 152_064),
        "granite-20b": (52, 6144, 48, 1, 24_576, 49_152),
        "nemotron-4-15b": (32, 6144, 48, 8, 24_576, 256_000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32_000),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50_304),
        "xlstm-125m": (12, 768, 4, 4, 0, 50_304),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151_936),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200_064),
    }[arch]
    cfg = config_base.get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, (got, expect)
    assert cfg.source        # citation required


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = config_base.reduced_config(arch)
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    # logical axes tree must mirror the params tree exactly
    axes = model.logical_axes(cfg)
    from repro.parallel.sharding import _is_axes_leaf
    n_axes = len(jax.tree.leaves(axes, is_leaf=_is_axes_leaf))
    n_params = len(jax.tree.leaves(params))
    assert n_axes == n_params, (n_axes, n_params)

    opt = opt_lib.adamw(1e-3)
    step = jax.jit(steps_lib.make_train_step(model, cfg, opt, POLICY))
    p2, o2, metrics = step(params, opt.init(params), _train_batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, p2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = config_base.reduced_config(arch)
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    cache = model.init_cache(cfg, B, 64, jnp.bfloat16)
    extra = {}
    if cfg.mrope:
        extra["positions"] = jnp.zeros((3, B, 1), jnp.int32)
    serve = jax.jit(steps_lib.make_serve_step(model, cfg, POLICY))
    tok = jnp.ones((B, 1), jnp.int32)
    nxt, cache2 = serve(params, tok, cache, jnp.int32(3), extra)
    assert nxt.shape == (B,)
    assert nxt.dtype == jnp.int32
    assert (np.asarray(nxt) >= 0).all() and (np.asarray(nxt) < cfg.vocab).all()
    # cache updated in place structure-wise
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_two_decode_steps_differ_from_one():
    """The cache must actually carry state between steps."""
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    serve = jax.jit(steps_lib.make_serve_step(model, cfg, POLICY))
    cache0 = model.init_cache(cfg, 1, 16, jnp.bfloat16)
    t = jnp.array([[5]], jnp.int32)
    n1, c1 = serve(params, t, cache0, jnp.int32(0), {})
    # same token at pos 1 with different history in cache
    n2a, _ = serve(params, t, c1, jnp.int32(1), {})
    cache0b = model.init_cache(cfg, 1, 16, jnp.bfloat16)
    n2b, _ = serve(params, jnp.array([[9]], jnp.int32), cache0b,
                   jnp.int32(0), {})
    _, c1b = serve(params, jnp.array([[9]], jnp.int32), cache0b,
                   jnp.int32(0), {})
    n2c, _ = serve(params, t, c1b, jnp.int32(1), {})
    # logits after [5, 5] vs after [9, 5] must differ
    assert int(n2a[0]) != int(n2c[0]) or True   # argmax may coincide...
    # ...so compare the caches' K content instead
    k1 = np.asarray(jax.tree.leaves(c1)[0], np.float32)
    k1b = np.asarray(jax.tree.leaves(c1b)[0], np.float32)
    assert not np.allclose(k1, k1b)


def test_vlm_embeds_prefix_changes_loss():
    cfg = config_base.reduced_config("qwen2-vl-72b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    batch = _train_batch(cfg)
    l1, _ = model.loss_fn(params, batch, cfg, policy=POLICY)
    batch2 = dict(batch, embeds=batch["embeds"] + 1.0)
    l2, _ = model.loss_fn(params, batch2, cfg, policy=POLICY)
    assert float(l1) != float(l2)


def test_whisper_encoder_memory_feeds_decoder():
    cfg = config_base.reduced_config("whisper-base")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    batch = _train_batch(cfg)
    l1, _ = model.loss_fn(params, batch, cfg, policy=POLICY)
    batch2 = dict(batch, audio_emb=batch["audio_emb"] * 2.0 + 1.0)
    l2, _ = model.loss_fn(params, batch2, cfg, policy=POLICY)
    assert float(l1) != float(l2)


def test_param_counts_match_analytic_estimate():
    """Analytic param_count() within 25% of the real reduced-model count
    (rough head/norm terms tolerated)."""
    for arch in ("qwen2-1.5b", "phi4-mini-3.8b", "olmoe-1b-7b"):
        cfg = config_base.reduced_config(arch)
        model = api.get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        real = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - real) / real < 0.25, (arch, est, real)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-1.2b", "xlstm-125m"])
def test_decode_matches_prefill(arch):
    """3 decode steps from a prefilled cache == prefill of the longer
    prompt (the §Perf zamba ring-buffer regression test)."""
    cfg = config_base.reduced_config(arch)
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    logits, cache = model.prefill(params, toks, cfg, policy=POLICY,
                                  max_len=32)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    allt = toks
    for i in range(3):
        allt = jnp.concatenate([allt, cur], axis=1)
        l, cache = model.decode_step(params, cur, cache, jnp.int32(16 + i),
                                     cfg, policy=POLICY)
        cur = jnp.argmax(l[:, -1], -1).astype(jnp.int32)[:, None]
    lb, _ = model.prefill(params, allt, cfg, policy=POLICY, max_len=32)
    err = float(jnp.max(jnp.abs(l[:, -1] - lb[:, -1])))
    assert err < 5e-3, err


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation (§Perf H6) must be numerically equivalent to
    the full-batch step (same grads up to reduction order)."""
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    opt = opt_lib.adamw(1e-3)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)),
                                   jnp.int32)}
    s1 = jax.jit(steps_lib.make_train_step(model, cfg, opt, POLICY,
                                           microbatches=1))
    s4 = jax.jit(steps_lib.make_train_step(model, cfg, opt, POLICY,
                                           microbatches=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)))
    assert d < 5e-3, d


def test_whisper_decode_matches_incremental():
    """encdec: two decode steps with the self/cross cache equal the
    teacher-forced decoder run on the same prefix."""
    cfg = config_base.reduced_config("whisper-base")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    audio = jnp.asarray(rng.normal(0, 1, (2, 24, cfg.d_model)), jnp.float32)
    logits0, cache = model.prefill(params, audio, cfg, policy=POLICY)
    # prefill returns (memory, cache) for encdec — adapt
    memory, cache = logits0 if isinstance(logits0, tuple) else (logits0, cache)
    from repro.models import encdec
    cparams = POLICY.cast_to_compute(params)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 3)), jnp.int32)
    # teacher-forced reference over 3 tokens
    mem = encdec.encode(cparams, audio, cfg)
    h = encdec.decode(cparams, toks, mem, cfg)
    ref = (h[:, -1] @ cparams["embed"]["emb"].T).astype(jnp.float32)
    # incremental decode of the same 3 tokens
    l = None
    for i in range(3):
        l, cache = model.decode_step(params, toks[:, i:i + 1], cache,
                                     jnp.int32(i), cfg, policy=POLICY)
    np.testing.assert_allclose(np.asarray(l[:, -1]), np.asarray(ref),
                               atol=5e-3, rtol=5e-2)


def test_moe_topk_all_experts_close_to_dense_average():
    """With top_k == n_experts and uniform router, MoE output equals the
    average of all experts' FFNs (dispatch/combine math sanity)."""
    from repro.configs.base import ArchConfig, MoEConfig
    from repro.substrate import moe as moe_lib
    cfg = ArchConfig(
        arch_id="t", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=128, ffn_type="swiglu",
        moe=MoEConfig(n_experts=4, top_k=4, d_ff_expert=64,
                      capacity_factor=8.0))
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jnp.asarray(np.random.default_rng(0).normal(0, 0.5, (1, 16, 32)),
                    jnp.float32)
    y, _, stats = moe_lib.apply_moe(p, x, cfg)
    assert float(stats["moe_drop_frac"]) == 0.0
    # manual expert average
    h_all = []
    for e in range(4):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_in"][e])
        h_all.append(h @ p["w_out"][e])
    ref = sum(h_all) / 4.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
