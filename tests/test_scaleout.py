"""Topology-aware 2-level runtime: Topology factories, bucketed
hierarchical gradient reduction (parity with the flat psum for BOTH
engine loops on a virtual node×device mesh), jaxpr collective accounting,
and the subprocess 2x2 virtual-topology gate CI runs."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import calo3dgan
from repro.core import adversarial
from repro.data.calo import CaloSimulator, CaloSpec
from repro.launch.mesh import (TOPOLOGIES, make_node_mesh, topology)
from repro.optim import optimizers as opt_lib
from repro.parallel import collectives
from repro.parallel.jaxpr_cost import cost_of
from repro.train import engine as engine_lib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


def test_topology_factories_cover_paper_configs():
    assert topology("v100", 8).total_devices == 64
    assert topology("v100", 8).mesh_shape == (8, 8)
    for name in ("v100x8", "v100x128", "tpu_v3-8", "tpu_v3-32"):
        assert name in TOPOLOGIES
    assert TOPOLOGIES["v100x128"].nodes == 16
    assert TOPOLOGIES["tpu_v3-32"].total_devices == 32


def test_gpu_topology_links_are_hierarchical():
    t = topology("v100", 2)
    assert t.intra_link.bandwidth > t.inter_link.bandwidth
    assert t.intra_link.latency < t.inter_link.latency
    assert t.axis_names == ("node", "device")


def test_make_node_mesh_folds_host_devices():
    mesh = make_node_mesh(1, 1)
    assert mesh.axis_names == ("node", "device")
    assert mesh.shape == {"node": 1, "device": 1}


def test_make_node_mesh_rejects_oversized_grid():
    with pytest.raises(ValueError, match="virtual topology"):
        make_node_mesh(64, 64)


# ---------------------------------------------------------------------------
# bucket planning + grad-reduce strategies
# ---------------------------------------------------------------------------


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_plan_buckets_respects_cap_and_order():
    leaves = [_sds((256,)), _sds((256,)), _sds((256,)), _sds((4096,))]
    # cap = 2 * 256 f32 leaves -> [0,1], [2], [3 alone: oversize]
    buckets = collectives.plan_buckets(leaves, bucket_bytes=2048)
    assert buckets == [[0, 1], [2], [3]]
    flat = [i for b in buckets for i in b]
    assert flat == list(range(len(leaves)))     # nothing dropped/reordered


def test_plan_buckets_never_mixes_dtypes():
    leaves = [_sds((8,)), _sds((8,), jnp.bfloat16), _sds((8,))]
    buckets = collectives.plan_buckets(leaves, bucket_bytes=1 << 20)
    assert buckets == [[0], [1], [2]]


def test_bucket_transform_is_identity():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((7,)), "c": jnp.zeros((2, 2, 2))}
    out = jax.jit(collectives.bucket_transform(bucket_bytes=32))(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))


def test_make_grad_reduce_validates():
    mesh = make_node_mesh(1, 1)
    with pytest.raises(ValueError, match="grad_reduce"):
        collectives.make_grad_reduce("nope", mesh, ("node", "device"))
    with pytest.raises(ValueError, match="2-level"):
        collectives.make_grad_reduce("hierarchical", mesh, ("node",))
    fn = collectives.make_grad_reduce(lambda t: t, mesh, ("node",))
    assert fn(3) == 3                            # callables pass through


def test_builtin_loop_honors_callable_grad_reduce():
    """A user-supplied callable must reach the step in BOTH loops — a
    zeroing reduce leaves params untouched."""
    mesh = make_node_mesh(1, 1)
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=0)
    batch = next(sim.batches(8))
    task = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4))
    eng = engine_lib.Engine(mesh, "builtin",
                            dp_axes=("node", "device"),
                            grad_reduce=lambda t: jax.tree.map(
                                jnp.zeros_like, t))
    state = eng.init_state(task, jax.random.key(0))
    step = eng.compile_step(task, batch)
    new_state, _ = step(state, batch, jax.random.key(1))
    before = eng.init_state(task, jax.random.key(0))   # state was donated
    for a, b in zip(jax.tree.leaves(before.g_params),
                    jax.tree.leaves(new_state.g_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_rejects_hierarchical_on_flat_mesh():
    from repro.launch.mesh import make_dev_mesh
    with pytest.raises(ValueError, match="2-level"):
        engine_lib.Engine(make_dev_mesh(), "custom", dp_axes=("data",),
                          grad_reduce="hierarchical")


# ---------------------------------------------------------------------------
# hierarchical vs flat parity (virtual node×device mesh, both loops)
# ---------------------------------------------------------------------------

GAN_CFG = calo3dgan.bench()


def _run_gan(loop, strategy, batches, mesh):
    task = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4))
    eng = engine_lib.Engine(mesh, loop, dp_axes=("node", "device"),
                            grad_reduce=strategy, bucket_mb=0.05)
    state = eng.init_state(task, jax.random.key(0))
    step = eng.compile_step(task, batches[0])
    rng = jax.random.key(1)
    for b in batches:
        rng, k = jax.random.split(rng)
        state, metrics = step(state, b, k)
    return state, metrics


@pytest.mark.parametrize("loop", ("builtin", "custom"))
def test_hierarchical_matches_flat_psum(loop):
    """The acceptance gate: hierarchical grad_reduce is numerically
    interchangeable with the flat psum path on a node×device mesh, for
    both engine loops (f32 tolerance; multi-participant reduction order
    is covered by tools/parity_scaleout.py on 4 virtual devices)."""
    mesh = make_node_mesh(1, 1)
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=3)
    batches = [next(sim.batches(8)) for _ in range(2)]
    flat_state, flat_m = _run_gan(loop, "flat", batches, mesh)
    hier_state, hier_m = _run_gan(loop, "hierarchical", batches, mesh)
    for a, b in zip(jax.tree.leaves(flat_state.g_params)
                    + jax.tree.leaves(flat_state.d_params),
                    jax.tree.leaves(hier_state.g_params)
                    + jax.tree.leaves(hier_state.d_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for k in flat_m:
        assert float(flat_m[k]) == pytest.approx(float(hier_m[k]),
                                                 rel=1e-4, abs=1e-5), k


def test_lm_custom_loop_hierarchical_matches_flat():
    """steps.make_train_step consumes the same grad_reduce hook — the
    LM path must be strategy-agnostic too."""
    from repro.configs import base as config_base
    from repro.data.tokens import MarkovTokens
    from repro.models import api
    from repro.substrate.precision import get_policy

    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    data = MarkovTokens(cfg.vocab, seed=0)
    batches = [{"tokens": data.sample(4, 64)} for _ in range(2)]
    mesh = make_node_mesh(1, 1)
    losses = {}
    for strat in ("flat", "hierarchical"):
        task = engine_lib.lm_task(model, cfg, opt_lib.adamw(1e-3),
                                  policy=get_policy("f32"))
        eng = engine_lib.Engine(mesh, "custom", dp_axes=("node", "device"),
                                grad_reduce=strat)
        state = eng.init_state(task, jax.random.key(0))
        step = eng.compile_step(task, batches[0])
        ls = []
        for b in batches:
            state, m = step(state, b, jax.random.key(2))
            ls.append(float(m["loss"]))
        losses[strat] = ls
    assert losses["flat"] == pytest.approx(losses["hierarchical"],
                                           rel=1e-6)


# ---------------------------------------------------------------------------
# jaxpr collective accounting + reduce traffic
# ---------------------------------------------------------------------------


def test_grad_reduce_traffic_matches_param_bytes():
    from repro.core import gan
    from repro.parallel.sharding import count_params

    cfg = calo3dgan.reduced()
    traffic = adversarial.grad_reduce_traffic(cfg)
    g = gan.init_generator(jax.random.key(0), cfg)
    d = gan.init_discriminator(jax.random.key(1), cfg)
    gb, db = 4 * count_params(g), 4 * count_params(d)
    rounds = dict(traffic["rounds"])
    assert rounds["d_real"] == db and rounds["d_fake"] == db
    assert rounds["g0"] == gb
    assert traffic["bytes_per_step"] == 2 * db + cfg.gen_steps_per_disc * gb


def test_jaxpr_cost_counts_shard_map_psum_bytes():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_node_mesh(1, 1)

    def local(x):
        return jax.lax.psum(x, ("node", "device"))

    fn = shard_map(local, mesh=mesh, in_specs=P(), out_specs=P(),
                   check_rep=False)
    stats = cost_of(fn, jax.ShapeDtypeStruct((256, 128), jnp.float32))
    # mesh.size (=1) * result bytes
    assert stats["collective_bytes"] == 256 * 128 * 4


def test_custom_loop_collective_bytes_cover_grad_traffic():
    """The custom GAN step's traced psums must carry at least the
    per-phase gradient payload adversarial.grad_reduce_traffic predicts
    (plus small metric reductions) — the jaxpr walk feeds the
    interconnect model with the right order of magnitude."""
    mesh = make_node_mesh(1, 1)
    task = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4))
    eng = engine_lib.Engine(mesh, "custom", dp_axes=("node", "device"))
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=0)
    batch = next(sim.batches(8))
    step = task.make_step(grad_reduce=eng._grad_reduce, mesh=None)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    state = eng.init_state(task, jax.random.key(0))
    smapped = shard_map(step, mesh=mesh,
                        in_specs=(P(), P(), P()), out_specs=(P(), P()),
                        check_rep=False)
    stats = cost_of(smapped, state, batch, jax.random.key(1))
    expect = adversarial.grad_reduce_traffic(GAN_CFG)["bytes_per_step"]
    assert stats["collective_bytes"] >= expect
    assert stats["collective_bytes"] <= expect * 1.5 + (1 << 20)


# ---------------------------------------------------------------------------
# the 2x2 multi-participant gate (subprocess: own 4-device pool)
# ---------------------------------------------------------------------------


def test_virtual_2x2_parity_subprocess():
    """Runs tools/parity_scaleout.py — 4 virtual devices folded into
    (node=2, device=2), REAL two-participant reductions at both levels —
    and requires parity for both loops (the CI scaleout-smoke gate)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "parity_scaleout.py")],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "parity OK" in r.stdout
