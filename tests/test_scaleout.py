"""Topology-aware 2-level runtime: Topology factories, bucketed
hierarchical + backward-overlapped gradient reduction (parity with the
flat psum for BOTH engine loops on a virtual node×device mesh), ZeRO-1
sharded-optimizer parity, jaxpr collective accounting (per-kind bytes,
schedule exposure, per-device state bytes), and the subprocess 2x2
virtual-topology gate CI runs."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import calo3dgan
from repro.core import adversarial
from repro.data.calo import CaloSimulator, CaloSpec
from repro.launch.mesh import (TOPOLOGIES, make_node_mesh, topology)
from repro.optim import optimizers as opt_lib
from repro.parallel import collectives
from repro.parallel.jaxpr_cost import cost_of
from repro.train import engine as engine_lib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


def test_topology_factories_cover_paper_configs():
    assert topology("v100", 8).total_devices == 64
    assert topology("v100", 8).mesh_shape == (8, 8)
    for name in ("v100x8", "v100x128", "tpu_v3-8", "tpu_v3-32"):
        assert name in TOPOLOGIES
    assert TOPOLOGIES["v100x128"].nodes == 16
    assert TOPOLOGIES["tpu_v3-32"].total_devices == 32


def test_gpu_topology_links_are_hierarchical():
    t = topology("v100", 2)
    assert t.intra_link.bandwidth > t.inter_link.bandwidth
    assert t.intra_link.latency < t.inter_link.latency
    assert t.axis_names == ("node", "device")


def test_make_node_mesh_folds_host_devices():
    mesh = make_node_mesh(1, 1)
    assert mesh.axis_names == ("node", "device")
    assert mesh.shape == {"node": 1, "device": 1}


def test_make_node_mesh_rejects_oversized_grid():
    with pytest.raises(ValueError, match="virtual topology"):
        make_node_mesh(64, 64)


# ---------------------------------------------------------------------------
# bucket planning + grad-reduce strategies
# ---------------------------------------------------------------------------


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_plan_buckets_respects_cap_and_order():
    leaves = [_sds((256,)), _sds((256,)), _sds((256,)), _sds((4096,))]
    # cap = 2 * 256 f32 leaves -> [0,1], [2], [3 alone: oversize]
    buckets = collectives.plan_buckets(leaves, bucket_bytes=2048)
    assert buckets == [[0, 1], [2], [3]]
    flat = [i for b in buckets for i in b]
    assert flat == list(range(len(leaves)))     # nothing dropped/reordered


def test_plan_buckets_never_mixes_dtypes():
    leaves = [_sds((8,)), _sds((8,), jnp.bfloat16), _sds((8,))]
    buckets = collectives.plan_buckets(leaves, bucket_bytes=1 << 20)
    assert buckets == [[0], [1], [2]]


def test_bucket_transform_is_identity():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((7,)), "c": jnp.zeros((2, 2, 2))}
    out = jax.jit(collectives.bucket_transform(bucket_bytes=32))(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))


def test_make_grad_reduce_validates():
    mesh = make_node_mesh(1, 1)
    with pytest.raises(ValueError, match="grad_reduce"):
        collectives.make_grad_reduce("nope", mesh, ("node", "device"))
    with pytest.raises(ValueError, match="2-level"):
        collectives.make_grad_reduce("hierarchical", mesh, ("node",))
    fn = collectives.make_grad_reduce(lambda t: t, mesh, ("node",))
    assert fn(3) == 3                            # callables pass through


def test_builtin_loop_honors_callable_grad_reduce():
    """A user-supplied callable must reach the step in BOTH loops — a
    zeroing reduce leaves params untouched."""
    mesh = make_node_mesh(1, 1)
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=0)
    batch = next(sim.batches(8))
    task = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4))
    eng = engine_lib.Engine(mesh, "builtin",
                            dp_axes=("node", "device"),
                            grad_reduce=lambda t: jax.tree.map(
                                jnp.zeros_like, t))
    state = eng.init_state(task, jax.random.key(0))
    step = eng.compile_step(task, batch)
    new_state, _ = step(state, batch, jax.random.key(1))
    before = eng.init_state(task, jax.random.key(0))   # state was donated
    for a, b in zip(jax.tree.leaves(before.g_params),
                    jax.tree.leaves(new_state.g_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_rejects_hierarchical_on_flat_mesh():
    from repro.launch.mesh import make_dev_mesh
    with pytest.raises(ValueError, match="2-level"):
        engine_lib.Engine(make_dev_mesh(), "custom", dp_axes=("data",),
                          grad_reduce="hierarchical")


# ---------------------------------------------------------------------------
# hierarchical vs flat parity (virtual node×device mesh, both loops)
# ---------------------------------------------------------------------------

GAN_CFG = calo3dgan.bench()


def _run_gan(loop, strategy, batches, mesh):
    task = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4))
    eng = engine_lib.Engine(mesh, loop, dp_axes=("node", "device"),
                            grad_reduce=strategy, bucket_mb=0.05)
    state = eng.init_state(task, jax.random.key(0))
    step = eng.compile_step(task, batches[0])
    rng = jax.random.key(1)
    for b in batches:
        rng, k = jax.random.split(rng)
        state, metrics = step(state, b, k)
    return state, metrics


@pytest.mark.parametrize("strategy", ("hierarchical", "overlap"))
@pytest.mark.parametrize("loop", ("builtin", "custom"))
def test_strategies_match_flat_psum(loop, strategy):
    """The acceptance gate: hierarchical AND backward-overlapped
    grad_reduce are numerically interchangeable with the flat psum path
    on a node×device mesh, for both engine loops (builtin: bit-identical
    — a single replica reduces to the identity; custom: f32 tolerance.
    Multi-participant reduction order is covered by
    tools/parity_scaleout.py on 4 virtual devices)."""
    mesh = make_node_mesh(1, 1)
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=3)
    batches = [next(sim.batches(8)) for _ in range(2)]
    flat_state, flat_m = _run_gan(loop, "flat", batches, mesh)
    alt_state, alt_m = _run_gan(loop, strategy, batches, mesh)
    for a, b in zip(jax.tree.leaves(flat_state.g_params)
                    + jax.tree.leaves(flat_state.d_params),
                    jax.tree.leaves(alt_state.g_params)
                    + jax.tree.leaves(alt_state.d_params)):
        if loop == "builtin":
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=2e-6)
    for k in flat_m:
        assert float(flat_m[k]) == pytest.approx(float(alt_m[k]),
                                                 rel=1e-4, abs=1e-5), k


def test_lm_custom_loop_strategies_match_flat():
    """steps.make_train_step consumes the same grad_reduce hook — the
    LM path must be strategy-agnostic too (overlap included: the
    wrap_params tagging path through the custom_vjp)."""
    from repro.configs import base as config_base
    from repro.data.tokens import MarkovTokens
    from repro.models import api
    from repro.substrate.precision import get_policy

    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    data = MarkovTokens(cfg.vocab, seed=0)
    batches = [{"tokens": data.sample(4, 64)} for _ in range(2)]
    mesh = make_node_mesh(1, 1)
    losses = {}
    for strat in ("flat", "hierarchical", "overlap"):
        task = engine_lib.lm_task(model, cfg, opt_lib.adamw(1e-3),
                                  policy=get_policy("f32"))
        eng = engine_lib.Engine(mesh, "custom", dp_axes=("node", "device"),
                                grad_reduce=strat)
        state = eng.init_state(task, jax.random.key(0))
        step = eng.compile_step(task, batches[0])
        ls = []
        for b in batches:
            state, m = step(state, b, jax.random.key(2))
            ls.append(float(m["loss"]))
        losses[strat] = ls
    assert losses["flat"] == pytest.approx(losses["hierarchical"],
                                           rel=1e-6)
    assert losses["flat"] == pytest.approx(losses["overlap"], rel=1e-6)


# ---------------------------------------------------------------------------
# ZeRO-1 sharded optimizer
# ---------------------------------------------------------------------------


def test_zero1_matches_replicated_optimizer():
    """zero1(rmsprop) must walk the same trajectory as plain rmsprop —
    the sharded (N, L) master layout + gather is pure data movement.
    4 shards on a 1x1 mesh exercises the layout without an axis."""
    mesh = make_node_mesh(1, 1)
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=3)
    batches = [next(sim.batches(8)) for _ in range(2)]

    def train(make_opt):
        task = engine_lib.gan_task(GAN_CFG, make_opt(), make_opt())
        eng = engine_lib.Engine(mesh, "custom", dp_axes=("node", "device"),
                                grad_reduce="flat")
        state = eng.init_state(task, jax.random.key(0))
        step = eng.compile_step(task, batches[0])
        rng = jax.random.key(1)
        for b in batches:
            rng, k = jax.random.split(rng)
            state, _ = step(state, b, k)
        return state

    rep = train(lambda: opt_lib.rmsprop(1e-4))
    z = train(lambda: opt_lib.zero1(opt_lib.rmsprop(1e-4), 4))
    for a, b in zip(jax.tree.leaves(rep.g_params)
                    + jax.tree.leaves(rep.d_params),
                    jax.tree.leaves(z.g_params)
                    + jax.tree.leaves(z.d_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=2e-6)


def test_zero1_state_layout_and_padding():
    """The (N, L) shard-major layout: padding stays zero after updates
    (the cross-N resharding invariant) and the master row concatenation
    reconstructs the params exactly at init."""
    params = {"w": jnp.arange(10.0), "b": jnp.ones((3,))}
    opt = opt_lib.zero1(opt_lib.rmsprop(1e-2), 4)
    st = opt.init(params)
    m = np.asarray(st["zero1"]["master"])
    assert m.shape[0] == 4 and m.size >= 13
    flat = m.reshape(-1)
    np.testing.assert_allclose(flat[:3], 1.0)       # "b" flattens first
    np.testing.assert_allclose(flat[3:13], np.arange(10.0))
    assert np.all(flat[13:] == 0)                  # zero padding
    grads = jax.tree.map(jnp.ones_like, params)
    upd, st2 = opt.update(grads, st, params)
    assert np.all(np.asarray(st2["zero1"]["master"]).reshape(-1)[13:] == 0)
    new = jax.tree.map(lambda p, u: p + u, params, upd)
    # element-wise rmsprop on the flat layout == rmsprop on the tree
    ref_upd, _ = opt_lib.rmsprop(1e-2).update(
        grads, opt_lib.rmsprop(1e-2).init(params), params)
    for k in params:
        np.testing.assert_allclose(np.asarray(new[k]),
                                   np.asarray(params[k] + ref_upd[k]),
                                   rtol=1e-6)


def test_per_device_state_bytes_zero1_is_fraction_of_replicated():
    """The bench's memory columns: a zero1 state's per-device
    optimizer+master bytes must be ~1/N of the replicated equivalent."""
    from repro.parallel import jaxpr_cost

    n = 8
    task_rep = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                                   opt_lib.rmsprop(1e-4))
    task_z = engine_lib.gan_task(
        GAN_CFG, opt_lib.zero1(opt_lib.rmsprop(1e-4), n),
        opt_lib.zero1(opt_lib.rmsprop(1e-4), n))
    rep = jax.eval_shape(task_rep.init, jax.random.key(0))
    z = jax.eval_shape(task_z.init, jax.random.key(0))
    # optimizer + master: replicated masters are the f32 params
    om_rep = (jaxpr_cost.per_device_state_bytes(
        {"g": rep.g_opt, "d": rep.d_opt}, 1)
        + jaxpr_cost.per_device_state_bytes(
            {"g": rep.g_params, "d": rep.d_params}, 1))
    om_z = jaxpr_cost.per_device_state_bytes({"g": z.g_opt, "d": z.d_opt}, n)
    assert om_z <= om_rep / n * 1.10 + 65536
    # and sharding marks only the zero1 subtree
    assert jaxpr_cost.per_device_state_bytes(z, n) < \
        jaxpr_cost.per_device_state_bytes(z, 1)


# ---------------------------------------------------------------------------
# jaxpr collective accounting + reduce traffic
# ---------------------------------------------------------------------------


def test_grad_reduce_traffic_matches_param_bytes():
    from repro.core import gan
    from repro.parallel.sharding import count_params

    cfg = calo3dgan.reduced()
    traffic = adversarial.grad_reduce_traffic(cfg)
    g = gan.init_generator(jax.random.key(0), cfg)
    d = gan.init_discriminator(jax.random.key(1), cfg)
    gb, db = 4 * count_params(g), 4 * count_params(d)
    rounds = dict(traffic["rounds"])
    assert rounds["d_real"] == db and rounds["d_fake"] == db
    assert rounds["g0"] == gb
    assert traffic["bytes_per_step"] == 2 * db + cfg.gen_steps_per_disc * gb


def test_jaxpr_cost_counts_shard_map_psum_bytes():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_node_mesh(1, 1)

    def local(x):
        return jax.lax.psum(x, ("node", "device"))

    fn = shard_map(local, mesh=mesh, in_specs=P(), out_specs=P(),
                   check_rep=False)
    stats = cost_of(fn, jax.ShapeDtypeStruct((256, 128), jnp.float32))
    # mesh.size (=1) * result bytes
    assert stats["collective_bytes"] == 256 * 128 * 4


def test_jaxpr_cost_per_kind_collective_bytes():
    """psum / all_gather / psum_scatter land in their own byte columns
    (what separates ZeRO's reduce-scatter + all-gather from plain
    all-reduce in the bench report)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_node_mesh(1, 1)

    def local(x):
        a = jax.lax.psum(x, ("node", "device"))
        g = jax.lax.all_gather(a, ("node", "device"), axis=0, tiled=False)
        s = jax.lax.psum_scatter(a.reshape(-1), ("node", "device"),
                                 tiled=True)
        return a, g, s

    fn = shard_map(local, mesh=mesh, in_specs=P(), out_specs=(P(), P(), P()),
                   check_rep=False)
    stats = cost_of(fn, jax.ShapeDtypeStruct((16, 8), jnp.float32))
    nb = 16 * 8 * 4
    assert stats["psum_bytes"] == nb
    assert stats["all_gather_bytes"] == nb          # world size 1
    assert stats["reduce_scatter_bytes"] == nb
    assert stats["collective_bytes"] == 3 * nb


def test_collective_schedule_overlap_exposes_less():
    """The MEASURED overlap story: the reverse-order bucket schedule must
    leave a strictly smaller byte-fraction of its collectives exposed
    (no independent later compute) than the post-backward hierarchical
    schedule, on the real custom-loop GAN step."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel import jaxpr_cost

    mesh = make_node_mesh(1, 1)
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=0)
    batch = next(sim.batches(8))
    fracs = {}
    for strat in ("hierarchical", "overlap"):
        task = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                                   opt_lib.rmsprop(1e-4))
        eng = engine_lib.Engine(mesh, "custom", dp_axes=("node", "device"),
                                grad_reduce=strat, bucket_mb=0.05)
        state = eng.init_state(task, jax.random.key(0))
        reduce = collectives.make_grad_reduce(strat, mesh,
                                              ("node", "device"),
                                              bucket_bytes=int(0.05 *
                                                               (1 << 20)))
        step = task.make_step(grad_reduce=reduce, mesh=None)
        smapped = shard_map(step, mesh=mesh,
                            in_specs=(P(), P(), P()),
                            out_specs=(P(), P()), check_rep=False)
        sched = jaxpr_cost.schedule_of(smapped, state, batch,
                                       jax.random.key(1))
        assert sched["n_collectives"] > 0
        fracs[strat] = sched["exposed_frac"]
    assert 0.0 < fracs["overlap"] < fracs["hierarchical"] <= 1.0


def test_custom_loop_collective_bytes_cover_grad_traffic():
    """The custom GAN step's traced psums must carry at least the
    per-phase gradient payload adversarial.grad_reduce_traffic predicts
    (plus small metric reductions) — the jaxpr walk feeds the
    interconnect model with the right order of magnitude."""
    mesh = make_node_mesh(1, 1)
    task = engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4))
    eng = engine_lib.Engine(mesh, "custom", dp_axes=("node", "device"))
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=0)
    batch = next(sim.batches(8))
    step = task.make_step(grad_reduce=eng._grad_reduce, mesh=None)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    state = eng.init_state(task, jax.random.key(0))
    smapped = shard_map(step, mesh=mesh,
                        in_specs=(P(), P(), P()), out_specs=(P(), P()),
                        check_rep=False)
    stats = cost_of(smapped, state, batch, jax.random.key(1))
    expect = adversarial.grad_reduce_traffic(GAN_CFG)["bytes_per_step"]
    assert stats["collective_bytes"] >= expect
    assert stats["collective_bytes"] <= expect * 1.5 + (1 << 20)


# ---------------------------------------------------------------------------
# the 2x2 multi-participant gate (subprocess: own 4-device pool)
# ---------------------------------------------------------------------------


def test_virtual_2x2_parity_subprocess():
    """Runs tools/parity_scaleout.py — 4 virtual devices folded into
    (node=2, device=2), REAL two-participant reductions at both levels —
    and requires parity for both loops across every strategy (flat /
    hierarchical / overlap) plus the ZeRO-1 sharded-optimizer gate
    (the CI scaleout-smoke job)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "parity_scaleout.py")],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "parity OK" in r.stdout
    assert "zero1 parity OK" in r.stdout
