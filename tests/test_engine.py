"""Unified data-parallel engine: the paper's builtin vs custom loops must
agree numerically on a 1-device mesh, gradient accumulation must match the
full-batch step, and the data pipeline the engine composes (ShardStore +
sharded prefetch) must round-trip and preserve order."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as config_base, calo3dgan
from repro.data.calo import CaloSimulator, CaloSpec
from repro.data.pipeline import ShardStore, prefetch
from repro.data.tokens import MarkovTokens
from repro.launch.mesh import make_dev_mesh
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.substrate.precision import get_policy
from repro.train import engine as engine_lib

GAN_CFG = calo3dgan.reduced()


def _gan_task(microbatches=1):
    return engine_lib.gan_task(GAN_CFG, opt_lib.rmsprop(1e-4),
                               opt_lib.rmsprop(1e-4),
                               microbatches=microbatches)


def _gan_batches(n, batch=8, seed=3):
    sim = CaloSimulator(CaloSpec(image_shape=GAN_CFG.image_shape), seed=seed)
    return [next(sim.batches(batch)) for _ in range(n)]


# ---------------------------------------------------------------------------
# builtin vs custom parity
# ---------------------------------------------------------------------------


def test_gan_builtin_and_custom_losses_close():
    """On a 1-device mesh both loop strategies are the same program: the
    custom loop folds the replica index (0) into the step rng, so handing
    the builtin loop the pre-folded key must reproduce every metric."""
    mesh = make_dev_mesh()
    batches = _gan_batches(3)
    traces = {}
    for loop in ("builtin", "custom"):
        eng = engine_lib.Engine(mesh, loop)
        task = _gan_task()
        state = eng.init_state(task, jax.random.key(0))
        step = eng.compile_step(task, batches[0])
        rng = jax.random.key(1)
        ms = []
        for b in eng.data_iter(iter(batches)):
            rng, k = jax.random.split(rng)
            k = k if loop == "custom" else jax.random.fold_in(k, 0)
            state, m = step(state, b, k)
            ms.append({name: float(v) for name, v in m.items()})
        traces[loop] = ms
    for mb, mc in zip(traces["builtin"], traces["custom"]):
        for name in mb:
            assert mb[name] == pytest.approx(mc[name], rel=2e-3,
                                             abs=2e-3), name


def test_lm_builtin_and_custom_losses_close():
    """The LM loss is rng-free, so the two loops must agree directly."""
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    data = MarkovTokens(cfg.vocab, seed=0)
    batches = [{"tokens": data.sample(4, 64)} for _ in range(3)]
    losses = {}
    for loop in ("builtin", "custom"):
        task = engine_lib.lm_task(model, cfg, opt_lib.adamw(1e-3),
                                  policy=get_policy("f32"))
        eng = engine_lib.Engine(make_dev_mesh(), loop)
        state = eng.init_state(task, jax.random.key(0))
        step = eng.compile_step(task, batches[0])
        ls = []
        for b in batches:
            state, m = step(state, b, jax.random.key(9))
            ls.append(float(m["loss"]))
        losses[loop] = ls
    np.testing.assert_allclose(losses["builtin"], losses["custom"],
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# gradient accumulation & fit
# ---------------------------------------------------------------------------


def test_lm_grad_accumulation_matches_full_batch():
    """microbatches=2 averages per-microbatch grads of equal size, so one
    step must match the full-batch step to float tolerance."""
    cfg = config_base.reduced_config("qwen2-1.5b")
    model = api.get_model(cfg)
    data = MarkovTokens(cfg.vocab, seed=0)
    batch = {"tokens": data.sample(4, 64)}
    mesh = make_dev_mesh()
    states = {}
    for m_count in (1, 2):
        task = engine_lib.lm_task(model, cfg, opt_lib.adamw(1e-3),
                                  policy=get_policy("f32"),
                                  microbatches=m_count)
        eng = engine_lib.Engine(mesh, "builtin", donate=False)
        state = eng.init_state(task, jax.random.key(0))
        step = eng.compile_step(task, batch)
        states[m_count], _ = step(state, batch, jax.random.key(1))
    for a, b in zip(jax.tree.leaves(states[1].params),
                    jax.tree.leaves(states[2].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_gan_accumulated_step_runs_and_is_finite():
    """Algorithm 1 with phase-wise gradient accumulation stays finite and
    preserves the update order (one optimizer update per phase)."""
    eng = engine_lib.Engine(make_dev_mesh(), "custom")
    state, metrics = eng.fit(_gan_task(microbatches=2),
                             iter(_gan_batches(2)), 2,
                             rng=jax.random.key(0))
    assert all(np.isfinite(float(v)) for v in metrics.values())


def test_fit_runs_both_loops_end_to_end():
    for loop in ("builtin", "custom"):
        eng = engine_lib.Engine(make_dev_mesh(), loop)
        state, metrics = eng.fit(_gan_task(), iter(_gan_batches(2)), 2,
                                 rng=jax.random.key(0))
        assert set(metrics) >= {"d_loss_real", "d_loss_fake", "g_loss"}
        assert all(np.isfinite(float(v)) for v in metrics.values())


def test_custom_loop_rejects_indivisible_batch():
    """Explicit per-device assignment is the custom loop's contract — a
    batch that does not divide the data shards must fail loudly, not be
    silently replicated."""
    mesh = make_dev_mesh()
    eng = engine_lib.Engine(mesh, "custom")
    if eng.n_shards == 1:
        pytest.skip("needs >1 data shard to be indivisible")
    bad = {"x": np.zeros((eng.n_shards + 1, 3), np.float32)}
    with pytest.raises(ValueError):
        eng.batch_pspecs(bad)


def test_engine_build_lowers_and_compiles():
    """The AOT path (weak-scaling bench / dry-run) compiles both loops."""
    from repro.launch import build as build_lib
    mesh = make_dev_mesh()
    for loop in ("builtin", "custom"):
        built = build_lib.build_gan_train(mesh, reduced=True,
                                          policy_name="f32", loop=loop)
        assert built.lower().compile() is not None


# ---------------------------------------------------------------------------
# data pipeline pieces the engine composes
# ---------------------------------------------------------------------------


def test_shard_store_roundtrip(tmp_path):
    store = ShardStore(str(tmp_path / "shards"))
    rng = np.random.default_rng(0)
    arrays = {"image": rng.normal(size=(4, 3, 3, 2)).astype(np.float32),
              "e_p": rng.uniform(10, 500, 4).astype(np.float32)}
    store.write("s0", arrays)
    assert store.shard_names() == ["s0"]
    back = store.read("s0")
    assert set(back) == set(arrays)
    for k in arrays:
        np.testing.assert_array_equal(back[k], arrays[k])


def test_prefetch_with_sharding_preserves_order_and_places():
    mesh = make_dev_mesh()
    sh = {"x": NamedSharding(mesh, P())}
    batches = [{"x": np.full((2,), i, np.float32)} for i in range(7)]
    out = list(prefetch(iter(batches), size=2, sharding=sh))
    assert len(out) == 7
    for i, b in enumerate(out):
        assert isinstance(b["x"], jax.Array)
        np.testing.assert_array_equal(np.asarray(b["x"]), i)


def test_engine_data_iter_shards_batches():
    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    batches = _gan_batches(2, batch=4)
    out = list(eng.data_iter(iter(batches)))
    assert len(out) == 2
    for got, src in zip(out, batches):
        assert isinstance(got["image"], jax.Array)
        np.testing.assert_allclose(np.asarray(got["image"]), src["image"])


# ---------------------------------------------------------------------------
# async-dispatch fit loop: windowed metric logging, no per-step host sync
# ---------------------------------------------------------------------------


def test_fit_windowed_logging_dispatch_count():
    """With log_every=N the loop performs one host transfer per window —
    not per step — and logs at the window-end step indices."""
    from repro.train.metrics import MetricLog
    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    log = MetricLog(print_every=0)
    state, metrics = eng.fit(_gan_task(), iter(_gan_batches(8, batch=4)), 8,
                             rng=jax.random.key(0), log=log, log_every=4)
    assert eng.last_fit_stats["steps"] == 8
    assert eng.last_fit_stats["host_transfers"] == 2
    assert [r["step"] for r in log.rows] == [3, 7]
    assert "d_loss_real" in log.rows[0]

    # a partial final window still flushes
    log2 = MetricLog(print_every=0)
    eng.fit(_gan_task(), iter(_gan_batches(5, batch=4)), 5,
            rng=jax.random.key(0), log=log2, log_every=4)
    assert eng.last_fit_stats["host_transfers"] == 2
    assert [r["step"] for r in log2.rows] == [3, 4]

    # log_every=1 reproduces the old per-step cadence
    log3 = MetricLog(print_every=0)
    eng.fit(_gan_task(), iter(_gan_batches(3, batch=4)), 3,
            rng=jax.random.key(0), log=log3, log_every=1)
    assert eng.last_fit_stats["host_transfers"] == 3
    assert [r["step"] for r in log3.rows] == [0, 1, 2]


def test_fit_window_means_match_per_step_logs():
    """The windowed means are exactly the mean of the per-step metrics
    (same rng => same step stream on both runs)."""
    from repro.train.metrics import MetricLog
    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    per_step, windowed = MetricLog(print_every=0), MetricLog(print_every=0)
    eng.fit(_gan_task(), iter(_gan_batches(4, batch=4)), 4,
            rng=jax.random.key(5), log=per_step, log_every=1)
    eng.fit(_gan_task(), iter(_gan_batches(4, batch=4)), 4,
            rng=jax.random.key(5), log=windowed, log_every=4)
    assert len(windowed.rows) == 1
    for key in ("d_loss_real", "d_loss_fake", "g_loss"):
        want = np.mean([r[key] for r in per_step.rows])
        np.testing.assert_allclose(windowed.rows[0][key], want, rtol=1e-6)


def test_fit_no_device_to_host_transfers_without_log():
    """The loop itself must not read from device: with logging off, a
    whole fit under a disallow-transfers guard completes cleanly."""
    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    task = _gan_task()
    batches = _gan_batches(3, batch=4)
    state = eng.init_state(task, jax.random.key(0))
    with jax.transfer_guard_device_to_host("disallow"):
        state, metrics = eng.fit(task, iter(batches), 3,
                                 rng=jax.random.key(1), state=state)
    assert eng.last_fit_stats["host_transfers"] == 0
    assert np.isfinite(float(metrics["g_loss"]))


def test_fit_sync_every_escape_hatch():
    from repro.train.metrics import MetricLog
    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    log = MetricLog(print_every=0)
    eng.fit(_gan_task(), iter(_gan_batches(4, batch=4)), 4,
            rng=jax.random.key(0), log=log, log_every=4, sync_every=2)
    assert eng.last_fit_stats["host_transfers"] == 1


def test_metric_accumulator_single_transfer():
    from repro.train.metrics import MetricAccumulator
    acc = MetricAccumulator()
    for i in range(3):
        acc.update({"a": jnp.float32(i), "b": jnp.float32(2 * i)})
    means = acc.means()
    assert means == {"a": 1.0, "b": 2.0}
    acc.reset()
    assert acc.means() == {}


def test_fit_flushes_partial_window_on_stream_exhaustion():
    """If the batch stream runs dry before ``steps``, the trailing
    partial window is still flushed (the old per-step logger never
    dropped completed steps)."""
    from repro.train.metrics import MetricLog
    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    log = MetricLog(print_every=0)
    eng.fit(_gan_task(), iter(_gan_batches(6, batch=4)), 10,
            rng=jax.random.key(0), log=log, log_every=4)
    assert eng.last_fit_stats["steps"] == 6
    assert eng.last_fit_stats["host_transfers"] == 2
    assert [r["step"] for r in log.rows] == [3, 5]


# ---------------------------------------------------------------------------
# overlapped input pipeline: producer-side device_put + h2d observability
# ---------------------------------------------------------------------------


def test_prefetch_issues_device_put_on_producer_thread(monkeypatch):
    """The overlap contract: host->device placement happens on the
    PRODUCER thread (under the running step), never on the consumer."""
    import threading

    from repro.data import pipeline as pipeline_lib

    calls = []
    real_put = jax.device_put

    def spy(x, *a, **kw):
        calls.append(threading.current_thread())
        return real_put(x, *a, **kw)

    monkeypatch.setattr(jax, "device_put", spy)
    batches = [{"x": np.full((2,), i, np.float32)} for i in range(5)]
    pf = pipeline_lib.prefetch(iter(batches), size=2)
    main = threading.current_thread()
    out = list(pf)
    assert len(out) == 5 and len(calls) == 5
    assert all(t is not main for t in calls)
    assert pf.stats["batches"] == 5
    assert pf.stats["h2d_wait_ms"] >= 0.0


def test_prefetch_propagates_producer_errors():
    from repro.data import pipeline as pipeline_lib

    def gen():
        yield {"x": np.zeros((2,), np.float32)}
        raise RuntimeError("source died")

    pf = pipeline_lib.prefetch(gen(), size=2)
    next(pf)
    with pytest.raises(RuntimeError, match="source died"):
        next(pf)


def test_fit_reports_per_window_h2d_wait():
    """last_fit_stats carries the prefetcher's consumer-stall time, one
    entry per logging window (the paper's overlap made observable)."""

    class _Log:
        def log(self, *a, **kw):
            pass

    eng = engine_lib.Engine(make_dev_mesh(), "builtin")
    eng.fit(_gan_task(), iter(_gan_batches(4)), 4,
            rng=jax.random.key(0), log=_Log(), log_every=2)
    stats = eng.last_fit_stats
    assert stats["steps"] == 4
    assert stats["host_transfers"] == 2
    assert len(stats["h2d_wait_ms_windows"]) == 2
    assert stats["h2d_wait_ms"] >= 0.0
    assert stats["h2d_wait_ms"] == pytest.approx(
        sum(stats["h2d_wait_ms_windows"]), abs=1e-6)
