"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests must see the
real single CPU device (the 512-device override is dryrun.py-only)."""
import jax
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_global_numpy_rng():
    """Pin the GLOBAL numpy RNG per test and restore it afterwards.

    Library code under test must not depend on ``np.random`` module state
    (everything seeds its own Generator), but test helpers occasionally
    reach for it — this makes any such use deterministic and
    order-independent, so tier-1 results never depend on which tests ran
    first (the flakiness audit of the elastic PR)."""
    saved = np.random.get_state()
    np.random.seed(0)
    yield
    np.random.set_state(saved)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def dev_mesh():
    from repro.launch.mesh import make_dev_mesh
    return make_dev_mesh(data=len(jax.devices()))
