"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests must see the
real single CPU device (the 512-device override is dryrun.py-only)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def dev_mesh():
    from repro.launch.mesh import make_dev_mesh
    return make_dev_mesh(data=len(jax.devices()))
