"""Train any assigned architecture (reduced) through the unified API —
the same train_step the 256-chip dry-run compiles, on the dev mesh.

  PYTHONPATH=src python examples/train_lm_arch.py --arch olmoe-1b-7b
  PYTHONPATH=src python examples/train_lm_arch.py --arch zamba2-1.2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as config_base
from repro.data.tokens import MarkovTokens
from repro.launch.mesh import make_dev_mesh
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.parallel import sharding
from repro.substrate.precision import get_policy
from repro.train import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b",
                    choices=[a for a in config_base.ARCH_IDS
                             if a != "calo3dgan"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = config_base.reduced_config(args.arch)
    model = api.get_model(cfg)
    policy = get_policy("f32")
    mesh = make_dev_mesh(data=len(jax.devices()))

    params = model.init(jax.random.key(0), cfg)
    print(f"{args.arch} (reduced): {sharding.count_params(params):,} params, "
          f"family={cfg.family}")

    opt = opt_lib.adamw(opt_lib.warmup_cosine(3e-3, 5, args.steps))
    ostate = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(model, cfg, opt, policy,
                                             mesh=mesh),
                   donate_argnums=(0, 1))
    data = MarkovTokens(cfg.vocab, seed=0)

    def make_batch():
        if cfg.family == "audio":
            return {"audio_emb": jnp.asarray(np.random.default_rng(0).normal(
                        0, 1, (args.batch, args.seq, cfg.d_model)),
                        jnp.float32),
                    "tokens": jnp.asarray(data.sample(args.batch, 64))}
        if cfg.family == "vlm":
            n_patch = 16
            S = args.seq
            pos = np.broadcast_to(np.arange(S, dtype=np.int32),
                                  (3, args.batch, S)).copy()
            return {"tokens": jnp.asarray(data.sample(args.batch, S - n_patch)),
                    "embeds": jnp.zeros((args.batch, n_patch, cfg.d_model),
                                        jnp.float32),
                    "positions": jnp.asarray(pos)}
        return {"tokens": jnp.asarray(data.sample(args.batch, args.seq))}

    t0 = time.time()
    with mesh:
        for i in range(args.steps):
            params, ostate, m = step(params, ostate, make_batch())
            if i % 10 == 0:
                print(f"step {i:3d} loss={float(m['loss']):.3f} "
                      f"gnorm={float(m['grad_norm']):.2f}")
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s")


if __name__ == "__main__":
    main()
