"""Serve a small LM with batched requests through the continuous-batching
engine — the serving-side analogue of the paper's "keep everything on the
accelerator" discipline (one compiled decode step, slot-pooled KV cache).

Trains qwen2-1.5b (reduced) briefly on the Markov stream first so the
served generations show the learned structure, then serves a batch of
prompts.

  PYTHONPATH=src python examples/serve_lm.py --train-steps 30 --requests 6
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as config_base
from repro.data.tokens import MarkovTokens
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.serve.engine import Request, ServeEngine
from repro.substrate.precision import get_policy
from repro.train import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = config_base.reduced_config(args.arch)
    model = api.get_model(cfg)
    policy = get_policy("f32")
    params = model.init(jax.random.key(0), cfg)

    # -- brief training so decoding isn't random --------------------------
    data = MarkovTokens(cfg.vocab, seed=0)
    opt = opt_lib.adamw(3e-3)
    ostate = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(model, cfg, opt, policy),
                   donate_argnums=(0, 1))
    for i in range(args.train_steps):
        params, ostate, m = step(params, ostate,
                                 {"tokens": jnp.asarray(data.sample(8, 128))})
        if i % 10 == 0:
            print(f"train step {i:3d} loss={float(m['loss']):.3f}")

    # -- batched serving ---------------------------------------------------
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=128)
    rng = np.random.default_rng(1)
    for rid in range(args.requests):
        prompt = data.sample(1, int(rng.integers(4, 10)))[0]
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"\nserved {len(done)} requests / {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: {r.prompt.tolist()} -> {r.tokens}")


if __name__ == "__main__":
    main()
