"""End-to-end fast simulation: train the 3DGAN briefly, then SERVE showers.

The paper's whole point compressed into one script: a short fused-loop
training burst (the bench-sized config so CPU runs finish in seconds),
checkpoint the generator, restore it into the bucketed serving engine
(`serve/simulate.SimulateEngine`), push a mix of odd-sized requests
through it, and let the rolling physics gate compare every window of
generated showers against fresh Monte Carlo — the same Fig. 3/7 numbers
that validate training fidelity, now guarding the deployment.

  PYTHONPATH=src python examples/simulate_showers.py \
      --train-steps 10 --requests 6 --max-events 24
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import calo3dgan
from repro.core import adversarial, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.optim import optimizers as opt_lib
from repro.serve.simulate import PhysicsGate, SimRequest, SimulateEngine
from repro.train import checkpoint as ckpt_lib


def train_briefly(cfg, steps, seed, batch=16):
    g_opt, d_opt = opt_lib.rmsprop(2e-4), opt_lib.rmsprop(2e-4)
    state = adversarial.init_state(jax.random.key(seed), cfg, g_opt, d_opt)
    fused = jax.jit(adversarial.make_fused_step(cfg, g_opt, d_opt),
                    donate_argnums=(0,))
    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=seed)
    rng = jax.random.key(seed + 1)
    it = sim.batches(batch)
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        rng, k = jax.random.split(rng)
        state, _ = fused(state, b, k)
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-events", type=int, default=24)
    ap.add_argument("--buckets", default="4,16")
    ap.add_argument("--gate-window", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = calo3dgan.bench()

    # -- train briefly, checkpoint the generator --------------------------
    print(f"training 3DGAN ({args.train_steps} fused steps)...")
    state = train_briefly(cfg, args.train_steps, args.seed)
    ckpt_dir = tempfile.mkdtemp(prefix="gan_ckpt_")
    ckpt_lib.save(ckpt_dir, state.g_params, step=args.train_steps,
                  extra={"kind": "gan_generator", "precision": "f32"})
    print(f"saved generator checkpoint to {ckpt_dir}")

    # -- restore into the serving engine (the production handoff);
    #    from_checkpoint also picks up the recorded precision policy ------
    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape),
                        seed=args.seed + 1)
    mc = next(sim.batches(max(128, args.gate_window)))
    gate = PhysicsGate(validation.reference_profiles(mc["image"], mc["e_p"]),
                       window=args.gate_window)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    eng = SimulateEngine.from_checkpoint(ckpt_dir, cfg, buckets=buckets,
                                         gate=gate)
    eng.warmup()

    # -- serve a mix of odd-sized requests --------------------------------
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        eng.submit(SimRequest(
            rid=rid, primary_energy=float(rng.uniform(10.0, 500.0)),
            n_events=int(rng.integers(1, args.max_events + 1)),
            seed=int(rng.integers(0, 2**31 - 1))))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    gate.flush()

    n_ev = eng.stats["events_generated"]
    print(f"\nserved {len(done)} requests / {n_ev} events in {dt:.2f}s "
          f"({n_ev / dt:.1f} events/s, {eng.compile_count} compiled "
          f"programs for buckets {buckets})")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: E_p={r.primary_energy:6.1f} GeV "
              f"x {r.n_events:3d} events -> images{r.images.shape} "
              f"E_CAL_mean={r.images.sum(axis=(1, 2, 3, 4)).mean():.3f} "
              f"({1e3 * r.latency_s:.0f}ms)")
    for i, rep in enumerate(gate.reports):
        print(f"  gate window {i} ({rep['count']:.0f} events): "
              + " ".join(f"{k}={rep[k]:.3f}" for k in
                         ("longitudinal_kl", "transverse_x_kl",
                          "transverse_y_kl", "response_rel_err")))
    assert all(r.images.shape[0] == r.n_events for r in done)
    print("every request got exactly n_events showers back")


if __name__ == "__main__":
    main()
