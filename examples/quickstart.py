"""Quickstart: the paper's technique in 60 lines.

Trains the (reduced) 3DGAN with the FUSED adversarial step — the paper's
custom-training-loop optimisation — on synthetic calorimeter Monte Carlo,
then validates the generated showers against fresh MC.

  PYTHONPATH=src python examples/quickstart.py [--steps 40]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import calo3dgan
from repro.core import adversarial, gan, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.optim import optimizers as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    cfg = calo3dgan.reduced()
    g_opt = opt_lib.rmsprop(2e-4)
    d_opt = opt_lib.rmsprop(2e-4)

    # ---- the paper's contribution: ONE compiled program for Algorithm 1
    state = adversarial.init_state(jax.random.key(0), cfg, g_opt, d_opt)
    fused_step = jax.jit(adversarial.make_fused_step(cfg, g_opt, d_opt),
                         donate_argnums=(0,))

    # ---- synthetic Geant4 stand-in ------------------------------------
    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=0)
    batches = sim.batches(args.batch)

    rng = jax.random.key(1)
    for i, batch in zip(range(args.steps), batches):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        rng, k = jax.random.split(rng)
        state, metrics = fused_step(state, b, k)
        if i % 10 == 0:
            print(f"step {i:4d}  d_real={float(metrics['d_loss_real']):.3f} "
                  f"d_fake={float(metrics['d_loss_fake']):.3f} "
                  f"g={float(metrics['g_loss']):.3f}")

    # ---- physics validation (paper Fig. 3) ------------------------------
    mc = next(sim.batches(128))
    noise = jax.random.normal(jax.random.key(2), (128, cfg.latent_dim))
    fake = gan.generate(state.g_params, noise, jnp.asarray(mc["e_p"]),
                        jnp.asarray(mc["theta"]), cfg)
    rep = validation.validation_report(np.asarray(fake), mc["image"],
                                       mc["e_p"], mc["e_p"])
    print("\nGAN vs Monte Carlo:")
    for k, v in rep.items():
        print(f"  {k:24s} {v:.4f}")


if __name__ == "__main__":
    main()
