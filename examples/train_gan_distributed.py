"""End-to-end driver: distributed 3DGAN training exactly as it would run on
a TPU pod — explicit mesh, (pod, data)-sharded batches, replicated params
(the paper's mirrored strategy), host-side prefetch overlapping compute.

On this CPU container the mesh is 1 device; on a v5e pod the SAME script
runs with make_production_mesh() — nothing else changes (that's the point
of the build layer; the 256/512-chip compile is proven by dryrun.py).

  PYTHONPATH=src python examples/train_gan_distributed.py --steps 100
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import calo3dgan
from repro.core import adversarial, gan, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.data.pipeline import prefetch
from repro.launch.mesh import make_dev_mesh
from repro.optim import optimizers as opt_lib
from repro.parallel import sharding
from repro.train.metrics import MetricLog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--per-replica-batch", type=int, default=16)
    ap.add_argument("--log", default="")
    args = ap.parse_args()

    mesh = make_dev_mesh(data=len(jax.devices()))
    n_rep = mesh.devices.size
    global_batch = args.per_replica_batch * n_rep
    print(f"mesh {dict(mesh.shape)} -> global batch {global_batch}")

    cfg = calo3dgan.reduced()
    g_opt = opt_lib.rmsprop(2e-4)
    d_opt = opt_lib.rmsprop(2e-4)
    state = adversarial.init_state(jax.random.key(0), cfg, g_opt, d_opt)

    # paper's mirrored strategy: replicated params, batch over data axis
    rep = NamedSharding(mesh, P())
    bsh = NamedSharding(mesh, P(sharding.batch_axes(mesh)))
    state = jax.device_put(state, rep)

    fused = jax.jit(adversarial.make_fused_step(cfg, g_opt, d_opt),
                    donate_argnums=(0,))

    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=0)
    shardings = {"image": NamedSharding(
                     mesh, P(sharding.batch_axes(mesh), None, None, None, None)),
                 "e_p": bsh, "theta": bsh, "ecal": bsh}
    batches = prefetch(sim.batches(global_batch), size=2, sharding=shardings)

    log = MetricLog(args.log or None, print_every=10)
    rng = jax.random.key(1)
    t0 = time.time()
    with mesh:
        for i, batch in zip(range(args.steps), batches):
            rng, k = jax.random.split(rng)
            state, m = fused(state, batch, k)
            log.log(i, **{kk: float(v) for kk, v in m.items()})
    dt = time.time() - t0
    print(f"{args.steps} steps x {global_batch} samples in {dt:.1f}s "
          f"({args.steps * global_batch / dt:.1f} samples/s)")

    mc = next(sim.batches(128))
    noise = jax.random.normal(jax.random.key(2), (128, cfg.latent_dim))
    fake = gan.generate(state.g_params, noise, jnp.asarray(mc["e_p"]),
                        jnp.asarray(mc["theta"]), cfg)
    rep_ = validation.validation_report(np.asarray(fake), mc["image"],
                                        mc["e_p"], mc["e_p"])
    print("physics:", {k: round(v, 4) for k, v in rep_.items()})


if __name__ == "__main__":
    main()
