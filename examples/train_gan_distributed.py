"""End-to-end driver: distributed 3DGAN training exactly as it would run on
a TPU pod — explicit mesh, (pod, data)-sharded batches, replicated params
(the paper's mirrored strategy), host-side prefetch overlapping compute.

Routes through the unified data-parallel engine (`repro.train.engine`), so
the paper's two loop strategies are one flag apart:

  PYTHONPATH=src python examples/train_gan_distributed.py --steps 100
  PYTHONPATH=src python examples/train_gan_distributed.py --loop custom

On this CPU container the mesh is 1 device; on a v5e pod the SAME script
runs with make_production_mesh() — nothing else changes (that's the point
of the build layer; the 256/512-chip compile is proven by dryrun.py).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import calo3dgan
from repro.core import gan, validation
from repro.data.calo import CaloSimulator, CaloSpec
from repro.launch.mesh import make_dev_mesh
from repro.optim import optimizers as opt_lib
from repro.train import engine as engine_lib
from repro.train.metrics import MetricLog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--per-replica-batch", type=int, default=16)
    ap.add_argument("--loop", default="builtin",
                    choices=("builtin", "custom"))
    ap.add_argument("--log", default="")
    args = ap.parse_args()

    mesh = make_dev_mesh(data=len(jax.devices()))
    n_rep = mesh.devices.size
    global_batch = args.per_replica_batch * n_rep
    print(f"mesh {dict(mesh.shape)} -> global batch {global_batch} "
          f"({args.loop} loop)")

    cfg = calo3dgan.reduced()
    task = engine_lib.gan_task(cfg, opt_lib.rmsprop(2e-4),
                               opt_lib.rmsprop(2e-4))
    # paper's mirrored strategy: replicated params, batch over all axes
    eng = engine_lib.Engine(mesh, args.loop, dp_axes=tuple(mesh.axis_names))

    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=0)
    log = MetricLog(args.log or None, print_every=10)
    t0 = time.time()
    state, _ = eng.fit(task, sim.batches(global_batch), args.steps,
                       rng=jax.random.key(1), log=log)
    dt = time.time() - t0
    print(f"{args.steps} steps x {global_batch} samples in {dt:.1f}s "
          f"({args.steps * global_batch / dt:.1f} samples/s)")

    mc = next(sim.batches(128))
    noise = jax.random.normal(jax.random.key(2), (128, cfg.latent_dim))
    fake = gan.generate(state.g_params, noise, jnp.asarray(mc["e_p"]),
                        jnp.asarray(mc["theta"]), cfg)
    rep_ = validation.validation_report(np.asarray(fake), mc["image"],
                                        mc["e_p"], mc["e_p"])
    print("physics:", {k: round(v, 4) for k, v in rep_.items()})


if __name__ == "__main__":
    main()
