"""Kernel benchmark: Pallas flash-attention vs the pure-JAX reference.

Times forward and forward+backward on representative LM attention shapes
(self-attention, GQA head grouping, sliding window) for both routes:

- ``pallas``: the flash-attention kernel family (online-softmax forward
  emitting the LSE residual, flash-2 recompute backward), block sizes
  from the shared autotune registry.  On the CPU stand-in this runs in
  INTERPRET mode, which measures the emulation, not the MXU — the
  numbers seed the perf trajectory and become meaningful on TPU.
- ``ref``: the O(S*T)-memory reference (`flash_attention/ref.py`).

The ``tile_rows`` section is the autotuner's report card: each case is
timed on the Pallas route with the HEURISTIC default blocks at f32
against the AUTOTUNED blocks at ``--precision`` (tuned via the shared
`kernels/autotune.autotune_signature` driver, persisted under
results/autotune/).

Writes machine-readable results to results/BENCH_kernel_attention.json.

  PYTHONPATH=src python -m benchmarks.bench_kernel_attention \
      [--batch 1] [--steps 2] [--precision bf16] [--no-tile-rows]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune as autotune_lib
from repro.kernels.flash_attention import tune as tune_lib
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.substrate.precision import get_policy

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(HERE, "results", "BENCH_kernel_attention.json")

# (name, seq_q, seq_kv, heads, kv_heads, d_head, causal, window)
CASES = [
    ("self_128", 128, 128, 4, 4, 64, True, 0),
    ("gqa_128", 128, 128, 8, 2, 32, True, 0),
    ("window_256", 256, 256, 4, 2, 64, True, 64),
]


def _timed(fn, args, steps, repeats=3):
    """Min-of-repeats per-step time — the autotuner's clock, so recorded
    numbers and tuning winners are measured identically."""
    return autotune_lib.time_min_of_repeats(fn, args, steps, repeats)


def _case_args(seq_q, seq_kv, heads, kv_heads, d_head, batch, rng, dtype):
    q = jnp.asarray(rng.normal(0, 1, (batch, seq_q, heads, d_head)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (batch, seq_kv, kv_heads, d_head)),
                    dtype)
    v = jnp.asarray(rng.normal(0, 1, (batch, seq_kv, kv_heads, d_head)),
                    dtype)
    return q, k, v


def _time_route(op, causal, window, args, steps):
    fwd = jax.jit(lambda q_, k_, v_: op(q_, k_, v_, causal, window))
    fwdbwd = jax.jit(jax.grad(
        lambda q_, k_, v_: jnp.sum(
            op(q_, k_, v_, causal, window).astype(jnp.float32) ** 2),
        argnums=(0, 1, 2)))
    return (1e3 * _timed(fwd, args, steps), 1e3 * _timed(fwdbwd, args, steps))


def bench_case(name, seq_q, seq_kv, heads, kv_heads, d_head, causal, window,
               batch, steps, rng, dtype):
    args = _case_args(seq_q, seq_kv, heads, kv_heads, d_head, batch, rng,
                      dtype)
    row = {"case": name, "batch": batch, "seq_q": seq_q, "seq_kv": seq_kv,
           "heads": heads, "kv_heads": kv_heads, "d_head": d_head,
           "causal": causal, "window": window}
    ops = {
        "pallas": flash_attention,
        "ref": lambda q_, k_, v_, c, w: attention_ref(q_, k_, v_, causal=c,
                                                      window=w),
    }
    for route, op in ops.items():
        f, fb = _time_route(op, causal, window, args, steps)
        row[f"{route}_fwd_ms"], row[f"{route}_fwdbwd_ms"] = f, fb
    row["fwd_speedup"] = row["ref_fwd_ms"] / row["pallas_fwd_ms"]
    row["fwdbwd_speedup"] = row["ref_fwdbwd_ms"] / row["pallas_fwdbwd_ms"]
    return row


def bench_case_tiles(name, seq_q, seq_kv, heads, kv_heads, d_head, causal,
                     window, batch, steps, rng, precision, autotune_steps=2):
    """Autotuned-vs-default-block row: f32 operands + heuristic default
    blocks against ``--precision`` operands + autotuned blocks."""
    policy = get_policy(precision)
    dtype = policy.compute_dtype
    snapshot = dict(autotune_lib._REGISTRY)
    row = {"case": name, "seq_q": seq_q, "seq_kv": seq_kv, "heads": heads,
           "kv_heads": kv_heads, "d_head": d_head, "precision": precision}
    try:
        sig32 = tune_lib.signature(seq_q, seq_kv, heads, kv_heads, d_head,
                                   causal, window, jnp.float32)
        autotune_lib.register_schedule(sig32,
                                       autotune_lib.default_schedule(sig32))
        args32 = _case_args(seq_q, seq_kv, heads, kv_heads, d_head, batch,
                            rng, jnp.float32)
        f32_fwd, f32_fwdbwd = _time_route(flash_attention, causal, window,
                                          args32, steps)
        # unpin BEFORE autotuning: the driver persists the whole registry,
        # and the heuristic baseline must not overwrite tuned f32 entries
        autotune_lib._REGISTRY.pop(sig32, None)

        sig = tune_lib.signature(seq_q, seq_kv, heads, kv_heads, d_head,
                                 causal, window, dtype)
        best, measured = autotune_lib.autotune_signature(
            sig, steps=autotune_steps)
        row["blocks"] = {"block_q": best.block_q, "block_kv": best.block_kv}
        args_p = _case_args(seq_q, seq_kv, heads, kv_heads, d_head, batch,
                            rng, dtype)
        at_fwd, at_fwdbwd = _time_route(flash_attention, causal, window,
                                        args_p, steps)
    finally:
        autotune_lib._REGISTRY.clear()
        autotune_lib._REGISTRY.update(snapshot)
    row.update({
        "default_f32_fwd_ms": f32_fwd, "default_f32_fwdbwd_ms": f32_fwdbwd,
        "autotuned_fwd_ms": at_fwd, "autotuned_fwdbwd_ms": at_fwdbwd,
        "autotune_measurements": measured,
        "fwd_speedup": f32_fwd / at_fwd,
        "fwdbwd_speedup": f32_fwdbwd / at_fwdbwd,
    })
    return row


def run(batch=1, steps=2, seed=0, precision="f32"):
    dtype = get_policy(precision).compute_dtype
    rng = np.random.default_rng(seed)
    return [bench_case(*case, batch=batch, steps=steps, rng=rng, dtype=dtype)
            for case in CASES]


def run_tiles(batch=1, steps=2, seed=0, precision="bf16"):
    rng = np.random.default_rng(seed)
    return [bench_case_tiles(*case, batch=batch, steps=steps, rng=rng,
                             precision=precision)
            for case in CASES]


def write_json(rows, path=OUT_PATH, **meta):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {"benchmark": "kernel_attention",
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu", **meta,
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--precision", default="bf16",
                    help="compute dtype for the route rows and the "
                         "autotuned side of the tile rows")
    ap.add_argument("--no-tile-rows", action="store_true",
                    help="skip the autotuned-vs-default-block comparison")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv)
    rows = run(args.batch, args.steps, precision=args.precision)
    print(f"bench_kernel_attention: Pallas flash vs reference "
          f"(B={args.batch}, precision={args.precision}, "
          f"backend={jax.default_backend()})")
    print(f"{'case':>12} {'S':>5} {'H':>3} {'KH':>3} {'pallas_fwd':>11} "
          f"{'ref_fwd':>9} {'pallas_fb':>10} {'ref_fb':>8} {'fb_speedup':>10}")
    for r in rows:
        print(f"{r['case']:>12} {r['seq_q']:>5} {r['heads']:>3} "
              f"{r['kv_heads']:>3} {r['pallas_fwd_ms']:>9.1f}ms "
              f"{r['ref_fwd_ms']:>7.1f}ms {r['pallas_fwdbwd_ms']:>8.1f}ms "
              f"{r['ref_fwdbwd_ms']:>6.1f}ms {r['fwdbwd_speedup']:>10.2f}")
    meta = {"batch": args.batch, "precision": args.precision}
    if not args.no_tile_rows:
        tile_rows = run_tiles(args.batch, args.steps,
                              precision=args.precision)
        print(f"\nblock autotuner: {args.precision}+autotuned vs "
              "f32+default blocks (Pallas route, fwd+bwd)")
        for r in tile_rows:
            b = r.get("blocks", {})
            bl = f"bq={b.get('block_q', '?')},bkv={b.get('block_kv', '?')}"
            print(f"{r['case']:>12} {bl:>16} "
                  f"{r['default_f32_fwdbwd_ms']:>9.1f}ms "
                  f"{r['autotuned_fwdbwd_ms']:>7.1f}ms "
                  f"{r['fwdbwd_speedup']:>8.2f}")
        meta["tile_rows"] = tile_rows
    path = write_json(rows, args.out, **meta)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    main()
