"""Fig. 6 (right): data-pipeline optimisation — prefetch/caching vs naive.

The paper verified that Azure ML's automatic dataset management (caching,
prefetching, parallel loading) matches a hand-tuned tf.data pipeline.  The
JAX analogue measured here: the double-buffered host->device ``prefetch``
iterator (data/pipeline.py) overlapping host batch prep with device
compute, vs. a naive synchronous iterator that prepares each batch on the
host while the device idles.

On a 1-core CPU container the overlap win is bounded by the shared core;
on a real TPU host (many cores, device compute off-CPU) the naive loop's
host time adds ~fully to step time — the derived column models that.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import calo3dgan
from repro.core import adversarial
from repro.data.calo import CaloSimulator, CaloSpec
from repro.data.pipeline import prefetch
from repro.optim import optimizers as opt_lib


def run(steps=6, batch=16):
    cfg = calo3dgan.bench()
    g_opt = opt_lib.rmsprop(1e-4)
    d_opt = opt_lib.rmsprop(1e-4)
    state = adversarial.init_state(jax.random.key(0), cfg, g_opt, d_opt)
    fused = jax.jit(adversarial.make_fused_step(cfg, g_opt, d_opt))
    sim = CaloSimulator(CaloSpec(image_shape=cfg.image_shape), seed=0)

    # warmup / compile
    b0 = {k: jnp.asarray(v) for k, v in next(sim.batches(batch)).items()}
    s, _ = fused(state, b0, jax.random.key(1))
    jax.block_until_ready(s.g_params)

    # host-side data-prep cost alone
    t0 = time.perf_counter()
    for _ in range(steps):
        next(sim.batches(batch))
    t_host = (time.perf_counter() - t0) / steps

    # naive: synchronous host prep each step
    it = sim.batches(batch)
    rng = jax.random.key(2)
    t0 = time.perf_counter()
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        rng, k = jax.random.split(rng)
        s, _ = fused(state, b, k)
    jax.block_until_ready(s.g_params)
    t_naive = (time.perf_counter() - t0) / steps

    # prefetched: host prep overlaps device compute
    rng = jax.random.key(2)
    pf = prefetch(sim.batches(batch), size=2)
    t0 = time.perf_counter()
    for _, b in zip(range(steps), pf):
        rng, k = jax.random.split(rng)
        s, _ = fused(state, b, k)
    jax.block_until_ready(s.g_params)
    t_pf = (time.perf_counter() - t0) / steps

    return {
        "host_prep_ms": 1e3 * t_host,
        "naive_ms": 1e3 * t_naive,
        "prefetch_ms": 1e3 * t_pf,
        # derived: on a TPU host the device step does not occupy the host
        # cores, so prefetch hides min(host, device) fully
        "derived_tpu_hidden_frac": min(t_host, t_naive - t_host)
        / max(t_naive, 1e-9),
    }


def main():
    r = run()
    print("bench_fig6_pipeline: prefetch overlap vs naive host prep")
    for k, v in r.items():
        print(f"  {k:24s} {v:.2f}")
    print("paper Fig.6-right: managed pipeline == hand-tuned cache/prefetch;"
          " the win is hiding host prep behind device compute")
    return r


if __name__ == "__main__":
    main()
