import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=128 "
                           "--xla_backend_optimization_level=0 "
                           "--xla_llvm_disable_expensive_passes=true")
"""Fig. 2 (right): weak scaling over multi-GPU NODES for the 3DGAN.

Runs in its OWN process (sets a 128-device pool before importing jax).
For each node count we fold the virtual devices into the paper's
hierarchical ``(node, device)`` topology (8 V100-class GPUs per node),
compile the GAN step THROUGH THE UNIFIED ENGINE (``--loop`` /
``--grad-reduce`` select the strategy) at the paper's per-device BS=128
(global batch grows with devices: weak scaling), and report TWO curves
side by side:

- measured: the roofline-derived step/epoch time from the COMPILED
  program — jaxpr FLOPs/bytes against the topology's per-device
  constants, plus the compiled collective traffic priced on the
  topology's NVLink/NIC links;
- predicted: the cloud planner's curve (`cloud/planner.py`) — the
  committed measured single-node step baseline
  (``results/BENCH_fig1_loop.json``) replayed through the interconnect
  model.  No efficiency table anywhere on either path.

Per-strategy overlap accounting (``--grad-reduce all`` records every
strategy side by side):

- ``exposed_comm_s`` — MEASURED: the traced program's collective
  schedule (`parallel/jaxpr_cost.collective_schedule`, custom loop —
  the builtin loop's collectives are inserted by GSPMD after lowering,
  so its column stays null) prices only the collectives with no
  independent later compute to hide under;
- ``modeled_exposed_comm_s`` — the interconnect model's exposure
  (`cloud/interconnect.exposed_comm_s` with the real per-round
  tail-bucket plan from ``adversarial.grad_reduce_traffic``) applied to
  the SAME measured payload;
- ``step_gap_s`` — |modeled - measured|: the model-fidelity gap the
  ``--check`` gate pins (overlap's gap must not exceed hierarchical's);
- ``state_bytes_per_device`` / ``state_bytes_per_device_zero1`` and the
  ``opt_master_bytes_per_device*`` pair — what one device holds with a
  replicated vs ZeRO-1-sharded (`optim.optimizers.zero1`) optimizer;
  ``--check`` also pins zero1's optimizer+master bytes to ~replicated/N.

``--out`` writes the BENCH_fig2_weakscaling.json artifact (the schema
``benchmarks/run.py`` records for every bench).
"""
import argparse
import json
import time

import numpy as np


def _state_rows(cfg, n):
    """Per-device state-byte columns: replicated vs ZeRO-1 over n shards
    (shapes only — nothing is allocated)."""
    import jax
    from repro.optim import optimizers as opt_lib
    from repro.parallel import jaxpr_cost
    from repro.train import engine as engine_lib

    def shapes(g_opt, d_opt):
        task = engine_lib.gan_task(cfg, g_opt, d_opt)
        return jax.eval_shape(task.init, jax.random.key(0))

    rep = shapes(opt_lib.rmsprop(1e-4), opt_lib.rmsprop(1e-4))
    z = shapes(opt_lib.zero1(opt_lib.rmsprop(1e-4), n),
               opt_lib.zero1(opt_lib.rmsprop(1e-4), n))
    # "optimizer + master" per device: the replicated baseline's masters
    # are the f32 params themselves, zero1 folds its master copy into
    # the sharded optimizer subtree
    om_rep = (jaxpr_cost.per_device_state_bytes(
        {"g": rep.g_opt, "d": rep.d_opt}, 1)
        + jaxpr_cost.per_device_state_bytes(
            {"g": rep.g_params, "d": rep.d_params}, 1))
    om_z = jaxpr_cost.per_device_state_bytes(
        {"g": z.g_opt, "d": z.d_opt}, n)
    return {
        "state_bytes_per_device": jaxpr_cost.per_device_state_bytes(rep, 1),
        "state_bytes_per_device_zero1":
            jaxpr_cost.per_device_state_bytes(z, n),
        "opt_master_bytes_per_device": om_rep,
        "opt_master_bytes_per_device_zero1": om_z,
    }


def run(node_counts=(1, 2, 4, 8, 16), devices_per_node=8, loop="builtin",
        grad_reduce="hierarchical", bucket_mb=4.0, results_dir="results"):
    import jax
    from jax.sharding import Mesh
    from repro.cloud import interconnect, planner
    from repro.configs import calo3dgan
    from repro.core import adversarial
    from repro.launch import build as build_lib
    from repro.launch.mesh import gpu_topology
    from repro.parallel import collectives, jaxpr_cost

    strategies = (collectives.GRAD_REDUCE_STRATEGIES
                  if grad_reduce == "all" else (grad_reduce,)
                  if isinstance(grad_reduce, str) else tuple(grad_reduce))
    bucket_bytes = int(bucket_mb * (1 << 20))
    cfg = calo3dgan.config()
    traffic = adversarial.grad_reduce_traffic(cfg, bucket_bytes)
    try:
        anchor = planner.load_anchor(results_dir)
    except (OSError, KeyError, ValueError):
        anchor = None

    devs = np.array(jax.devices())
    rows = []
    for nodes in node_counts:
        topo = gpu_topology(nodes, devices_per_node)
        n = topo.total_devices
        mesh = Mesh(devs[:n].reshape(nodes, devices_per_node),
                    ("node", "device"))
        state_cols = _state_rows(cfg, n)
        for strat in strategies:
            pred = (planner.weak_scaling_curve(
                anchor, node_counts=(nodes,),
                devices_per_node=devices_per_node, strategy=strat,
                bucket_bytes=bucket_bytes,
                tail_bytes=traffic.get("tail_bytes"))[0]
                if anchor is not None else None)
            with mesh:
                built = build_lib.build_gan_train(mesh, policy_name="bf16",
                                                  loop=loop,
                                                  grad_reduce=strat,
                                                  bucket_mb=bucket_mb)
                lowered = built.lower()
                compiled = lowered.compile()
            jc = jaxpr_cost.cost_of(built.fn, *built.args)
            sched = jaxpr_cost.schedule_of(built.fn, *built.args)
            coll = collectives.collective_stats(compiled.as_text())
            compute_s = jc["flops"] / (n * topo.peak_flops)
            memory_s = jc["bytes"] / (n * topo.hbm_bw)
            # the compiled program's own all-reduce payload (per-device
            # HLO result bytes), priced on the topology's links
            ar_bytes = sum(v["bytes"] for k, v in coll.items())
            coll_s = interconnect.allreduce_s(ar_bytes, topo, strat,
                                              bucket_bytes)
            step_s = max(compute_s, memory_s) + coll_s
            # measured vs modeled exposure, both priced on the SAME
            # measured payload (coll_s) so the gap isolates schedule
            # fidelity, not payload accounting
            meas_frac = (sched["exposed_frac"]
                         if sched["n_collectives"] else None)
            model_total = sum(
                interconnect.allreduce_s(b, topo, strat, bucket_bytes)
                for _, b in traffic["rounds"])
            model_exposed = interconnect.exposed_comm_s(
                traffic["rounds"], topo, strat, bucket_bytes,
                compute_s=compute_s, tail_bytes=traffic.get("tail_bytes"))
            model_frac = model_exposed / model_total if model_total else 1.0
            exposed_s = None if meas_frac is None else coll_s * meas_frac
            modeled_s = coll_s * model_frac
            global_batch = 128 * n
            # same dataset scale as the predicted column (planner rows)
            steps_per_epoch = planner.EPOCH_SAMPLES / global_batch
            row = {
                "topology": topo.name, "nodes": nodes, "devices": n,
                "global_batch": global_batch,
                "loop": loop, "grad_reduce": strat,
                "measured_step_s": step_s,
                "measured_epoch_s": step_s * steps_per_epoch,
                "measured_compute_s": compute_s,
                "measured_memory_s": memory_s,
                "measured_collective_s": coll_s,
                "hlo_collective_bytes": ar_bytes,
                "jaxpr_collective_bytes": jc["collective_bytes"],
                "reduce_scatter_bytes": jc["reduce_scatter_bytes"],
                "all_gather_bytes": jc["all_gather_bytes"],
                "exposed_comm_s": exposed_s,
                "measured_exposed_frac": meas_frac,
                "modeled_exposed_comm_s": modeled_s,
                "modeled_exposed_frac": model_frac,
                "step_gap_s": (None if exposed_s is None
                               else abs(modeled_s - exposed_s)),
                **state_cols,
            }
            if pred is not None:
                row.update({
                    "predicted_step_s": pred["step_s_pred"],
                    "predicted_epoch_s": pred["epoch_s_pred"],
                    "predicted_comm_s": pred["comm_s_pred"],
                    "anchor_step_s": anchor.step_s,
                    "anchor_source": anchor.source,
                })
            rows.append(row)
            jax.clear_caches()
    # efficiencies, each strategy normalized to its own single-node row
    for strat in strategies:
        srows = [r for r in rows if r["grad_reduce"] == strat]
        ideal0 = srows[0]["measured_epoch_s"] * srows[0]["devices"]
        for r in srows:
            r["measured_efficiency"] = (ideal0 / r["devices"]
                                        / r["measured_epoch_s"])
        if anchor is not None:
            p0 = srows[0]["predicted_step_s"]
            for r in srows:
                r["predicted_efficiency"] = p0 / r["predicted_step_s"]
    return rows


def check(rows) -> list:
    """The scaleout gate (``--check``): returns a list of failure strings.

    1. model fidelity — where measured exposure exists (custom loop),
       overlap's |modeled - measured| exposure gap must not exceed
       hierarchical's at the same node count;
    2. ZeRO-1 memory — per-device optimizer+master bytes must be
       ~replicated/N (padding + the step scalar allow 10% + 64 KiB).
    """
    failures = []
    by_nodes = {}
    for r in rows:
        by_nodes.setdefault(r["nodes"], {})[r["grad_reduce"]] = r
    for nodes, strats in sorted(by_nodes.items()):
        o, h = strats.get("overlap"), strats.get("hierarchical")
        if o and h and o["step_gap_s"] is not None \
                and h["step_gap_s"] is not None:
            if o["step_gap_s"] > h["step_gap_s"] + 1e-12:
                failures.append(
                    f"nodes={nodes}: overlap model gap "
                    f"{o['step_gap_s']:.3e}s > hierarchical "
                    f"{h['step_gap_s']:.3e}s")
        any_row = next(iter(strats.values()))
        n = any_row["devices"]
        if n > 1:
            rep = any_row["opt_master_bytes_per_device"]
            z = any_row["opt_master_bytes_per_device_zero1"]
            bound = rep / n * 1.10 + 65536
            if z > bound:
                failures.append(
                    f"nodes={nodes}: zero1 opt+master {z}B/device > "
                    f"replicated/N bound {bound:.0f}B (replicated {rep}B)")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--loop", default="builtin",
                    choices=("builtin", "custom"))
    ap.add_argument("--grad-reduce", default="hierarchical",
                    choices=("flat", "hierarchical", "overlap", "all"))
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--node-counts", default="1,2,4,8,16",
                    help="comma-separated node counts (8 devices each)")
    ap.add_argument("--devices-per-node", type=int, default=8)
    ap.add_argument("--results", default="results",
                    help="dir holding BENCH_fig1_loop.json (the measured "
                         "single-node anchor the predictions replay)")
    ap.add_argument("--check", action="store_true",
                    help="gate: overlap's measured-vs-modeled exposure gap "
                         "<= hierarchical's, and zero1 state ~ 1/N "
                         "(exit 1 on failure)")
    ap.add_argument("--out", default="",
                    help="write BENCH-schema JSON here")
    args = ap.parse_args(argv)
    node_counts = tuple(int(x) for x in args.node_counts.split(","))
    t0 = time.time()
    rows = run(node_counts=node_counts,
               devices_per_node=args.devices_per_node, loop=args.loop,
               grad_reduce=args.grad_reduce, bucket_mb=args.bucket_mb,
               results_dir=args.results)
    print(f"bench_fig2_weakscaling: 3DGAN weak scaling over (node, device) "
          f"(BS=128/device, {args.loop} loop, {args.grad_reduce} reduce)")
    have_pred = "predicted_efficiency" in rows[0]
    hdr = (f"{'devices':>8} {'reduce':>13} {'meas_epoch_s':>12} "
           f"{'meas_eff':>9} {'exp_comm_ms':>11} {'gap_ms':>8}"
           + (f" {'pred_eff':>9}" if have_pred else ""))
    print(hdr)
    for r in rows:
        exp = r["exposed_comm_s"]
        gap = r["step_gap_s"]
        line = (f"{r['devices']:>8} {r['grad_reduce']:>13} "
                f"{r['measured_epoch_s']:>12.1f} "
                f"{r['measured_efficiency']:>9.3f} "
                f"{'-' if exp is None else format(exp * 1e3, '.3f'):>11} "
                f"{'-' if gap is None else format(gap * 1e3, '.3f'):>8}")
        if have_pred:
            line += f" {r['predicted_efficiency']:>9.3f}"
        print(line)
    r0 = rows[0]
    print(f"state bytes/device at {r0['devices']} devices: replicated "
          f"{r0['state_bytes_per_device']}, zero1 "
          f"{r0['state_bytes_per_device_zero1']} (opt+master "
          f"{r0['opt_master_bytes_per_device']} -> "
          f"{r0['opt_master_bytes_per_device_zero1']})")
    print("paper Fig.2-right: ~linear to 128 devices; both columns derive "
          "from measurement + structure, no efficiency table")
    rc = 0
    if args.check:
        failures = check(rows)
        for f in failures:
            print(f"CHECK FAIL: {f}")
        if not failures:
            print("check OK: overlap model gap <= hierarchical's; zero1 "
                  "opt+master state ~ replicated/N")
        rc = 1 if failures else 0
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"benchmark": "fig2_weakscaling",
                       "seconds": round(time.time() - t0, 3),
                       "rows": rows}, f, indent=2, default=str)
        print(f"[wrote {args.out}]")
    if rc:
        raise SystemExit(rc)
    return rows


if __name__ == "__main__":
    main()
