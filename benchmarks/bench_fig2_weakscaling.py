import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=128 "
                           "--xla_backend_optimization_level=0 "
                           "--xla_llvm_disable_expensive_passes=true")
"""Fig. 2 (right): weak scaling 8 -> 128 TPU cores for the 3DGAN.

Runs in its OWN process (sets a 128-device pool before importing jax).
For each core count we compile the GAN step THROUGH THE UNIFIED ENGINE
(``--loop builtin`` or ``--loop custom``, see `repro.train.engine`) with
the paper's per-core BS=128 (global batch grows with cores: weak
scaling), derive the roofline-bound step time and the epoch time for the
paper's dataset, and compare with the ideal linear-scaling line — the
quantities in Fig. 2-right.
"""
import time

import numpy as np

EPOCH_SAMPLES = 180_000       # paper-era 3DGAN training-set scale


def run(core_counts=(8, 16, 32, 64, 128), loop="builtin"):
    import jax
    from jax.sharding import Mesh
    from repro.launch import build as build_lib
    from repro.launch.mesh import HARDWARE
    from repro.parallel import collectives, jaxpr_cost
    from benchmarks.roofline import ici_per_chip_bytes

    devs = np.array(jax.devices())
    rows = []
    for n in core_counts:
        mesh = Mesh(devs[:n].reshape(n, 1), ("data", "model"))
        with mesh:
            built = build_lib.build_gan_train(mesh, policy_name="bf16",
                                              loop=loop)
            lowered = built.lower()
            compiled = lowered.compile()
        jc = jaxpr_cost.cost_of(built.fn, *built.args)
        coll = collectives.collective_stats(compiled.as_text())
        compute_s = jc["flops"] / (n * HARDWARE["peak_flops_bf16"])
        memory_s = jc["bytes"] / (n * HARDWARE["hbm_bw"])
        coll_s = ici_per_chip_bytes(coll, n) / HARDWARE["ici_bw"]
        step_s = max(compute_s, memory_s, coll_s)
        global_batch = 128 * n
        steps_per_epoch = EPOCH_SAMPLES / global_batch
        rows.append({
            "cores": n,
            "global_batch": global_batch,
            "step_s_bound": step_s,
            "epoch_s": step_s * steps_per_epoch,
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": max(("compute", compute_s), ("memory", memory_s),
                            ("collective", coll_s), key=lambda kv: kv[1])[0],
        })
        jax.clear_caches()
    ideal0 = rows[0]["epoch_s"] * rows[0]["cores"]
    for r in rows:
        r["ideal_epoch_s"] = ideal0 / r["cores"]
        r["efficiency"] = r["ideal_epoch_s"] / r["epoch_s"]
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--loop", default="builtin",
                    choices=("builtin", "custom"))
    args = ap.parse_args()
    rows = run(loop=args.loop)
    print(f"bench_fig2_weakscaling: 3DGAN roofline-derived epoch time "
          f"(BS=128/core, weak scaling, {args.loop} loop)")
    print(f"{'cores':>6} {'epoch_s':>9} {'ideal_s':>9} {'eff':>6} "
          f"{'dominant':>11}")
    for r in rows:
        print(f"{r['cores']:>6} {r['epoch_s']:>9.1f} "
              f"{r['ideal_epoch_s']:>9.1f} {r['efficiency']:>6.2f} "
              f"{r['dominant']:>11}")
    print("paper Fig.2-right: linear to 128 cores, epoch ~30s at v3-128")
    return rows


if __name__ == "__main__":
    main()
