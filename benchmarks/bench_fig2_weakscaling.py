import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=128 "
                           "--xla_backend_optimization_level=0 "
                           "--xla_llvm_disable_expensive_passes=true")
"""Fig. 2 (right): weak scaling over multi-GPU NODES for the 3DGAN.

Runs in its OWN process (sets a 128-device pool before importing jax).
For each node count we fold the virtual devices into the paper's
hierarchical ``(node, device)`` topology (8 V100-class GPUs per node),
compile the GAN step THROUGH THE UNIFIED ENGINE (``--loop`` /
``--grad-reduce`` select the strategy) at the paper's per-device BS=128
(global batch grows with devices: weak scaling), and report TWO curves
side by side:

- measured: the roofline-derived step/epoch time from the COMPILED
  program — jaxpr FLOPs/bytes against the topology's per-device
  constants, plus the compiled collective traffic priced on the
  topology's NVLink/NIC links;
- predicted: the cloud planner's curve (`cloud/planner.py`) — the
  committed measured single-node step baseline
  (``results/BENCH_fig1_loop.json``) replayed through the interconnect
  model.  No efficiency table anywhere on either path.

``--out`` writes the BENCH_fig2_weakscaling.json artifact (the schema
``benchmarks/run.py`` records for every bench).
"""
import argparse
import json
import time

import numpy as np


def run(node_counts=(1, 2, 4, 8, 16), devices_per_node=8, loop="builtin",
        grad_reduce="hierarchical", bucket_mb=4.0, results_dir="results"):
    import jax
    from jax.sharding import Mesh
    from repro.cloud import interconnect, planner
    from repro.launch import build as build_lib
    from repro.launch.mesh import gpu_topology
    from repro.parallel import collectives, jaxpr_cost

    bucket_bytes = int(bucket_mb * (1 << 20))
    try:
        anchor = planner.load_anchor(results_dir)
    except (OSError, KeyError, ValueError):
        anchor = None
    pred_rows = (planner.weak_scaling_curve(
        anchor, node_counts=node_counts, devices_per_node=devices_per_node,
        strategy=grad_reduce, bucket_bytes=bucket_bytes)
        if anchor is not None else [None] * len(node_counts))

    devs = np.array(jax.devices())
    rows = []
    for nodes, pred in zip(node_counts, pred_rows):
        topo = gpu_topology(nodes, devices_per_node)
        n = topo.total_devices
        mesh = Mesh(devs[:n].reshape(nodes, devices_per_node),
                    ("node", "device"))
        with mesh:
            built = build_lib.build_gan_train(mesh, policy_name="bf16",
                                              loop=loop,
                                              grad_reduce=grad_reduce,
                                              bucket_mb=bucket_mb)
            lowered = built.lower()
            compiled = lowered.compile()
        jc = jaxpr_cost.cost_of(built.fn, *built.args)
        coll = collectives.collective_stats(compiled.as_text())
        compute_s = jc["flops"] / (n * topo.peak_flops)
        memory_s = jc["bytes"] / (n * topo.hbm_bw)
        # the compiled program's own all-reduce payload (per-device HLO
        # result bytes), priced on the topology's links
        ar_bytes = sum(v["bytes"] for k, v in coll.items())
        coll_s = interconnect.allreduce_s(ar_bytes, topo, grad_reduce,
                                          bucket_bytes)
        step_s = max(compute_s, memory_s) + coll_s
        global_batch = 128 * n
        # same dataset scale as the predicted column (planner rows)
        steps_per_epoch = planner.EPOCH_SAMPLES / global_batch
        row = {
            "topology": topo.name, "nodes": nodes, "devices": n,
            "global_batch": global_batch,
            "loop": loop, "grad_reduce": grad_reduce,
            "measured_step_s": step_s,
            "measured_epoch_s": step_s * steps_per_epoch,
            "measured_compute_s": compute_s, "measured_memory_s": memory_s,
            "measured_collective_s": coll_s,
            "hlo_collective_bytes": ar_bytes,
            "jaxpr_collective_bytes": jc["collective_bytes"],
        }
        if pred is not None:
            row.update({
                "predicted_step_s": pred["step_s_pred"],
                "predicted_epoch_s": pred["epoch_s_pred"],
                "predicted_comm_s": pred["comm_s_pred"],
                "anchor_step_s": anchor.step_s,
                "anchor_source": anchor.source,
            })
        rows.append(row)
        jax.clear_caches()
    # efficiencies, both normalized to their own single-node row
    ideal0 = rows[0]["measured_epoch_s"] * rows[0]["devices"]
    for r in rows:
        r["measured_efficiency"] = (ideal0 / r["devices"]
                                    / r["measured_epoch_s"])
    if anchor is not None:
        p0 = rows[0]["predicted_step_s"]
        for r in rows:
            r["predicted_efficiency"] = p0 / r["predicted_step_s"]
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--loop", default="builtin",
                    choices=("builtin", "custom"))
    ap.add_argument("--grad-reduce", default="hierarchical",
                    choices=("flat", "hierarchical"))
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--results", default="results",
                    help="dir holding BENCH_fig1_loop.json (the measured "
                         "single-node anchor the predictions replay)")
    ap.add_argument("--out", default="",
                    help="write BENCH-schema JSON here")
    args = ap.parse_args(argv)
    t0 = time.time()
    rows = run(loop=args.loop, grad_reduce=args.grad_reduce,
               bucket_mb=args.bucket_mb, results_dir=args.results)
    print(f"bench_fig2_weakscaling: 3DGAN weak scaling over (node, device) "
          f"(BS=128/device, {args.loop} loop, {args.grad_reduce} reduce)")
    have_pred = "predicted_efficiency" in rows[0]
    hdr = (f"{'devices':>8} {'meas_epoch_s':>12} {'meas_eff':>9}"
           + (f" {'pred_epoch_s':>12} {'pred_eff':>9}" if have_pred else ""))
    print(hdr)
    for r in rows:
        line = (f"{r['devices']:>8} {r['measured_epoch_s']:>12.1f} "
                f"{r['measured_efficiency']:>9.3f}")
        if have_pred:
            line += (f" {r['predicted_epoch_s']:>12.1f} "
                     f"{r['predicted_efficiency']:>9.3f}")
        print(line)
    print("paper Fig.2-right: ~linear to 128 devices; both columns derive "
          "from measurement + structure, no efficiency table")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"benchmark": "fig2_weakscaling",
                       "seconds": round(time.time() - t0, 3),
                       "rows": rows}, f, indent=2, default=str)
        print(f"[wrote {args.out}]")
    return rows


if __name__ == "__main__":
    main()
