"""Kernel benchmark: fused Pallas conv3d vs the lax.conv reference.

Times forward and forward+backward on the per-layer shapes of the
3DGAN (`configs/calo3dgan`) — every transposed conv of the generator and
every strided conv of the discriminator — for both routes:

- ``pallas``: the fused implicit-GEMM kernel family (conv+bias fused,
  Pallas backward).  On the CPU stand-in this runs in INTERPRET mode,
  which measures the emulation, not the MXU — the numbers seed the perf
  trajectory and become meaningful on the TPU target.
- ``lax``: XLA's conv_general_dilated / conv_transpose (the oracle).

``--precision`` selects the operand dtype (the mixed-precision policy's
compute dtype; the kernels keep their f32 VMEM accumulators either way).
The ``tile_rows`` section is the autotuner's report card: each layer is
timed on the Pallas route with the HEURISTIC default tiles at f32 —
the pre-autotune configuration — against the AUTOTUNED tiles at
``--precision`` (tuned via `kernels/conv3d/tiles.autotune_signature`,
persisted under results/autotune/), and the summary aggregates the
end-to-end speedup the autotuner + precision policy bought.

Writes machine-readable results to results/BENCH_kernel_conv3d.json.

  PYTHONPATH=src python -m benchmarks.bench_kernel_conv3d \
      [--config bench|reduced|full] [--batch 2] [--steps 3] \
      [--precision bf16] [--no-tile-rows]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import calo3dgan
from repro.kernels.conv3d import (conv3d_bias_act, conv3d_bias_act_ref,
                                  conv3d_transpose_bias_act,
                                  conv3d_transpose_bias_act_ref)
from repro.kernels.conv3d import tiles as tiles_lib
from repro.substrate.precision import get_policy

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(HERE, "results", "BENCH_kernel_conv3d.json")


def layer_shapes(cfg):
    """(name, kind, spatial, ci, co, stride) for every conv in the GAN."""
    shapes = []
    ups = len(cfg.gen_channels) - 1
    dims = tuple(-(-d // 2 ** ups) for d in cfg.image_shape)
    for i in range(ups):
        shapes.append((f"gen_up{i}", "conv_t", dims, cfg.gen_channels[i],
                       cfg.gen_channels[i + 1], 2))
        dims = tuple(d * 2 for d in dims)
    shapes.append(("gen_out", "conv", cfg.image_shape,
                   cfg.gen_channels[-1], 1, 1))
    dims, ci = cfg.image_shape, 1
    for i, c in enumerate(cfg.disc_channels):
        shapes.append((f"disc_conv{i}", "conv", dims, ci, c, 2))
        dims = tuple(-(-d // 2) for d in dims)
        ci = c
    return shapes


def _timed(fn, args, steps, repeats=3):
    """Min-of-repeats per-step time — the autotuner's clock
    (`tiles.time_min_of_repeats`), so recorded numbers and tuning
    winners are measured identically."""
    return tiles_lib.time_min_of_repeats(fn, args, steps, repeats)


def _layer_args(spatial, ci, co, batch, rng, dtype):
    x = jnp.asarray(rng.normal(0, 1, (batch, *spatial, ci)), dtype)
    w = jnp.asarray(rng.normal(0, 0.1, (3, 3, 3, ci, co)), dtype)
    b = jnp.zeros((co,), dtype)
    return x, w, b


def _time_pallas(kind, stride, args, steps):
    op = conv3d_transpose_bias_act if kind == "conv_t" else conv3d_bias_act
    fwd = jax.jit(lambda x_, w_, b_: op(x_, w_, b_, stride))
    # loss math in f32 as the GAN step does (core/gan.disc_loss casts
    # logits/sums to f32 before the loss regardless of compute dtype)
    fwdbwd = jax.jit(jax.grad(
        lambda x_, w_, b_: jnp.sum(
            op(x_, w_, b_, stride).astype(jnp.float32) ** 2),
        argnums=(0, 1)))
    return (1e3 * _timed(fwd, args, steps),
            1e3 * _timed(fwdbwd, args, steps))


def bench_layer(name, kind, spatial, ci, co, stride, batch, steps, rng,
                dtype):
    args = _layer_args(spatial, ci, co, batch, rng, dtype)
    ops = {
        "pallas": (conv3d_transpose_bias_act if kind == "conv_t"
                   else conv3d_bias_act),
        "lax": (conv3d_transpose_bias_act_ref if kind == "conv_t"
                else conv3d_bias_act_ref),
    }
    row = {"layer": name, "kind": kind, "batch": batch, "spatial": spatial,
           "ci": ci, "co": co, "stride": stride}
    for route, op in ops.items():
        fwd = jax.jit(lambda x_, w_, b_, op=op: op(x_, w_, b_, stride))
        row[f"{route}_fwd_ms"] = 1e3 * _timed(fwd, args, steps)
        fwdbwd = jax.jit(jax.grad(
            lambda x_, w_, b_, op=op: jnp.sum(
                op(x_, w_, b_, stride).astype(jnp.float32) ** 2),
            argnums=(0, 1)))
        row[f"{route}_fwdbwd_ms"] = 1e3 * _timed(fwdbwd, args, steps)
    row["fwd_speedup"] = row["lax_fwd_ms"] / row["pallas_fwd_ms"]
    row["fwdbwd_speedup"] = row["lax_fwdbwd_ms"] / row["pallas_fwdbwd_ms"]
    return row


def _layer_sigs(kind, spatial, ci, co, stride, dtype):
    """The fwd + bwd tile signatures one layer's step hits."""
    fwd = tiles_lib.signature(kind, spatial, ci, co, 3, stride, dtype)
    return [fwd] + tiles_lib._bwd_signatures(kind, tuple(spatial), ci, co,
                                             3, stride, dtype)


def bench_layer_tiles(name, kind, spatial, ci, co, stride, batch, steps,
                      rng, precision, autotune_steps=2):
    """Autotuned-vs-default-tile row: the PRE-PR configuration (f32
    operands, heuristic default tiles) against the tuned one (compute
    dtype of ``precision``, autotuned tiles for fwd AND bwd)."""
    policy = get_policy(precision)
    dtype = policy.compute_dtype
    snapshot = dict(tiles_lib._REGISTRY)
    row = {"layer": name, "kind": kind, "ci": ci, "co": co,
           "stride": stride, "precision": precision}
    try:
        # -- baseline: pin heuristic defaults for every involved sig ----
        pinned = _layer_sigs(kind, spatial, ci, co, stride, jnp.float32)
        for sig in pinned:
            tiles_lib.register_tiles(sig, tiles_lib.default_tiles(sig))
        args32 = _layer_args(spatial, ci, co, batch, rng, jnp.float32)
        f32_fwd, f32_fwdbwd = _time_pallas(kind, stride, args32, steps)
        for sig in pinned:
            # unpin BEFORE autotuning: autotune_signature persists the
            # whole registry, and these heuristic baselines must not
            # overwrite genuinely tuned f32 cache entries
            tiles_lib._REGISTRY.pop(sig, None)

        # -- tuned: real measurements via the autotune driver ------------
        measured = 0
        for sig in _layer_sigs(kind, spatial, ci, co, stride, dtype):
            best, n = tiles_lib.autotune_signature(sig,
                                                   steps=autotune_steps)
            measured += n
            if sig[0] == kind:            # the fwd signature's winner
                row["tiles"] = {"bn": best.bn, "fuse_taps": best.fuse_taps}
        args_p = _layer_args(spatial, ci, co, batch, rng, dtype)
        at_fwd, at_fwdbwd = _time_pallas(kind, stride, args_p, steps)
    finally:
        tiles_lib._REGISTRY.clear()
        tiles_lib._REGISTRY.update(snapshot)
    row.update({
        "default_f32_fwd_ms": f32_fwd, "default_f32_fwdbwd_ms": f32_fwdbwd,
        "autotuned_fwd_ms": at_fwd, "autotuned_fwdbwd_ms": at_fwdbwd,
        "autotune_measurements": measured,
        "fwd_speedup": f32_fwd / at_fwd,
        "fwdbwd_speedup": f32_fwdbwd / at_fwdbwd,
    })
    return row


def run(config="bench", batch=2, steps=3, seed=0, precision="f32"):
    cfg = {"bench": calo3dgan.bench, "reduced": calo3dgan.reduced,
           "full": calo3dgan.config}[config]()
    dtype = get_policy(precision).compute_dtype
    rng = np.random.default_rng(seed)
    rows = []
    for spec in layer_shapes(cfg):
        rows.append(bench_layer(*spec, batch=batch, steps=steps, rng=rng,
                                dtype=dtype))
    return rows


def run_tiles(config="bench", batch=2, steps=3, seed=0, precision="bf16"):
    cfg = {"bench": calo3dgan.bench, "reduced": calo3dgan.reduced,
           "full": calo3dgan.config}[config]()
    rng = np.random.default_rng(seed)
    return [bench_layer_tiles(*spec, batch=batch, steps=steps, rng=rng,
                              precision=precision)
            for spec in layer_shapes(cfg)]


def tile_summary(tile_rows, precision):
    tot_def = sum(r["default_f32_fwdbwd_ms"] for r in tile_rows)
    tot_at = sum(r["autotuned_fwdbwd_ms"] for r in tile_rows)
    tot_def_f = sum(r["default_f32_fwd_ms"] for r in tile_rows)
    tot_at_f = sum(r["autotuned_fwd_ms"] for r in tile_rows)
    return {
        "precision": precision,
        "default_f32_fwd_ms_total": tot_def_f,
        "autotuned_fwd_ms_total": tot_at_f,
        "default_f32_fwdbwd_ms_total": tot_def,
        "autotuned_fwdbwd_ms_total": tot_at,
        "fwd_speedup": tot_def_f / tot_at_f,
        "fwdbwd_speedup": tot_def / tot_at,
    }


def write_json(rows, path=OUT_PATH, **meta):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {"benchmark": "kernel_conv3d",
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu", **meta,
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="bench",
                    choices=("bench", "reduced", "full"))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--precision", default="bf16",
                    help="compute dtype for the route rows and the "
                         "autotuned side of the tile rows")
    ap.add_argument("--no-tile-rows", action="store_true",
                    help="skip the autotuned-vs-default-tile comparison")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv)
    rows = run(args.config, args.batch, args.steps,
               precision=args.precision)
    print(f"bench_kernel_conv3d: Pallas fused vs lax.conv "
          f"({args.config} config, B={args.batch}, "
          f"precision={args.precision}, backend={jax.default_backend()})")
    hdr = (f"{'layer':>12} {'kind':>7} {'ci':>4} {'co':>4} "
           f"{'pallas_fwd':>11} {'lax_fwd':>9} {'pallas_fb':>10} "
           f"{'lax_fb':>8} {'fb_speedup':>10}")
    print(hdr)
    for r in rows:
        print(f"{r['layer']:>12} {r['kind']:>7} {r['ci']:>4} {r['co']:>4} "
              f"{r['pallas_fwd_ms']:>9.1f}ms {r['lax_fwd_ms']:>7.1f}ms "
              f"{r['pallas_fwdbwd_ms']:>8.1f}ms {r['lax_fwdbwd_ms']:>6.1f}ms "
              f"{r['fwdbwd_speedup']:>10.2f}")
    meta = {"config": args.config, "batch": args.batch,
            "precision": args.precision}
    if not args.no_tile_rows:
        tile_rows = run_tiles(args.config, args.batch, args.steps,
                              precision=args.precision)
        summary = tile_summary(tile_rows, args.precision)
        print(f"\ntile autotuner: {args.precision}+autotuned vs "
              "f32+default tiles (Pallas route, fwd+bwd)")
        print(f"{'layer':>12} {'tiles':>18} {'f32_def_fb':>11} "
              f"{'tuned_fb':>9} {'speedup':>8}")
        for r in tile_rows:
            t = r.get("tiles", {})
            tl = f"bn={t.get('bn', '?')},fuse={t.get('fuse_taps', '?')}"
            print(f"{r['layer']:>12} {tl:>18} "
                  f"{r['default_f32_fwdbwd_ms']:>9.1f}ms "
                  f"{r['autotuned_fwdbwd_ms']:>7.1f}ms "
                  f"{r['fwdbwd_speedup']:>8.2f}")
        print(f"{'TOTAL':>12} {'':>18} "
              f"{summary['default_f32_fwdbwd_ms_total']:>9.1f}ms "
              f"{summary['autotuned_fwdbwd_ms_total']:>7.1f}ms "
              f"{summary['fwdbwd_speedup']:>8.2f}")
        meta["tile_rows"] = tile_rows
        meta["tile_summary"] = summary
    path = write_json(rows, args.out, **meta)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    main()
