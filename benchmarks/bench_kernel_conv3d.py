"""Kernel benchmark: fused Pallas conv3d vs the lax.conv reference.

Times forward and forward+backward on the per-layer shapes of the
3DGAN (`configs/calo3dgan`) — every transposed conv of the generator and
every strided conv of the discriminator — for both routes:

- ``pallas``: the fused implicit-GEMM kernel family (conv+bias fused,
  Pallas backward).  On the CPU stand-in this runs in INTERPRET mode,
  which measures the emulation, not the MXU — the numbers seed the perf
  trajectory and become meaningful on the TPU target.
- ``lax``: XLA's conv_general_dilated / conv_transpose (the oracle).

Writes machine-readable results to results/BENCH_kernel_conv3d.json.

  PYTHONPATH=src python -m benchmarks.bench_kernel_conv3d \
      [--config bench|reduced|full] [--batch 2] [--steps 3]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import calo3dgan
from repro.kernels.conv3d import (conv3d_bias_act, conv3d_bias_act_ref,
                                  conv3d_transpose_bias_act,
                                  conv3d_transpose_bias_act_ref)

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(HERE, "results", "BENCH_kernel_conv3d.json")


def layer_shapes(cfg):
    """(name, kind, spatial, ci, co, stride) for every conv in the GAN."""
    shapes = []
    ups = len(cfg.gen_channels) - 1
    dims = tuple(-(-d // 2 ** ups) for d in cfg.image_shape)
    for i in range(ups):
        shapes.append((f"gen_up{i}", "conv_t", dims, cfg.gen_channels[i],
                       cfg.gen_channels[i + 1], 2))
        dims = tuple(d * 2 for d in dims)
    shapes.append(("gen_out", "conv", cfg.image_shape,
                   cfg.gen_channels[-1], 1, 1))
    dims, ci = cfg.image_shape, 1
    for i, c in enumerate(cfg.disc_channels):
        shapes.append((f"disc_conv{i}", "conv", dims, ci, c, 2))
        dims = tuple(-(-d // 2) for d in dims)
        ci = c
    return shapes


def _timed(fn, args, steps):
    out = fn(*args)                       # warmup / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(steps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / steps


def bench_layer(name, kind, spatial, ci, co, stride, batch, steps, rng):
    x = jnp.asarray(rng.normal(0, 1, (batch, *spatial, ci)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (3, 3, 3, ci, co)), jnp.float32)
    b = jnp.zeros((co,), jnp.float32)
    ops = {
        "pallas": (conv3d_transpose_bias_act if kind == "conv_t"
                   else conv3d_bias_act),
        "lax": (conv3d_transpose_bias_act_ref if kind == "conv_t"
                else conv3d_bias_act_ref),
    }
    row = {"layer": name, "kind": kind, "batch": batch, "spatial": spatial,
           "ci": ci, "co": co, "stride": stride}
    for route, op in ops.items():
        fwd = jax.jit(lambda x_, w_, b_, op=op: op(x_, w_, b_, stride))
        row[f"{route}_fwd_ms"] = 1e3 * _timed(fwd, (x, w, b), steps)
        fwdbwd = jax.jit(jax.grad(
            lambda x_, w_, b_, op=op: jnp.sum(op(x_, w_, b_, stride) ** 2),
            argnums=(0, 1)))
        row[f"{route}_fwdbwd_ms"] = 1e3 * _timed(fwdbwd, (x, w, b), steps)
    row["fwd_speedup"] = row["lax_fwd_ms"] / row["pallas_fwd_ms"]
    row["fwdbwd_speedup"] = row["lax_fwdbwd_ms"] / row["pallas_fwdbwd_ms"]
    return row


def run(config="bench", batch=2, steps=3, seed=0):
    cfg = {"bench": calo3dgan.bench, "reduced": calo3dgan.reduced,
           "full": calo3dgan.config}[config]()
    rng = np.random.default_rng(seed)
    rows = []
    for spec in layer_shapes(cfg):
        rows.append(bench_layer(*spec, batch=batch, steps=steps, rng=rng))
    return rows


def write_json(rows, path=OUT_PATH, **meta):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {"benchmark": "kernel_conv3d",
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu", **meta,
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="bench",
                    choices=("bench", "reduced", "full"))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv)
    rows = run(args.config, args.batch, args.steps)
    print(f"bench_kernel_conv3d: Pallas fused vs lax.conv "
          f"({args.config} config, B={args.batch}, "
          f"backend={jax.default_backend()})")
    hdr = (f"{'layer':>12} {'kind':>7} {'ci':>4} {'co':>4} "
           f"{'pallas_fwd':>11} {'lax_fwd':>9} {'pallas_fb':>10} "
           f"{'lax_fb':>8} {'fb_speedup':>10}")
    print(hdr)
    for r in rows:
        print(f"{r['layer']:>12} {r['kind']:>7} {r['ci']:>4} {r['co']:>4} "
              f"{r['pallas_fwd_ms']:>9.1f}ms {r['lax_fwd_ms']:>7.1f}ms "
              f"{r['pallas_fwdbwd_ms']:>8.1f}ms {r['lax_fwdbwd_ms']:>6.1f}ms "
              f"{r['fwdbwd_speedup']:>10.2f}")
    path = write_json(rows, args.out, config=args.config, batch=args.batch)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    main()
