"""Fig. 5: scaling + cost-per-epoch on GCP (V100 reserved/preemptible, TPU).

Reproduces the paper's cost table: epoch time drops ~linearly with GPUs
while cost/epoch stays ~flat; preemptible TPU v3-8 is ~2.4x cheaper than
the GPU-equivalent epoch.  Epoch times follow the paper's measured scaling
efficiencies; prices are the paper-era GCP europe-west4 list.
"""
from __future__ import annotations

from repro.cloud import costs as cost_lib

# paper: one epoch on 2 V100s (BS=96/GPU) — anchor point, seconds
BASE_EPOCH_S_2GPU = 5200.0
# TPU comparison anchors (paper Fig. 2/5): v3-8 epoch and v3-32 epoch
TPU_V3_8_EPOCH_S = 480.0
TPU_V3_32_EPOCH_S = 120.0


def run():
    rows = []
    for pre in (False, True):
        for ec in cost_lib.scaling_cost_table(BASE_EPOCH_S_2GPU,
                                              preemptible=pre):
            rows.append({"device": ec.device, "n": ec.n_devices,
                         "epoch_s": ec.epoch_time_s, "cost_usd": ec.cost})
    for ver, cores, t, pre in (("v3", 8, TPU_V3_8_EPOCH_S, True),
                               ("v3", 8, TPU_V3_8_EPOCH_S, False),
                               ("v3", 32, TPU_V3_32_EPOCH_S, False)):
        ec = cost_lib.tpu_epoch_cost(ver, cores, t, preemptible=pre)
        rows.append({"device": ec.device, "n": ec.n_devices,
                     "epoch_s": ec.epoch_time_s, "cost_usd": ec.cost})
    return rows


def main():
    rows = run()
    print("bench_fig5_cost: cost per epoch (GCP europe-west4, paper-era)")
    print(f"{'device':>16} {'n':>4} {'epoch_s':>9} {'cost_usd':>9}")
    for r in rows:
        print(f"{r['device']:>16} {r['n']:>4} {r['epoch_s']:>9.0f} "
              f"{r['cost_usd']:>9.2f}")
    # paper claims
    pre = [r for r in rows if r["device"] == "V100-pre"]
    flat = max(r["cost_usd"] for r in pre) / min(r["cost_usd"] for r in pre)
    print(f"cost/epoch spread across 2..128 preemptible GPUs: x{flat:.2f} "
          "(paper: ~flat)")
    v100_64 = next(r for r in pre if r["n"] == 64)
    tpu8 = next(r for r in rows if r["device"] == "TPU-v3-8-pre")
    print(f"preemptible TPU v3-8 vs 64 preemptible V100: "
          f"{v100_64['cost_usd'] / tpu8['cost_usd']:.1f}x cheaper "
          "(paper: 2.4x vs GPU-equivalent)")
    return rows


if __name__ == "__main__":
    main()
