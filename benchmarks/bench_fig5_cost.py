"""Fig. 5: scaling + cost-per-epoch on GCP (V100 reserved/preemptible, TPU).

Reproduces the paper's cost table THROUGH THE PLANNER
(`cloud/planner.cost_frontier`): epoch time drops ~linearly with GPUs
while cost/epoch stays ~flat; preemptible TPU v3-8 is ~2.4x cheaper than
the GPU-equivalent epoch.  Parallel efficiencies are DERIVED — the
measured base step (implied by the paper's 2-GPU epoch anchor) plus the
cross-node interconnect model — instead of the hard-coded table this
bench used to carry; prices are the paper-era GCP europe-west4 list.
The TPU v3-32 row is itself a prediction from the v3-8 anchor through
the ICI model (it lands on the paper's ~120 s epoch).
"""
from __future__ import annotations

import argparse

from repro.cloud import planner

# paper: one epoch on 2 V100s (BS=96/GPU) — anchor point, seconds
BASE_EPOCH_S_2GPU = 5200.0
# TPU comparison anchors (paper Fig. 2/5): v3-8 and v2-8 epochs are
# measured anchors; v3-32 (None) is predicted through the ICI model
TPU_EPOCH_ANCHORS = {"v3-8": 480.0, "v2-8": 1056.0, "v3-32": None}


def run(grad_reduce: str = "overlap"):
    return planner.cost_frontier(BASE_EPOCH_S_2GPU, base_gpus=2,
                                 strategy=grad_reduce,
                                 tpu_epochs=TPU_EPOCH_ANCHORS)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--grad-reduce", default="overlap",
                    choices=("flat", "hierarchical", "overlap"),
                    help="reduction strategy the derived efficiencies "
                         "assume (overlap = comm hidden under backward)")
    args = ap.parse_args(argv)
    rows = run(grad_reduce=args.grad_reduce)
    print("bench_fig5_cost: cost per epoch (GCP europe-west4, paper-era; "
          f"efficiencies derived via cloud/interconnect with "
          f"{args.grad_reduce} reduce, not tabulated)")
    print(f"{'device':>16} {'n':>4} {'epoch_s':>9} {'cost_usd':>9} "
          f"{'eff':>6}")
    for r in rows:
        eff = f"{r['efficiency']:>6.3f}" if r["efficiency"] else "     -"
        print(f"{r['device']:>16} {r['n']:>4} {r['epoch_s']:>9.0f} "
              f"{r['cost_usd']:>9.2f} {eff}")
    # paper claims
    pre = [r for r in rows if r["device"] == "V100-pre"]
    flat = max(r["cost_usd"] for r in pre) / min(r["cost_usd"] for r in pre)
    print(f"cost/epoch spread across 2..128 preemptible GPUs: x{flat:.2f} "
          "(paper: ~flat)")
    v100_64 = next(r for r in pre if r["n"] == 64)
    tpu8 = next(r for r in rows if r["device"] == "TPU-v3-8-pre")
    print(f"preemptible TPU v3-8 vs 64 preemptible V100: "
          f"{v100_64['cost_usd'] / tpu8['cost_usd']:.1f}x cheaper "
          "(paper: 2.4x vs GPU-equivalent)")
    tpu32 = next(r for r in rows if r["device"] == "TPU-v3-32")
    print(f"predicted TPU v3-32 epoch: {tpu32['epoch_s']:.0f}s "
          "(paper: ~120s)")
    return rows


if __name__ == "__main__":
    main()
