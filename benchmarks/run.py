"""Benchmark harness: one benchmark per paper table/figure.

In-process (1 CPU device): fig1 loop, fig2 batch-size, physics, fig5 cost,
fig6 pipeline, serving, the conv3d kernel bench.  Own-device-pool (each
sets XLA_FLAGS before importing jax, so it needs its own process): fig2
weak scaling (128 devs), fig4 layout (32 devs), and the §Roofline report
(reads results/dryrun_baseline.json produced by repro.launch.dryrun).

EVERY registered benchmark — in-process or own-pool — writes its rows to
results/BENCH_<name>.json (machine-readable — the perf-trajectory record
that successive PRs diff against), in addition to the printed tables.

  PYTHONPATH=src python -m benchmarks.run [--skip-subprocess]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(HERE, "results")


def _banner(name):
    print("\n" + "=" * 72)
    print(f"== {name}")
    print("=" * 72, flush=True)


def _write_bench_json(name, rows, seconds):
    """BENCH_<name>.json: whatever the benchmark's main() returned."""
    if rows is None:
        return
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"benchmark": name, "seconds": round(seconds, 3),
                   "rows": rows}, f, indent=2, default=str)
    print(f"[wrote {path}]")


def _run_inproc(name, main_fn, failures, write=True):
    t0 = time.time()
    try:
        rows = main_fn()
    except Exception as e:          # keep the harness going; record it
        print(f"[{name}: FAILED — {e}]")
        failures.append(name)
        return
    if write:                       # benches that write their own richer
        _write_bench_json(name, rows, time.time() - t0)  # JSON skip this


def _sub(mod, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "src")
    env.pop("XLA_FLAGS", None)          # each module sets its own
    t0 = time.time()
    r = subprocess.run([sys.executable, "-m", mod, *args], cwd=HERE, env=env)
    print(f"[{mod}: {'ok' if r.returncode == 0 else 'FAILED'} "
          f"in {time.time() - t0:.0f}s]")
    return r.returncode


def _run_registered_sub(name, mod, failures, *args):
    """Registered device-pool bench: runs in its own process (it must
    set XLA_FLAGS before importing jax) but is a first-class bench —
    ``--out`` makes it write the same results/BENCH_<name>.json artifact
    ``_write_bench_json`` produces for the in-process ones."""
    out = os.path.join(RESULTS, f"BENCH_{name}.json")
    if _sub(mod, "--out", out):
        failures.append(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-subprocess", action="store_true",
                    help="only the in-process benches (single device)")
    ap.add_argument("--precision", default="bf16",
                    help="mixed-precision policy passed through to the "
                         "fig1 loop and conv3d kernel benches, so the "
                         "BENCH_*.json files record the policy speedup")
    args = ap.parse_args()

    failures = []

    _banner("Fig.1 — naive vs fused adversarial loop")
    from benchmarks import bench_fig1_loop
    _run_inproc("fig1_loop",
                lambda: bench_fig1_loop.main(["--precision",
                                              args.precision]), failures)

    _banner("Fig.2 (left/center) — batch-size impact")
    from benchmarks import bench_fig2_batchsize
    _run_inproc("fig2_batchsize", bench_fig2_batchsize.main, failures)

    _banner("Fig.3/7 — physics validation (GAN vs MC)")
    from benchmarks import bench_physics
    _run_inproc("physics", bench_physics.main, failures)

    _banner("Fig.5 — cloud cost per epoch")
    from benchmarks import bench_fig5_cost
    _run_inproc("fig5_cost", bench_fig5_cost.main, failures)

    _banner("Fig.6 — data-pipeline prefetch overlap")
    from benchmarks import bench_fig6_pipeline
    _run_inproc("fig6_pipeline", bench_fig6_pipeline.main, failures)

    _banner("Serving — 3DGAN fast-simulation engine (events/s, gate)")
    from benchmarks import bench_serve_fastsim
    # writes its own BENCH_serve_fastsim.json with gate/ratio metadata
    _run_inproc("serve_fastsim", bench_serve_fastsim.main, failures,
                write=False)

    _banner("Serving — LM continuous batching (chunked prefill, decode)")
    from benchmarks import bench_serve_lm
    # writes its own BENCH_serve_lm.json with backend/routing metadata
    _run_inproc("serve_lm", bench_serve_lm.main, failures, write=False)

    _banner("Kernel — fused Pallas conv3d vs lax.conv (fwd / fwd+bwd)")
    from benchmarks import bench_kernel_conv3d
    # writes its own BENCH_kernel_conv3d.json with backend/config metadata
    # + the autotuned-vs-default tile rows; reduced config — the layers
    # are big enough to time above the container's noise floor
    _run_inproc("kernel_conv3d",
                lambda: bench_kernel_conv3d.main(
                    ["--config", "reduced", "--steps", "5",
                     "--precision", args.precision]),
                failures, write=False)

    if not args.skip_subprocess:
        _banner("Fig.2 (right) — weak scaling over (node, device) "
                "[own device pool]")
        _run_registered_sub("fig2_weakscaling",
                            "benchmarks.bench_fig2_weakscaling", failures)

        _banner("Fig.4 — worker/mesh layout sweep [own device pool]")
        _run_registered_sub("fig4_layout",
                            "benchmarks.bench_fig4_layout", failures)

        _banner("§Roofline — per (arch x shape x mesh) [reads dry-run JSON]")
        dj = os.path.join(HERE, "results", "dryrun_baseline.json")
        if os.path.exists(dj):
            if _sub("benchmarks.roofline"):
                failures.append("roofline")
        else:
            print(f"skipped: {dj} not found — run "
                  "`python -m repro.launch.dryrun --all --both-meshes "
                  "--out results/dryrun_baseline.json` first")

    print("\nbenchmarks done" + (f"; FAILURES: {failures}" if failures else ""))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
